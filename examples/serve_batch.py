"""Serve a small model with batched requests through the continuous-
batching engine (prefill + greedy decode, slot waves).

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    model = build_model(SMOKES["qwen2.5-3b"])
    engine = ServeEngine(model, batch_size=4, max_seq=64,
                         rng=jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i, prompt=rng.integers(1, 500, size=int(rng.integers(4, 12))),
                max_new_tokens=8)
        for i in range(10)
    ]
    t0 = time.time()
    out = engine.generate(requests)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in out.values())
    for uid in sorted(out):
        print(f"request {uid}: {out[uid]}")
    print(f"{n_tok} tokens in {dt:.1f}s ({n_tok / dt:.1f} tok/s, CPU)")


if __name__ == "__main__":
    main()
