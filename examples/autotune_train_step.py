import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf cell C: the paper's profile-based searcher autotunes the
DISTRIBUTED STEP CONFIG of qwen2.5-3b train_4k on the production mesh,
through the public ``repro.tuning`` API.

Training phase: ``TuningSession.train_on_evaluator`` compiles a deliberate
sample of the step space and fits the TP -> PC_ops model.  Autotuning:
profile -> bottleneck -> ΔPC -> biased step, against REAL compiles, driven
ask-tell.  Compared with random search at the same budget.

    PYTHONPATH=src python examples/autotune_train_step.py \
        [--arch qwen2.5-3b] [--budget 10] [--out step_tune.json]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

from repro.core.step_tuner import CompiledStepEvaluator  # noqa: E402
from repro.tuning import TuningSession                   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--train-samples", type=int, default=14)
    ap.add_argument("--out", default="step_tune.json")
    ap.add_argument("--save-model", default=None,
                    help="also write the trained TP->PC model JSON artifact")
    args = ap.parse_args()

    t0 = time.time()
    ev_train = CompiledStepEvaluator(args.arch, args.shape)
    space = ev_train.space
    print(f"step space: {len(space)} configs")

    # --- training phase: deliberate sample -> TP->PC model ---------------
    session = TuningSession(space, seed=0)
    print(f"training phase: compiling <= {args.train_samples} sampled configs")
    session.train_on_evaluator(ev_train, values_per_param=2,
                               max_samples=args.train_samples)
    print(f"model trained ({ev_train.compile_seconds:.0f}s of compiles, "
          f"{ev_train.steps} empirical tests)")
    if args.save_model:
        session.save_model(args.save_model)
        print(f"model artifact -> {args.save_model}")

    # --- autotuning: profile-based vs random at the same budget ----------
    results = {"space": len(space), "train_samples": ev_train.steps,
               "budget": args.budget}
    for label in ("profile", "random"):
        ev = CompiledStepEvaluator(args.arch, args.shape)
        ev._cache.update(ev_train._cache)  # share compile cache across
        extra = {"n": 3} if label == "profile" else {}
        session.tune(budget=args.budget, searcher=label, evaluator=ev,
                     seed=1, **extra)
        best = space[ev.best_index]
        print(f"[{label}] best {ev.best_runtime*1e3:.1f}ms after "
              f"{ev.steps} tests: {best}")
        results[label] = {"best_ms": ev.best_runtime * 1e3,
                          "best_config": best, "steps": ev.steps}
    results["train_best_ms"] = ev_train.best_runtime * 1e3
    results["train_best_config"] = space[ev_train.best_index]
    results["total_seconds"] = time.time() - t0
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"done in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
