import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf cell C: the paper's profile-based searcher autotunes the
DISTRIBUTED STEP CONFIG of qwen2.5-3b train_4k on the production mesh.

Training phase: a deliberate sample of the step space is compiled and
parsed (TP -> PC_ops model).  Autotuning: profile -> bottleneck -> ΔPC ->
biased step, against REAL compiles.  Compared with random search at the
same budget.

    PYTHONPATH=src python examples/autotune_train_step.py \
        [--arch qwen2.5-3b] [--budget 10] [--out step_tune.json]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import numpy as np   # noqa: E402

from repro.core import (ProfileBasedSearcher, RandomSearcher,  # noqa: E402
                        deliberate_training_sample)
from repro.core.model import DecisionTreeModel                 # noqa: E402
from repro.core.step_tuner import CompiledStepEvaluator        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--train-samples", type=int, default=14)
    ap.add_argument("--out", default="step_tune.json")
    args = ap.parse_args()

    t0 = time.time()
    ev_train = CompiledStepEvaluator(args.arch, args.shape)
    space = ev_train.space
    print(f"step space: {len(space)} configs")

    # --- training phase: deliberate sample -> TP->PC model ---------------
    sample = deliberate_training_sample(space, values_per_param=2,
                                        rng=np.random.default_rng(0))
    sample = sample[:args.train_samples]
    print(f"training phase: compiling {len(sample)} sampled configs")
    cfgs, counters = [], []
    for i in sample:
        cs = ev_train.profile(i)
        cfgs.append(space[i])
        counters.append(cs.ops)
    model = DecisionTreeModel(space, cfgs, counters)
    print(f"model trained ({ev_train.compile_seconds:.0f}s of compiles)")

    # --- autotuning: profile-based vs random at the same budget ----------
    results = {"space": len(space), "train_samples": len(sample),
               "budget": args.budget}
    for label, searcher_fn in (
        ("profile", lambda evx: ProfileBasedSearcher(
            space, model, cores=1, n=3, seed=1)),
        ("random", lambda evx: RandomSearcher(space, seed=1)),
    ):
        ev = CompiledStepEvaluator(args.arch, args.shape)
        ev._cache.update(ev_train._cache)  # share compile cache across
        searcher_fn(ev).search(ev, max_steps=args.budget)
        best = space[ev.best_index]
        print(f"[{label}] best {ev.best_runtime*1e3:.1f}ms after "
              f"{ev.steps} tests: {best}")
        results[label] = {"best_ms": ev.best_runtime * 1e3,
                          "best_config": best, "steps": ev.steps}
    results["train_best_ms"] = ev_train.best_runtime * 1e3
    results["train_best_config"] = space[ev_train.best_index]
    results["total_seconds"] = time.time() - t0
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"done in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
