"""Quickstart: autotune a Pallas GEMM's block sizes through the public
``repro.tuning`` API — model trained on virtual TPU v4, serialized to JSON,
then used to tune on v5e (the paper's hardware-portability headline).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

from repro.core import SPECS
from repro.kernels.registry import BENCHMARKS
from repro.tuning import TuningSession


def main():
    bm = BENCHMARKS["matmul"]
    space = bm.make_space()
    workload = lambda cfg: bm.workload_fn(cfg, bm.default_input)

    # Phase 1 — train the portable TP→PC_ops model on DIFFERENT hardware
    # and ship it as a JSON artifact.
    trainer = TuningSession(space, workload, hw=SPECS["tpu_v4"], seed=0)
    trainer.train(kind="tree")
    artifact = os.path.join(tempfile.gettempdir(), "gemm_tppc_v4.json")
    trainer.save_model(artifact)
    print(f"model trained on tpu_v4 -> {artifact} "
          f"({os.path.getsize(artifact)} bytes)")

    # Phase 2 — load the artifact on the machine of interest and tune.
    session = TuningSession(space, workload, hw=SPECS["tpu_v5e"], seed=0)
    session.load_model(artifact)
    result = session.tune(budget=25)
    print(f"space: {len(space)} configurations")
    print(f"best after {result.steps} empirical tests: "
          f"{result.best_runtime * 1e6:.1f} us")
    print(f"best config: {result.best_config}")

    # validate the chosen configuration numerically (interpret mode)
    import jax.numpy as jnp
    from repro.kernels.matmul.space import GemmInput
    rng = np.random.default_rng(0)
    inp = GemmInput(256, 256, 256)
    a, b = bm.make_args(inp, rng)
    cfg = dict(result.best_config)
    cfg["BLOCK_M"] = min(cfg["BLOCK_M"], 256)
    cfg["BLOCK_N"] = min(cfg["BLOCK_N"], 256)
    cfg["BLOCK_K"] = min(cfg["BLOCK_K"], 256)
    out = bm.run(cfg, a, b, interpret=True)
    err = float(jnp.max(jnp.abs(out - bm.ref(a, b))))
    print(f"numerical check vs oracle (256^3): max err {err:.2e}")


if __name__ == "__main__":
    main()
