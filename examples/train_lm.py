"""End-to-end driver: train the reduced qwen1.5 config for a few hundred
steps on CPU with checkpointing (the full-size path is identical — swap
--smoke for a real mesh).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps), "--batch", "8", "--seq", "256",
        "--ckpt-dir", "/tmp/repro_train_ckpt", "--ckpt-every", "50",
    ]
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src",
                                               "PATH": "/usr/bin:/bin",
                                               "HOME": "/root"}))


if __name__ == "__main__":
    main()
