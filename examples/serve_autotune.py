"""Online shape-bucketed serving autotuner: drift -> retune -> reuse.

Replays a shifting request mix against the deterministic synthetic backend
(no model weights needed — the same substrate the benchmark uses), showing
the three behaviors of the online tuner:

1. a new dominant shape bucket triggers a handful of live warm-started
   trials (the portable TP→PC model ranks the space; only the top few
   configurations are measured);
2. a stable mix costs zero trials;
3. a bucket seen before — in this process or in the persisted store — is
   reused with zero live trials.

    PYTHONPATH=src python examples/serve_autotune.py

For the real engine, see ``python -m repro.launch.serve --autotune``.
"""
import os
import tempfile

import numpy as np

from repro.core.hwspec import SPECS
from repro.serve.autotune import (OnlineAutotuner, ServeWorkloadStats,
                                  ShapeBucketer, SyntheticServeBackend)
from repro.serve.engine import Request
from repro.tuning.store import ConfigStore


def tick(rng, plen_c, new_c, n=24, uid0=0):
    return [Request(uid=uid0 + i,
                    prompt=np.ones(int(np.clip(rng.normal(plen_c, 2), 1, 96)),
                                   np.int32),
                    max_new_tokens=int(np.clip(rng.normal(new_c, 1), 1, 32)))
            for i in range(n)]


def run(store_path):
    stats = ServeWorkloadStats()
    backend = SyntheticServeBackend(SPECS["tpu_v4"], stats, seed=0)
    tuner = OnlineAutotuner(backend, store=ConfigStore(store_path),
                            bucketer=ShapeBucketer(max_prompt=96, max_new=32),
                            hw=SPECS["tpu_v4"], train_hw=SPECS["tpu_v5e"],
                            stats=stats, seed=0)
    rng = np.random.default_rng(0)
    uid = 0
    # phases: short prompts/gens -> long/long -> back to short
    for name, (p, nw) in [("short", (12, 6)), ("long", (80, 28)),
                          ("short again", (12, 6))]:
        for t in range(3):
            requests = tick(rng, p, nw, uid0=uid)
            uid += len(requests)
            _, rep = tuner.serve(requests)
            what = ("reused from store" if rep.reused else
                    f"tuned live ({rep.live_trials} trials)"
                    if rep.drift else "steady state")
            print(f"  [{name:12s} tick {t}] bucket={rep.bucket:5s} "
                  f"{what:24s} config={rep.config}")
    return tuner


def main():
    with tempfile.TemporaryDirectory() as td:
        store_path = os.path.join(td, "serve_store.json")
        print("run 1 (cold store):")
        run(store_path)
        print("run 2 (same store — every drift event is pure reuse):")
        tuner = run(store_path)
        trials = sum(r.live_trials for r in tuner.reports)
        print(f"run 2 spent {trials} live trials total")


if __name__ == "__main__":
    main()
