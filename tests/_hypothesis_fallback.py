"""Seeded stand-in for the slice of the hypothesis API this suite uses.

The container image may not ship ``hypothesis``; rather than losing the
property tests to a collection ImportError, the three modules that use it
fall back to this shim: ``@given`` runs the test body on ``max_examples``
pseudo-random samples drawn from the declared strategies with a fixed seed.
No shrinking, no database — just deterministic sampled coverage.
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, gen):
        self.gen = gen


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda r: [elements.gen(r)
                       for _ in range(r.randint(min_size, max_size))])


st = _Strategies()


def settings(max_examples: int = 20, **_ignored):
    """Accepts (and ignores) hypothesis-only knobs like ``deadline``."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        def wrapper():
            rnd = random.Random(0)
            for _ in range(getattr(fn, "_max_examples", 20)):
                fn(*(s.gen(rnd) for s in strategies))

        # keep the test's identity for collection/reporting, but present a
        # zero-arg signature so pytest doesn't mistake params for fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
