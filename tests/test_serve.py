"""Serving engine: batched generate, slot waves, determinism, and the
partial-wave / token-budget / tuning-timing regression tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine, tune_engine_batch


class EchoModel:
    """Deterministic fake model: next token = (last token + 1) % VOCAB.

    jit-compatible prefill/decode with the registry ``Model`` calling
    convention, so engine behavior (wave masking, budgets, EOS) is testable
    exactly, without weights or a real forward pass.
    """

    VOCAB = 32

    def init(self, rng):
        return {"w": jnp.zeros((1,))}

    def _logits(self, tok):
        nxt = (tok + 1) % self.VOCAB
        return jax.nn.one_hot(nxt, self.VOCAB, dtype=jnp.float32)[:, None, :]

    def prefill(self, params, batch, max_seq):
        last = batch["tokens"][:, -1].astype(jnp.int32)
        return self._logits(last), (last + 1) % self.VOCAB

    def decode(self, params, cache, batch):
        tok = batch["tokens"][:, 0].astype(jnp.int32)
        return self._logits(tok), (tok + 1) % self.VOCAB


def echo_engine(batch_size, max_seq=32):
    return ServeEngine(EchoModel(), batch_size=batch_size, max_seq=max_seq,
                       rng=jax.random.PRNGKey(0))


def _count_decodes(engine):
    """Wrap ``engine._decode`` to record decode-call token shapes."""
    calls = []
    orig = engine._decode

    def counting(params, cache, batch):
        calls.append(tuple(batch["tokens"].shape))
        return orig(params, cache, batch)

    engine._decode = counting
    return calls


@pytest.fixture(scope="module")
def engine():
    model = build_model(SMOKES["qwen1.5-0.5b"])
    return ServeEngine(model, batch_size=2, max_seq=32,
                       rng=jax.random.PRNGKey(7))


def _reqs(n, rng):
    return [
        Request(uid=i,
                prompt=rng.integers(1, 500, size=rng.integers(3, 8)),
                max_new_tokens=5)
        for i in range(n)
    ]


def test_generate_batch(engine):
    rng = np.random.default_rng(0)
    out = engine.generate(_reqs(2, rng))
    assert set(out) == {0, 1}
    for toks in out.values():
        assert len(toks) == 5
        assert all(0 <= t < 512 for t in toks)


def test_generate_more_requests_than_slots(engine):
    rng = np.random.default_rng(1)
    out = engine.generate(_reqs(5, rng))
    assert set(out) == set(range(5))


def test_generate_deterministic(engine):
    rng1 = np.random.default_rng(2)
    rng2 = np.random.default_rng(2)
    a = engine.generate(_reqs(2, rng1))
    b = engine.generate(_reqs(2, rng2))
    assert a == b


# =============================================================================
# Edge cases + bugfix regressions (deterministic fake model)
# =============================================================================
def test_echo_model_sequence():
    out = echo_engine(2).generate(
        [Request(uid=0, prompt=np.array([5], np.int32), max_new_tokens=4)])
    assert out[0] == [6, 7, 8, 9]


def test_partial_wave_masks_ghost_slots():
    """Regression: a partial wave must prefill/decode only its true size —
    pre-fix, zero-padded ghost slots ran the full decode loop."""
    eng = echo_engine(4)
    calls = _count_decodes(eng)
    reqs = [Request(uid=i, prompt=np.array([3 + i], np.int32),
                    max_new_tokens=3) for i in range(2)]
    out = eng.generate(reqs)
    assert out == {0: [4, 5, 6], 1: [5, 6, 7]}
    assert calls, "expected at least one decode step"
    assert all(shape == (2, 1) for shape in calls), calls


def test_partial_wave_matches_full_wave_output_and_steps():
    """A 2-request wave must produce identical output and decode-step count
    whether the engine batch is exactly 2 or has 2 ghost slots."""
    reqs = [Request(uid=i, prompt=np.array([10 + i], np.int32),
                    max_new_tokens=4) for i in range(2)]
    full = echo_engine(2)
    partial = echo_engine(4)
    full_calls = _count_decodes(full)
    partial_calls = _count_decodes(partial)
    out_full = full.generate([dataclasses.replace(r) for r in reqs])
    out_partial = partial.generate([dataclasses.replace(r) for r in reqs])
    assert out_full == out_partial
    assert len(full_calls) == len(partial_calls)


def test_max_new_tokens_zero_gets_no_tokens():
    """Regression: a 0-budget request batched with longer ones received one
    generated token (append ran before the length check)."""
    reqs = [Request(uid=0, prompt=np.array([5], np.int32), max_new_tokens=0),
            Request(uid=1, prompt=np.array([7], np.int32), max_new_tokens=3)]
    out = echo_engine(2).generate(reqs)
    assert out[0] == []
    assert out[1] == [8, 9, 10]


def test_all_zero_budget_wave_never_decodes():
    eng = echo_engine(2)
    calls = _count_decodes(eng)
    out = eng.generate([Request(uid=i, prompt=np.array([4], np.int32),
                                max_new_tokens=0) for i in range(2)])
    assert out == {0: [], 1: []}
    assert calls == []


def test_eos_mid_wave():
    """One request hits EOS early; its slot stops appending while the other
    runs to its full budget."""
    reqs = [Request(uid=0, prompt=np.array([5], np.int32), max_new_tokens=6,
                    eos_id=7),
            Request(uid=1, prompt=np.array([20], np.int32), max_new_tokens=6)]
    out = echo_engine(2).generate(reqs)
    assert out[0] == [6, 7]                        # stops at EOS (included)
    assert out[1] == [21, 22, 23, 24, 25, 26]      # full budget


def test_empty_request_list():
    assert echo_engine(2).generate([]) == {}


def test_engine_warmup_compiles_decode():
    eng = echo_engine(2, max_seq=16)
    calls = _count_decodes(eng)
    eng.warmup()
    assert len(calls) >= 1


# =============================================================================
# tune_engine_batch: warmup + engine reuse (JIT-bias regression)
# =============================================================================
class _FakeEngine:
    def __init__(self, batch, log, builds):
        self.batch = batch
        self.log = log
        builds[batch] = builds.get(batch, 0) + 1

    def warmup(self):
        self.log.append(("warmup", self.batch))

    def generate(self, requests):
        self.log.append(("generate", self.batch))
        return {r.uid: [] for r in requests}


def test_tune_engine_batch_warms_up_and_reuses_engines():
    """Regression: each trial must serve an untimed warmup wave before its
    timed run (pre-fix, first-call JIT compilation was inside the timed
    region) and engines must be built once per batch size."""
    log, builds = [], {}
    reqs = [Request(uid=i, prompt=np.array([1], np.int32), max_new_tokens=2)
            for i in range(4)]
    best, best_s, hist = tune_engine_batch(
        lambda b: _FakeEngine(b, log, builds), reqs, batch_sizes=(1, 2, 4))
    assert set(builds) == {1, 2, 4} and all(v == 1 for v in builds.values())
    assert len(hist) == 3
    for b in (1, 2, 4):
        events = [kind for kind, eb in log if eb == b]
        assert events[0] == "warmup", (b, events)
        assert events.count("generate") >= 1
