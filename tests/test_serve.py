"""Serving engine: batched generate, slot waves, determinism."""
import jax
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    model = build_model(SMOKES["qwen1.5-0.5b"])
    return ServeEngine(model, batch_size=2, max_seq=32,
                       rng=jax.random.PRNGKey(7))


def _reqs(n, rng):
    return [
        Request(uid=i,
                prompt=rng.integers(1, 500, size=rng.integers(3, 8)),
                max_new_tokens=5)
        for i in range(n)
    ]


def test_generate_batch(engine):
    rng = np.random.default_rng(0)
    out = engine.generate(_reqs(2, rng))
    assert set(out) == {0, 1}
    for toks in out.values():
        assert len(toks) == 5
        assert all(0 <= t < 512 for t in toks)


def test_generate_more_requests_than_slots(engine):
    rng = np.random.default_rng(1)
    out = engine.generate(_reqs(5, rng))
    assert set(out) == set(range(5))


def test_generate_deterministic(engine):
    rng1 = np.random.default_rng(2)
    rng2 = np.random.default_rng(2)
    a = engine.generate(_reqs(2, rng1))
    b = engine.generate(_reqs(2, rng2))
    assert a == b
