"""Cost model, bottleneck analysis, ΔPC reaction, scoring (paper §3.5-3.6)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # seeded sampling shim (no pip deps)
    from _hypothesis_fallback import given, settings, st

from repro.core import SPECS, analyze, compute_delta_pc
from repro.core import counters as C
from repro.core import costmodel, scoring
from repro.core.bottleneck import (B_HBM_READ, B_MXU, B_PARAL, B_SPILL,
                                   ALL_BOTTLENECKS)
from repro.core.reaction import INST_REACTION_DEFAULT

HW = SPECS["tpu_v5e"]


def _mk_ops(**kw):
    ops = {k: 0.0 for k in C.PC_OPS}
    ops.update(kw)
    return ops


def test_compute_bound_runtime():
    ops = _mk_ops(**{C.MXU_FLOPS: 197e12, C.GRID: 64, C.VMEM_WS: 2**20})
    cs = costmodel.execute(ops, HW)
    assert 0.9 < cs.runtime < 1.2          # ~1s of MXU work
    assert cs.st(C.MXU_U) > 0.8


def test_memory_bound_runtime():
    ops = _mk_ops(**{C.HBM_RD: 819e9, C.GRID: 64, C.VMEM_WS: 2**20})
    cs = costmodel.execute(ops, HW)
    assert 0.9 < cs.runtime < 1.2
    assert cs.st(C.HBM_U) > 0.8


def test_spill_cliff():
    base = _mk_ops(**{C.VPU_OPS: 1e9, C.GRID: 16})
    fit = costmodel.execute({**base, C.VMEM_WS: HW.vmem_bytes / 4}, HW)
    spill = costmodel.execute({**base, C.VMEM_WS: HW.vmem_bytes * 2}, HW)
    assert spill.runtime > fit.runtime
    assert spill.op(C.SPILL_B) > 0.0


def test_double_buffer_cliff():
    """WS beyond half VMEM serializes DMA with compute."""
    ops = _mk_ops(**{C.MXU_FLOPS: 1e12, C.HBM_RD: 5e9, C.GRID: 16})
    db = costmodel.execute({**ops, C.VMEM_WS: HW.vmem_bytes / 4}, HW)
    ser = costmodel.execute({**ops, C.VMEM_WS: HW.vmem_bytes * 0.9}, HW)
    assert ser.runtime > db.runtime


def test_parallelism_penalty():
    """One program on a 2-core chip leaves half the chip idle (v4)."""
    hw4 = SPECS["tpu_v4"]
    ops = _mk_ops(**{C.MXU_FLOPS: 1e13, C.VMEM_WS: 2**20})
    few = costmodel.execute({**ops, C.GRID: 1}, hw4)
    many = costmodel.execute({**ops, C.GRID: 8}, hw4)
    assert many.runtime < few.runtime
    assert few.st(C.CORE_E) == pytest.approx(0.5)


def test_bottleneck_vector_range():
    ops = _mk_ops(**{C.MXU_FLOPS: 1e14, C.HBM_RD: 1e11, C.HBM_WR: 1e10,
                     C.VMEM_RD: 1e11, C.VMEM_WR: 1e10, C.TRANS_OPS: 1e10,
                     C.VPU_OPS: 1e12, C.ISSUE_OPS: 1e14 + 1e12,
                     C.GRID: 8, C.VMEM_WS: 2**24})
    cs = costmodel.execute(ops, HW)
    b = analyze(cs, cores=HW.cores)
    assert set(b) == set(ALL_BOTTLENECKS)
    for k, v in b.items():
        assert 0.0 <= v <= 1.0, (k, v)


def test_memory_bottleneck_identified():
    ops = _mk_ops(**{C.HBM_RD: 1e12, C.HBM_WR: 1e10, C.VPU_OPS: 1e9,
                     C.ISSUE_OPS: 1e9, C.GRID: 64, C.VMEM_WS: 2**20})
    cs = costmodel.execute(ops, HW)
    b = analyze(cs, cores=HW.cores)
    assert b[B_HBM_READ] > 0.8
    delta = compute_delta_pc(b)
    assert delta[C.HBM_RD] < -0.8          # reaction: reduce HBM reads


def test_inst_reaction_threshold():
    """Instruction reactions only fire above inst_reaction (Eq. 15)."""
    b = {k: 0.0 for k in ALL_BOTTLENECKS}
    b[B_MXU] = INST_REACTION_DEFAULT - 0.05
    assert compute_delta_pc(b)[C.MXU_FLOPS] == 0.0
    b[B_MXU] = INST_REACTION_DEFAULT + 0.15
    assert compute_delta_pc(b)[C.MXU_FLOPS] < 0.0


def test_parallel_reaction_positive():
    b = {k: 0.0 for k in ALL_BOTTLENECKS}
    b[B_PARAL] = 0.5
    assert compute_delta_pc(b)[C.GRID] == 0.5


def test_delta_pc_range():
    b = {k: 1.0 for k in ALL_BOTTLENECKS}
    for k, v in compute_delta_pc(b).items():
        assert -1.0 <= v <= 1.0


# --- scoring (Eq. 16-17) -------------------------------------------------------
def test_score_prefers_required_direction():
    delta = {C.HBM_RD: -1.0}
    prof = {C.HBM_RD: 100.0}
    better = {C.HBM_RD: 50.0}
    worse = {C.HBM_RD: 200.0}
    assert scoring.score_configuration(delta, prof, better) > 0
    assert scoring.score_configuration(delta, prof, worse) < 0


def test_score_skips_zero_predictions():
    delta = {C.HBM_RD: -1.0}
    assert scoring.score_configuration(delta, {C.HBM_RD: 0.0},
                                       {C.HBM_RD: 5.0}) == 0.0


@given(st.lists(st.floats(-1.0, 1.0), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_normalize_scores_range(scores):
    w = scoring.normalize_scores(scores)
    assert (w >= scoring.FLOOR - 1e-12).all()
    assert (w <= scoring.CEIL + 1e-9).all()


def test_normalize_scores_amplifies_positive():
    w = scoring.normalize_scores([1.0, 0.5, -0.1, -0.5])
    assert w[0] == pytest.approx(256.0)
    assert w[1] > 1.0
    assert w[2] < 1.0
    assert w[3] == pytest.approx(scoring.FLOOR)  # below γ cutoff


def test_weighted_choice_respects_mask():
    rngs = np.random.default_rng(0)
    w = np.array([1.0, 1000.0, 1.0])
    mask = np.array([True, False, True])
    for _ in range(20):
        assert scoring.weighted_choice(w, rngs, mask) != 1
