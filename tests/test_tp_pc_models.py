"""TP→PC_ops models: decision trees, quadratic regression, exact replay."""
import numpy as np

from repro.core import (DecisionTreeModel, ExactCounterModel,
                        QuadraticRegressionModel, TuningParameter,
                        TuningSpace, deliberate_training_sample)
from repro.core import counters as C


def _space():
    return TuningSpace([
        TuningParameter("x", (1, 2, 4, 8, 16)),
        TuningParameter("y", (1, 2, 4)),
        TuningParameter("flag", (0, 1)),
    ])


def _counters_for(space):
    """Ground truth with quadratic + interaction structure per subspace."""
    out = []
    for cfg in space:
        base = 2.0 if cfg["flag"] else 1.0
        out.append({
            C.HBM_RD: base * (100.0 * cfg["x"] + cfg["x"] * cfg["y"]),
            C.MXU_FLOPS: base * (cfg["y"] ** 2) * 50.0,
            C.GRID: float(cfg["x"] * cfg["y"]),
        })
    return out


def test_exact_model_replays():
    sp = _space()
    cs = _counters_for(sp)
    m = ExactCounterModel(sp, cs)
    for i, cfg in enumerate(sp):
        assert m.predict(cfg) == cs[i]


def test_quadratic_model_recovers_quadratics():
    sp = _space()
    cs = _counters_for(sp)
    m = QuadraticRegressionModel(sp, list(sp), cs,
                                 counters_to_model=(C.HBM_RD, C.MXU_FLOPS,
                                                    C.GRID))
    for i, cfg in enumerate(sp):
        pred = m.predict(cfg)
        for k in (C.HBM_RD, C.MXU_FLOPS):
            true = cs[i][k]
            assert abs(pred[k] - true) <= 0.05 * abs(true) + 1.0, (cfg, k)


def test_tree_model_low_error_in_sample():
    sp = _space()
    cs = _counters_for(sp)
    m = DecisionTreeModel(sp, list(sp), cs,
                          counters_to_model=(C.HBM_RD, C.GRID))
    errs = []
    for i, cfg in enumerate(sp):
        pred = m.predict(cfg)[C.HBM_RD]
        true = cs[i][C.HBM_RD]
        errs.append(abs(pred - true) / (abs(true) + 1e-9))
    assert np.median(errs) < 0.5


def test_deliberate_sample_covers_binary_subspaces():
    sp = _space()
    idxs = deliberate_training_sample(sp, values_per_param=2)
    flags = {sp[i]["flag"] for i in idxs}
    assert flags == {0, 1}
    # 2 values per non-binary param -> at most 2*2*2 configs
    assert len(idxs) <= 8
