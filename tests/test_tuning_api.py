"""The public ask-tell tuning API: registry, protocol, golden equivalence
with the legacy searcher loop, and the portable-model artifact."""
import json

import numpy as np
import pytest

from repro.core import (SEARCHERS, SPECS, ProfilingUnsupported,
                        ReplayEvaluator, convergence_curve, record_space,
                        run_search, train_model)
from repro.core import bottleneck, reaction, scoring
from repro.core.evaluate import FunctionEvaluator
from repro.core.tuner import SearchStats
from repro.core.tuning_space import TuningParameter, TuningSpace
from repro.kernels.registry import BENCHMARKS
from repro.tuning import TuningSession, make_searcher

HW = SPECS["tpu_v5e"]


@pytest.fixture(scope="module")
def gemm_recorded():
    bm = BENCHMARKS["matmul"]
    sp = bm.make_space()
    return record_space(sp, lambda c: bm.workload_fn(c, bm.default_input), HW)


@pytest.fixture(scope="module")
def gemm_recorded_v4(gemm_recorded):
    bm = BENCHMARKS["matmul"]
    return record_space(gemm_recorded.space,
                        lambda c: bm.workload_fn(c, bm.default_input),
                        SPECS["tpu_v4"])


# =============================================================================
# Registry + protocol basics
# =============================================================================
def test_registry_constructs_all_searchers_uniformly(gemm_recorded):
    assert {"random", "profile", "basin_hopping", "starchart",
            "profile_local"} <= set(SEARCHERS)
    for name in SEARCHERS:
        s = SEARCHERS[name](gemm_recorded.space, seed=7)
        assert s.name == name


def test_ask_tell_protocol_shape(gemm_recorded):
    s = SEARCHERS["random"](gemm_recorded.space, seed=0)
    ev = ReplayEvaluator(gemm_recorded)
    cands = s.propose(5)
    assert len(cands) == 5
    obs = ev.measure_many(cands)
    assert [o.index for o in obs] == [c.index for c in cands]
    s.observe(obs)
    more = s.propose(3)
    assert len(more) == 3
    assert {c.index for c in more}.isdisjoint({c.index for c in cands})


def test_profile_searcher_without_model_raises(gemm_recorded):
    s = SEARCHERS["profile"](gemm_recorded.space, seed=0)
    with pytest.raises(ValueError, match="model"):
        s.propose(1)


def test_profile_searcher_without_cores_raises(gemm_recorded):
    model = train_model(gemm_recorded, kind="exact")
    s = SEARCHERS["profile"](gemm_recorded.space, seed=0, model=model)
    with pytest.raises(ValueError, match="core"):
        s.propose(1)


def test_session_rejects_typo_searcher_kwargs(gemm_recorded):
    session = TuningSession(gemm_recorded.space, seed=0)
    ev = ReplayEvaluator(gemm_recorded)
    with pytest.raises(TypeError, match="inst_reation"):
        session.tune(budget=5, searcher="random", evaluator=ev,
                     inst_reation=0.9)


def test_run_search_budget_is_relative_to_entry(gemm_recorded):
    ev = ReplayEvaluator(gemm_recorded)
    for i in range(14):   # e.g. a training phase charged to the same account
        ev.measure(i)
    run_search(SEARCHERS["random"](gemm_recorded.space, seed=0), ev, 10)
    assert ev.steps == 24   # full 10-step search budget after the 14


def test_evaluator_history_is_public(gemm_recorded):
    ev = ReplayEvaluator(gemm_recorded)
    run_search(SEARCHERS["random"](gemm_recorded.space, seed=0), ev, 10)
    hist = ev.history()
    assert len(hist) == 10
    assert all(rt == float(gemm_recorded.runtimes[i]) for i, rt in hist)
    # trace and history agree step-for-step
    assert [rt for _, rt in hist] == [rt for _, _, rt in ev.trace]


def test_function_evaluator_runtime_only():
    sp = TuningSpace([TuningParameter("X", (1, 2, 3, 4))])
    ev = FunctionEvaluator(sp, lambda cfg: 1.0 / cfg["X"])
    run_search(make_searcher("random", sp, seed=0), ev, len(sp))
    assert ev.best_index == sp.index_of({"X": 4})
    with pytest.raises(ProfilingUnsupported):
        ev.profile(0)


def test_function_evaluator_cache_hit_charges_nothing():
    """Regression: a re-measure served from the memo cache must not charge
    ``fn``'s runtime again — the function never re-ran."""
    calls = []
    sp = TuningSpace([TuningParameter("X", (1, 2))])
    ev = FunctionEvaluator(sp, lambda cfg: calls.append(cfg["X"]) or 0.5)
    assert ev.measure(0) == 0.5
    assert ev.measure(0) == 0.5
    assert calls == [1]                      # fn ran once
    assert ev.steps == 2                     # both tests counted
    assert len(ev.history()) == 2
    assert ev.elapsed == pytest.approx(0.5)  # pre-fix: 1.0


def test_function_evaluator_uncached_rerun_pays_per_test():
    """``cache=False`` re-runs fn per measurement; each test pays its own
    cost (Replay-consistent re-measure accounting)."""
    calls = []
    sp = TuningSpace([TuningParameter("X", (1, 2))])
    ev = FunctionEvaluator(sp, lambda cfg: calls.append(cfg["X"]) or 0.5,
                           cache=False)
    ev.measure(0)
    ev.measure(0)
    assert calls == [1, 1]
    assert ev.steps == 2
    assert ev.elapsed == pytest.approx(1.0)


def test_warm_start_searcher_follows_order_then_covers_space():
    sp = TuningSpace([TuningParameter("X", (1, 2, 3, 4))])
    ev = FunctionEvaluator(sp, lambda cfg: float(cfg["X"]))
    s = SEARCHERS["warm_start"](sp, seed=0, order=[2, 0])
    run_search(s, ev, len(sp))
    idxs = [i for i, _ in ev.history()]
    assert idxs[:2] == [2, 0]                # warm-start prefix, in order
    assert sorted(idxs) == [0, 1, 2, 3]      # fallback tail covers the rest
    assert ev.best_index == 0


# =============================================================================
# Golden equivalence: ask-tell == legacy loop, step for step
# =============================================================================
def _legacy_profile_search(space, model, cores, n, inst_reaction, seed, ev,
                           max_steps):
    """Verbatim port of the pre-ask-tell Algorithm 1 search loop."""
    rng = np.random.default_rng(seed)
    pred_cache = {}

    def predict(i):
        if i not in pred_cache:
            pred_cache[i] = model.predict(space[i])
        return pred_cache[i]

    size = len(space)
    c_profile = int(rng.integers(size))
    while ev.steps < max_steps and not ev.exhausted():
        pc = ev.profile(c_profile)
        t = pc.runtime
        b = bottleneck.analyze(pc, cores=cores)
        delta_pc = reaction.compute_delta_pc(b, inst_reaction)
        pc_prof = predict(c_profile)
        raw = np.zeros(size)
        mask = np.zeros(size, dtype=bool)
        for k in range(size):
            if k in ev.evaluated:
                continue
            mask[k] = True
            raw[k] = scoring.score_configuration(delta_pc, pc_prof,
                                                 predict(k))
        if not mask.any():
            return
        weights = scoring.normalize_scores(raw)
        for _ in range(n):
            if ev.steps >= max_steps or not mask.any():
                break
            sel = scoring.weighted_choice(weights, rng, mask)
            t_new = ev.measure(sel)
            mask[sel] = False
            if t_new <= t:
                c_profile, t = sel, t_new
        if ev.exhausted():
            return


@pytest.mark.parametrize("budget", [17, 40, 256])
def test_profile_ask_tell_matches_legacy_trace(gemm_recorded, budget):
    model = train_model(gemm_recorded, kind="exact")
    for seed in range(5):
        ev_old = ReplayEvaluator(gemm_recorded)
        _legacy_profile_search(
            gemm_recorded.space, model, cores=HW.cores, n=5,
            inst_reaction=reaction.INST_REACTION_DEFAULT, seed=seed,
            ev=ev_old, max_steps=budget)
        ev_new = ReplayEvaluator(gemm_recorded)
        s = SEARCHERS["profile"](gemm_recorded.space, seed=seed, model=model,
                                 cores=HW.cores)
        run_search(s, ev_new, budget)
        assert ev_old.trace == ev_new.trace


def test_random_ask_tell_matches_legacy_trace(gemm_recorded):
    for seed in range(5):
        ev_old = ReplayEvaluator(gemm_recorded)
        rng = np.random.default_rng(seed)
        for idx in rng.permutation(len(gemm_recorded.space))[:50]:
            ev_old.measure(int(idx))
        ev_new = ReplayEvaluator(gemm_recorded)
        run_search(SEARCHERS["random"](gemm_recorded.space, seed=seed),
                   ev_new, 50)
        assert ev_old.trace == ev_new.trace


# =============================================================================
# The portable-model artifact (paper headline as a file)
# =============================================================================
def test_model_save_load_predict_round_trip(tmp_path, gemm_recorded_v4):
    sp = gemm_recorded_v4.space
    bm = BENCHMARKS["matmul"]
    wl = lambda c: bm.workload_fn(c, bm.default_input)
    session = TuningSession(sp, wl, hw=SPECS["tpu_v4"], seed=0)
    model = session.train(kind="tree")
    path = session.save_model(str(tmp_path / "tppc.json"))
    # artifact is plain JSON
    d = json.loads(open(path).read())
    assert d["format"] == "repro.tppc_model" and d["kind"] == "tree"
    # load into a session targeting DIFFERENT hardware
    other = TuningSession(sp, wl, hw=SPECS["tpu_v6e"], seed=1)
    loaded = other.load_model(path)
    for idx in (0, 17, len(sp) - 1):
        assert model.predict(sp[idx]) == loaded.predict(sp[idx])


@pytest.mark.parametrize("kind", ["quadratic", "exact"])
def test_other_model_kinds_round_trip(tmp_path, gemm_recorded_v4, kind):
    from repro.tuning import model_from_dict, model_to_dict

    sp = gemm_recorded_v4.space
    model = train_model(gemm_recorded_v4, kind=kind)
    blob = json.dumps(model_to_dict(model))
    loaded = model_from_dict(json.loads(blob))  # space rebuilt from artifact
    for idx in (3, 100):
        p1, p2 = model.predict(sp[idx]), loaded.predict(sp[idx])
        assert p1.keys() == p2.keys()
        for k in p1:
            assert p1[k] == pytest.approx(p2[k], rel=1e-12, abs=1e-12)


def test_portable_artifact_steers_search_on_other_hardware(
        tmp_path, gemm_recorded, gemm_recorded_v4):
    """Acceptance: model trained on tpu_v4, shipped through JSON, steers
    ProfileBasedSearcher on tpu_v5e to a well-performing config (<=1.1x
    best) in fewer median steps than random search."""
    bm = BENCHMARKS["matmul"]
    sp = gemm_recorded.space
    wl = lambda c: bm.workload_fn(c, bm.default_input)
    trainer = TuningSession(sp, wl, hw=SPECS["tpu_v4"], seed=0)
    trainer.train(sample="full", kind="tree")
    path = trainer.save_model(str(tmp_path / "v4.json"))

    session = TuningSession(sp, wl, hw=HW, seed=0)
    model = session.load_model(path)

    threshold = gemm_recorded.best_runtime * 1.1
    repeats = 40

    def median_steps(factory):
        steps = []
        for rep in range(repeats):
            ev = ReplayEvaluator(gemm_recorded)
            run_search(factory(rep), ev, len(sp))
            found = next((s for s, _, rt in ev.trace if rt <= threshold),
                         None)
            assert found is not None  # full budget always finds it
            steps.append(found)
        return float(np.median(steps))

    med_profile = median_steps(
        lambda s: SEARCHERS["profile"](sp, seed=s, model=model,
                                       cores=HW.cores))
    med_random = median_steps(lambda s: SEARCHERS["random"](sp, seed=s))
    assert med_profile < med_random


# =============================================================================
# TuningSession behaviour
# =============================================================================
def test_session_two_phase_and_result(gemm_recorded):
    bm = BENCHMARKS["matmul"]
    sp = gemm_recorded.space
    wl = lambda c: bm.workload_fn(c, bm.default_input)
    session = TuningSession(sp, wl, hw=HW, seed=0)
    session.train(train_hw=SPECS["tpu_v4"])
    result = session.tune(budget=25)
    assert result.steps == 25
    assert result.best_runtime > 0
    assert result.history == sorted(result.history)
    # any registry searcher works through the same entry point
    r2 = session.tune(budget=10, searcher="basin_hopping")
    assert r2.steps == 10


def test_session_tune_with_explicit_evaluator(gemm_recorded):
    session = TuningSession(gemm_recorded.space, seed=3)
    ev = ReplayEvaluator(gemm_recorded)
    result = session.tune(budget=15, searcher="random", evaluator=ev)
    assert result.steps == 15 and ev.steps == 15


# =============================================================================
# Satellite guards
# =============================================================================
def test_convergence_curve_empty_traces_do_not_raise(gemm_recorded):
    grid, mean, std = convergence_curve(
        lambda s: SEARCHERS["random"](gemm_recorded.space, seed=s),
        gemm_recorded, repeats=3, max_steps=0,
        time_grid=np.array([1.0, 2.0]))
    assert grid.shape == mean.shape == std.shape
    assert np.isnan(mean).all()


def test_load_model_rejects_incompatible_space(tmp_path, gemm_recorded_v4):
    sp = gemm_recorded_v4.space
    bm = BENCHMARKS["matmul"]
    wl = lambda c: bm.workload_fn(c, bm.default_input)
    trainer = TuningSession(sp, wl, hw=SPECS["tpu_v4"], seed=0)
    trainer.train()
    path = trainer.save_model(str(tmp_path / "gemm.json"))
    other_space = BENCHMARKS["transpose"].make_space()
    session = TuningSession(other_space, seed=0)
    with pytest.raises(ValueError, match="incompatible tuning space"):
        session.load_model(path)


def test_session_rejects_seed_on_searcher_instance(gemm_recorded):
    session = TuningSession(gemm_recorded.space, seed=0)
    s = SEARCHERS["random"](gemm_recorded.space, seed=1)
    ev = ReplayEvaluator(gemm_recorded)
    with pytest.raises(TypeError, match="already-constructed"):
        session.tune(budget=5, searcher=s, evaluator=ev, seed=7)


def test_starchart_counts_build_steps_under_truncating_budget(gemm_recorded):
    s = SEARCHERS["starchart"](gemm_recorded.space, seed=0)
    ev = ReplayEvaluator(gemm_recorded)
    run_search(s, ev, 10)   # budget ends inside the model-build phase
    assert s.model_build_steps == ev.steps == 10


def test_search_stats_never_found_reporting():
    st = SearchStats(searcher="random", steps_to_well=[], times_to_well=[],
                     never_found=7)
    assert st.found_rate == 0.0
    assert np.isnan(st.mean_steps) and np.isnan(st.median_steps)
    assert "never found" in st.summary() and "7" in st.summary()
    st2 = SearchStats(searcher="x", steps_to_well=[2, 4], times_to_well=[1.0, 2.0],
                      never_found=1)
    assert st2.found_rate == pytest.approx(2 / 3)
    assert "1/3" in st2.summary()
