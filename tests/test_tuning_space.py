"""Tuning-space construction and invariants (unit + property)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # seeded sampling shim (no pip deps)
    from _hypothesis_fallback import given, settings, st

from repro.core import TuningParameter, TuningSpace, powers_of_two


def test_cross_product_size():
    sp = TuningSpace([
        TuningParameter("a", (1, 2, 3)),
        TuningParameter("b", (0, 1)),
    ])
    assert len(sp) == 6


def test_constraints_prune():
    sp = TuningSpace(
        [TuningParameter("a", (1, 2, 4)), TuningParameter("b", (1, 2, 4))],
        constraints=[lambda c: c["a"] * c["b"] <= 4],
    )
    assert all(c["a"] * c["b"] <= 4 for c in sp)
    assert len(sp) == 6


def test_empty_space_raises():
    with pytest.raises(ValueError):
        TuningSpace([TuningParameter("a", (1,))],
                    constraints=[lambda c: False])


def test_binary_detection():
    sp = TuningSpace([TuningParameter("a", (0, 1)),
                      TuningParameter("b", (2, 4))])
    assert [p.name for p in sp.binary_parameters] == ["a"]
    assert [p.name for p in sp.nonbinary_parameters] == ["b"]


def test_neighbours_differ_by_one():
    sp = TuningSpace([TuningParameter("a", (1, 2, 3)),
                      TuningParameter("b", (0, 1))])
    for nb in sp.neighbours(0):
        diff = sum(1 for k in sp[0] if sp[0][k] != sp[nb][k])
        assert diff == 1


def test_index_roundtrip():
    sp = TuningSpace([TuningParameter("a", (1, 2, 3)),
                      TuningParameter("b", ("x", "y"))])
    for i, cfg in enumerate(sp):
        assert sp.index_of(cfg) == i


def test_subspace_key():
    sp = TuningSpace([TuningParameter("bin", (0, 1)),
                      TuningParameter("v", (1, 2))])
    keys = {sp.subspace_key(c) for c in sp}
    assert keys == {(0,), (1,)}


@given(st.integers(1, 4), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_property_size_product(na, nb):
    sp = TuningSpace([
        TuningParameter("a", tuple(range(na))),
        TuningParameter("b", tuple(range(10, 10 + nb))),
    ])
    assert len(sp) == na * nb
    # vectorize is total and numeric
    for cfg in sp:
        v = sp.vectorize(cfg)
        assert len(v) == 2
        assert all(isinstance(x, float) for x in v)


def test_non_numeric_values_stay_supported():
    """The space is generic over what a parameter means (docstring claim):
    values float() cannot convert encode as their declared index."""
    sp = TuningSpace([TuningParameter("shard", ((1, 2), (2, 1), (4, 1))),
                      TuningParameter("b", (0, 1))])
    assert len(sp) == 6
    assert sp.feature_matrix[:, 0].tolist() == [0.0, 0.0, 1.0, 1.0, 2.0, 2.0]
    for i, cfg in enumerate(sp):
        assert sp.index_of(cfg) == i
    for nb in sp.neighbours(0):
        diff = sum(1 for k in sp[0] if sp[0][k] != sp[nb][k])
        assert diff == 1


def test_tuple_valued_space_survives_json_round_trip():
    """JSON turns tuple values into lists (unhashable): the space must still
    construct, index and enumerate neighbours after deserialization."""
    import json

    from repro.tuning.serialize import space_from_dict, space_to_dict
    sp = TuningSpace([TuningParameter("shard", ((1, 2), (2, 1), (4, 1))),
                      TuningParameter("b", (0, 1))])
    sp2 = space_from_dict(json.loads(json.dumps(space_to_dict(sp))))
    assert len(sp2) == len(sp)
    for i, cfg in enumerate(sp2):
        assert sp2.index_of(cfg) == i
    assert sp2.feature_matrix.tolist() == sp.feature_matrix.tolist()
    assert [sp2.neighbours(i) for i in range(len(sp2))] \
        == [sp.neighbours(i) for i in range(len(sp))]


def test_index_of_rejects_encoding_coincidence():
    """A numeric 0 must not alias the 0th declared string value."""
    sp = TuningSpace([TuningParameter("s", ("a", "b"))])
    assert sp.index_of({"s": "a"}) == 0
    with pytest.raises(KeyError):
        sp.index_of({"s": 0})


def test_mixed_string_numeric_parameter_values():
    """A parameter mixing strings and numerics must keep exact raw-value
    index/neighbour semantics even though 'b' and 1 share a feature code."""
    sp = TuningSpace([TuningParameter("x", ("a", "b", 1)),
                      TuningParameter("y", (0, 1))])
    for i, cfg in enumerate(sp):
        assert sp.index_of(dict(cfg)) == i
    for idx in range(len(sp)):
        nbrs = sp.neighbours(idx)
        assert len(nbrs) == len(set(nbrs))  # no duplicates
        for nb in nbrs:
            diff = sum(1 for k in sp[idx] if sp[idx][k] != sp[nb][k])
            assert diff == 1


def test_feature_matrix_and_subspace_keys_align():
    sp = TuningSpace([TuningParameter("a", (1, 2, 3)),
                      TuningParameter("flag", (0, 1)),
                      TuningParameter("s", ("x", "y"))])
    assert sp.vectorize_configs(sp.configs).tolist() \
        == sp.feature_matrix.tolist()
    assert sp.subspace_keys() == [sp.subspace_key(c) for c in sp]
    assert sp.subspace_key_matrix.shape == (len(sp), 1)


def test_powers_of_two():
    assert powers_of_two(8, 64) == (8, 16, 32, 64)


def test_step_space_well_formed():
    """The distributed-step tuning space (core/step_tuner.py)."""
    from repro.core.step_tuner import make_step_space
    sp = make_step_space()
    assert len(sp) == 4 * 2 * 4 * 4 * 2
    names = {p.name for p in sp.parameters}
    assert {"MICROBATCHES", "REMAT", "LOSS_CHUNKS", "KV_CHUNK",
            "FSDP"} == names
    # FSDP is the only binary parameter -> 2 model subspaces
    assert len(sp.binary_parameters) == 1
