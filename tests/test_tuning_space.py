"""Tuning-space construction and invariants (unit + property)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # seeded sampling shim (no pip deps)
    from _hypothesis_fallback import given, settings, st

from repro.core import TuningParameter, TuningSpace, powers_of_two


def test_cross_product_size():
    sp = TuningSpace([
        TuningParameter("a", (1, 2, 3)),
        TuningParameter("b", (0, 1)),
    ])
    assert len(sp) == 6


def test_constraints_prune():
    sp = TuningSpace(
        [TuningParameter("a", (1, 2, 4)), TuningParameter("b", (1, 2, 4))],
        constraints=[lambda c: c["a"] * c["b"] <= 4],
    )
    assert all(c["a"] * c["b"] <= 4 for c in sp)
    assert len(sp) == 6


def test_empty_space_raises():
    with pytest.raises(ValueError):
        TuningSpace([TuningParameter("a", (1,))],
                    constraints=[lambda c: False])


def test_binary_detection():
    sp = TuningSpace([TuningParameter("a", (0, 1)),
                      TuningParameter("b", (2, 4))])
    assert [p.name for p in sp.binary_parameters] == ["a"]
    assert [p.name for p in sp.nonbinary_parameters] == ["b"]


def test_neighbours_differ_by_one():
    sp = TuningSpace([TuningParameter("a", (1, 2, 3)),
                      TuningParameter("b", (0, 1))])
    for nb in sp.neighbours(0):
        diff = sum(1 for k in sp[0] if sp[0][k] != sp[nb][k])
        assert diff == 1


def test_index_roundtrip():
    sp = TuningSpace([TuningParameter("a", (1, 2, 3)),
                      TuningParameter("b", ("x", "y"))])
    for i, cfg in enumerate(sp):
        assert sp.index_of(cfg) == i


def test_subspace_key():
    sp = TuningSpace([TuningParameter("bin", (0, 1)),
                      TuningParameter("v", (1, 2))])
    keys = {sp.subspace_key(c) for c in sp}
    assert keys == {(0,), (1,)}


@given(st.integers(1, 4), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_property_size_product(na, nb):
    sp = TuningSpace([
        TuningParameter("a", tuple(range(na))),
        TuningParameter("b", tuple(range(10, 10 + nb))),
    ])
    assert len(sp) == na * nb
    # vectorize is total and numeric
    for cfg in sp:
        v = sp.vectorize(cfg)
        assert len(v) == 2
        assert all(isinstance(x, float) for x in v)


def test_powers_of_two():
    assert powers_of_two(8, 64) == (8, 16, 32, 64)


def test_step_space_well_formed():
    """The distributed-step tuning space (core/step_tuner.py)."""
    from repro.core.step_tuner import make_step_space
    sp = make_step_space()
    assert len(sp) == 4 * 2 * 4 * 4 * 2
    names = {p.name for p in sp.parameters}
    assert {"MICROBATCHES", "REMAT", "LOSS_CHUNKS", "KV_CHUNK",
            "FSDP"} == names
    # FSDP is the only binary parameter -> 2 model subspaces
    assert len(sp.binary_parameters) == 1
