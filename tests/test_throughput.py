"""Hot-path throughput overhaul: group commit, off-loop training, delta saves.

The acceptance surface: the journal's ``batch`` mode never acks before
durability (an ack returned to any thread implies its records survive a
crash right now), group commit actually groups (commits ≪ appends under
concurrency), a crashing trainer thread cannot take the fleet down, and
the store's delta/no-op save fast paths write byte-equivalent corpora
while skipping the work they claim to skip.
"""
import json
import os
import socket as socketlib
import threading
import time

import pytest

from repro.fleet import (FleetTuner, ThreadWorkerPool, VirtualWorkerPool,
                         job_from_registry)
from repro.service import (RequestJournal, ShardedConfigStore, TuningDaemon,
                           validate_request)
from repro.service import protocol as P
from repro.service.journal import (EV_SUBMIT, MODE_ALWAYS, MODE_BATCH,
                                   MODE_OFF, MODES, replay)
from repro.tuning import ConfigStore

HW = "tpu_v4"


# =============================================================================
# Journal modes: construction, validation, back-compat
# =============================================================================
def test_journal_mode_validation(tmp_path):
    with pytest.raises(ValueError):
        RequestJournal(str(tmp_path / "j.jsonl"), mode="sometimes")


def test_journal_fsync_flag_backcompat(tmp_path):
    with RequestJournal(str(tmp_path / "a.jsonl"), fsync=True) as j:
        assert j.mode == MODE_ALWAYS and j.fsync
    with RequestJournal(str(tmp_path / "b.jsonl"), fsync=False) as j:
        assert j.mode == MODE_OFF and not j.fsync
    with RequestJournal(str(tmp_path / "c.jsonl"), mode=MODE_BATCH) as j:
        assert j.fsync     # batch IS durable; back-compat readers see True


def test_journal_stats_expose_mode_and_commits(tmp_path):
    with RequestJournal(str(tmp_path / "j.jsonl"), mode=MODE_BATCH) as j:
        j.append(EV_SUBMIT, rid="r1", key="k")
        st = j.stats()
        assert st["mode"] == MODE_BATCH
        assert st["commits"] >= 1
        assert st["pending"] == 0
        assert st["max_batch"] >= 1


# =============================================================================
# Group commit: ack-after-fsync ordering under a concurrent storm
# =============================================================================
def test_batch_append_returns_only_after_durable(tmp_path):
    """Every append(wait=True) that returns implies the record's seq is
    covered by a completed fsync — checked from 16 racing threads."""
    path = str(tmp_path / "j.jsonl")
    violations = []
    with RequestJournal(path, mode=MODE_BATCH) as j:

        def writer(t):
            for n in range(25):
                rec = j.append(EV_SUBMIT, rid=f"t{t}n{n}", key="k")
                if j.durable_upto() < rec["seq"]:
                    violations.append((t, n, rec["seq"]))

        threads = [threading.Thread(target=writer, args=(t,))
                   for t in range(16)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not violations
        assert j.appends == 16 * 25
        # group commit did group: far fewer fsyncs than records
        assert j.stats()["commits"] < j.appends
    events, stats = replay(path)
    assert stats.events == 16 * 25 and stats.corrupt == 0


def test_batch_ticket_wait_durable(tmp_path):
    with RequestJournal(str(tmp_path / "j.jsonl"), mode=MODE_BATCH) as j:
        rec = j.append(EV_SUBMIT, wait=False, rid="r1", key="k")
        gate = j.ticket()
        assert gate >= rec["seq"]
        j.wait_durable(gate)
        assert j.durable_upto() >= gate


def test_kick_ends_quiesce_early(tmp_path):
    """With a long quiesce window, kick() forces the pending batch to
    commit now instead of waiting out the window."""
    j = RequestJournal(str(tmp_path / "j.jsonl"), mode=MODE_BATCH,
                       batch_window_s=0.3, batch_max_delay_s=2.0)
    try:
        rec = j.append(EV_SUBMIT, wait=False, rid="r1", key="k")
        t0 = time.monotonic()
        j.kick()
        j.wait_durable(rec["seq"])
        assert time.monotonic() - t0 < 0.25   # far below the 0.3s window
    finally:
        j.close()


def test_batch_mode_survives_closed_without_loss(tmp_path):
    path = str(tmp_path / "j.jsonl")
    with RequestJournal(path, mode=MODE_BATCH) as j:
        for n in range(10):
            j.append(EV_SUBMIT, wait=False, rid=f"r{n}", key="k")
    events, stats = replay(path)
    assert stats.events == 10 and stats.corrupt == 0 and stats.torn == 0


def _storm_daemon(tmp_path, mode):
    store = ShardedConfigStore(str(tmp_path / "corpus"), n_shards=2)
    job = job_from_registry("matmul", "2048", HW)
    store.put(job.space.name, job.bucket, job.hardware_key,
              config=dict(job.space[0]), runtime=1.0, trials=8)
    store.save()
    jpath = str(tmp_path / "journal.jsonl")
    d = TuningDaemon(VirtualWorkerPool(workers=2), store,
                     default_trial_budget=4,
                     journal=RequestJournal(jpath, mode=mode))
    d.start()
    return d, jpath


@pytest.mark.parametrize("mode", [MODE_ALWAYS, MODE_BATCH])
def test_daemon_acked_submits_are_on_disk(tmp_path, mode):
    """Socket storm: every acked store-first submit has its submit+done
    records replayable from disk the moment the ack arrives — checked
    while the daemon is still running (no clean-shutdown flush excuse)."""
    d, jpath = _storm_daemon(tmp_path, mode)
    acked = []
    errors = []

    def client(t):
        try:
            with socketlib.create_connection(d.address, timeout=30) as s:
                f = s.makefile("rb")
                for n in range(10):
                    s.sendall(P.encode(dict(
                        op="submit", kind="kernel", tenant=f"t{t}",
                        kernel="matmul", input="2048", hardware=HW,
                        budget=4, seed=7)))
                    r = json.loads(f.readline())
                    assert r["ok"] and r["state"] == "done"
                    acked.append(r["request_id"])
        except Exception as e:              # surface into the test thread
            errors.append(repr(e))

    threads = [threading.Thread(target=client, args=(t,)) for t in range(6)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    try:
        assert not errors
        events, stats = replay(jpath)       # daemon still live
        assert stats.corrupt == 0
        on_disk = {}
        for e in events:
            if e.get("rid"):
                on_disk.setdefault(e["rid"], set()).add(e["ev"])
        for rid in acked:
            assert "submit" in on_disk.get(rid, set()), rid
            assert "done" in on_disk.get(rid, set()), rid
    finally:
        d.shutdown(drain=False)
        assert d.wait(timeout=60)


def test_daemon_health_reports_journal_mode(tmp_path):
    d, _ = _storm_daemon(tmp_path, MODE_BATCH)
    try:
        with socketlib.create_connection(d.address, timeout=30) as s:
            f = s.makefile("rb")
            s.sendall(P.encode({"op": "stats"}))
            r = json.loads(f.readline())
            assert r["ok"]
            assert r["journal"]["mode"] == MODE_BATCH
            assert "commits" in r["journal"]
            assert r["store_saves"]["saves"] >= 0
    finally:
        d.shutdown(drain=False)
        assert d.wait(timeout=60)


# =============================================================================
# Off-loop training: crash containment, thread hygiene, determinism
# =============================================================================
def _fleet_jobs(seed=3):
    jobs = []
    for k, inp, hw in (("matmul", "2048", "tpu_v4"),
                       ("transpose", "8192", "tpu_v5e")):
        job = job_from_registry(k, inp, hw, budget=6, seed=seed,
                                searcher="random")

        def eval_fn(index, profile, _n=len(job.space)):
            return 1.0 + (index % _n) / _n, None, 1e-4

        job.eval_fn = eval_fn
        jobs.append(job)
    return jobs


def test_trainer_crash_is_contained(tmp_path, monkeypatch):
    """A training closure that raises must not kill the fleet: the run
    completes, results are intact, and the error is recorded."""
    from repro.tuning.session import TuningSession

    def boom(self, *a, **kw):
        raise RuntimeError("trainer crashed")

    monkeypatch.setattr(TuningSession, "train", boom)
    store = ShardedConfigStore(str(tmp_path / "c"), n_shards=2)
    pool = ThreadWorkerPool(workers=2)
    try:
        tuner = FleetTuner(_fleet_jobs(), pool, store=store,
                           in_flight=2, train_async=True)
        rep = tuner.run()
    finally:
        pool.close()
    assert len(rep.results) == 2
    assert all(r.best_index is not None for r in rep.results)
    assert any("train" in msg for _, msg in tuner.train_errors)
    assert sum(1 for _ in store.model_keys()) == 0


def test_trainer_thread_does_not_leak(tmp_path):
    """finish() joins the trainer thread — repeated fleets must not
    accumulate background threads."""
    store = ShardedConfigStore(str(tmp_path / "c"), n_shards=2)
    pool = ThreadWorkerPool(workers=2)
    try:
        FleetTuner(_fleet_jobs(), pool, store=store, in_flight=2,
                   train_async=True).run()
        before = threading.active_count()
        for i in range(3):
            t = FleetTuner(_fleet_jobs(seed=4 + i), pool, store=store,
                           in_flight=2, train_async=True)
            t.run()
            assert t._trainer is None
        assert threading.active_count() <= before
    finally:
        pool.close()


def test_async_training_matches_sync_results(tmp_path):
    outcomes = {}
    for train_async in (False, True):
        store = ShardedConfigStore(
            str(tmp_path / f"c{int(train_async)}"), n_shards=2)
        pool = ThreadWorkerPool(workers=2)
        try:
            rep = FleetTuner(_fleet_jobs(), pool, store=store,
                             in_flight=2, train_async=train_async).run()
        finally:
            pool.close()
        outcomes[train_async] = sorted(
            (r.job, r.trials, round(r.best_runtime, 12))
            for r in rep.results)
        assert sum(1 for _ in store.model_keys()) == 2
    assert outcomes[False] == outcomes[True]


# =============================================================================
# Delta store saves: equivalence, clean no-op, counters
# =============================================================================
def _populate(store, n=40):
    for i in range(n):
        store.put(f"sp{i % 4}", f"b{i}", HW,
                  config={"BM": 64, "i": i}, runtime=1.0 + i, trials=4)


def test_clean_save_is_a_noop(tmp_path):
    """Regression: a save with nothing dirty must not rewrite the file."""
    path = str(tmp_path / "s.json")
    store = ConfigStore(path)
    store.autosave = False
    _populate(store)
    store.save()
    st0 = os.stat(path)
    before = store.save_stats["noop"]
    store.save()
    store.save()
    st1 = os.stat(path)
    assert store.save_stats["noop"] == before + 2
    assert (st0.st_mtime_ns, st0.st_size) == (st1.st_mtime_ns, st1.st_size)


def test_dirty_save_roundtrips_equivalent(tmp_path):
    """Delta saves produce the same on-disk corpus as a forced full
    save — byte-for-byte entry equivalence after reload."""
    path = str(tmp_path / "s.json")
    store = ConfigStore(path)
    store.autosave = False
    _populate(store)
    store.save()
    store.put("sp0", "b0", HW, config={"BM": 128, "i": -1},
              runtime=0.25, trials=9)
    store.put("sp1", "bNEW", HW, config={"BM": 32}, runtime=2.5, trials=1)
    merged0 = store.save_stats["merged_reads"]
    store.save()                          # own-write fast path: no read-back
    assert store.save_stats["merged_reads"] == merged0
    via_delta = ConfigStore(path).to_dict()["entries"]

    store.save(force=True)                # full rewrite of the same state
    via_full = ConfigStore(path).to_dict()["entries"]
    assert via_delta == via_full
    assert ConfigStore(path).get("sp0", "b0", HW).runtime == 0.25


def test_put_applies_merge_rule_in_memory(tmp_path):
    """The own-write save fast path serializes memory without re-reading
    the file, so memory must never regress below what was persisted: a
    put with a worse runtime or a lower model revision loses at put time
    (the same resolution _merge_from applies between files)."""
    store = ConfigStore(str(tmp_path / "s.json"))
    store.autosave = False
    store.put("sp", "b", HW, config={"BM": 64}, runtime=1.0, trials=4)
    kept = store.put("sp", "b", HW, config={"BM": 8}, runtime=5.0, trials=1)
    assert kept.runtime == 1.0 and kept.config == {"BM": 64}
    # equal runtime: the fresh put wins (merge keeps "ours" on ties, and
    # at put time ours is the incoming value)
    store.put("sp", "b", HW, config={"BM": 32}, runtime=1.0, trials=9)
    assert store.get("sp", "b", HW).config == {"BM": 32}

    store.put_model_dict("sp", "b", HW, {"tag": "new"}, revision=7)
    store.put_model_dict("sp", "b", HW, {"tag": "stale"}, revision=3)
    assert store.get_model_dict("sp", "b", HW)["tag"] == "new"
    store.put_model_dict("sp", "b", HW, {"tag": "newer"})   # auto: rev 8
    assert store.get_model_dict("sp", "b", HW)["revision"] == 8


def test_delta_save_skips_readback_but_merges_foreign_writes(tmp_path):
    """Our own last write ⇒ no read-back; a foreign write to the same
    file must still be merged, not clobbered."""
    path = str(tmp_path / "s.json")
    store = ConfigStore(path)
    store.autosave = False
    _populate(store, n=8)
    store.save()
    merged0 = store.save_stats["merged_reads"]
    store.put("sp0", "b0", HW, config={"BM": 256}, runtime=0.5, trials=2)
    store.save()
    assert store.save_stats["merged_reads"] == merged0   # own write: no read

    other = ConfigStore(path)             # second writer, same file
    other.autosave = False
    other.put("spX", "bX", HW, config={"BM": 8}, runtime=9.0, trials=1)
    other.save()

    store.put("sp1", "b1", HW, config={"BM": 512}, runtime=0.75, trials=2)
    store.save()                          # stat token mismatch → merge
    assert store.save_stats["merged_reads"] == merged0 + 1
    assert store.save_stats["delta"] >= 1  # overlay write, not full dump
    reread = ConfigStore(path)
    assert reread.get("spX", "bX", HW).runtime == 9.0
    assert reread.get("sp1", "b1", HW).runtime == 0.75


# =============================================================================
# Launch CLI: --fsync plumbs through, rejects unknown modes
# =============================================================================
def test_launch_fsync_choices():
    import argparse

    from repro.launch.daemon import main
    with pytest.raises(SystemExit) as ei:
        main(["--backend", "virtual", "--fsync", "sometimes",
              "--port", "0"])
    assert ei.value.code == 2             # argparse rejects the choice
    assert "sometimes" not in MODES
    assert isinstance(argparse.ArgumentParser, type)


def test_daemon_accepts_journal_instance(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    d = TuningDaemon(VirtualWorkerPool(workers=2), ConfigStore(),
                     default_trial_budget=4,
                     journal=RequestJournal(jpath, mode=MODE_OFF))
    d.tuner.begin()
    r = d.handle(validate_request(dict(
        op="submit", kind="kernel", tenant="t", kernel="matmul",
        input="2048", hardware=HW, budget=4, seed=7, wait=False)))
    assert r["ok"]
    d.journal.close()
    events, _ = replay(jpath)
    assert any(e["ev"] == "submit" for e in events)
