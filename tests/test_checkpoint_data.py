"""Checkpointing (atomic, keep-k, elastic) and data-pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import SMOKES
from repro.data.pipeline import DataConfig, make_batch
from repro.models.registry import build_model
from repro.optim.adamw import AdamW, constant_lr
from repro.train.train_step import init_train_state


@pytest.fixture
def state():
    model = build_model(SMOKES["xlstm-125m"])
    opt = AdamW(lr=constant_lr(1e-3))
    return init_train_state(model, opt, jax.random.PRNGKey(0))


def _trees_equal(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def test_save_restore_roundtrip(tmp_path, state):
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(7, state)
    step, restored = ck.restore_latest(state)
    assert step == 7
    assert _trees_equal(state, restored)


def test_async_save(tmp_path, state):
    ck = Checkpointer(str(tmp_path), async_write=True)
    ck.save(3, state)
    ck.wait()
    assert ck.latest_step() == 3


def test_keep_k_gc(tmp_path, state):
    ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.all_steps() == [3, 4]


def test_no_partial_checkpoints_visible(tmp_path, state):
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(1, state)
    # a .tmp directory must never be listed as a restorable step
    os.makedirs(os.path.join(str(tmp_path), "step_0000000099.tmp"))
    assert ck.latest_step() == 1


def test_elastic_restore_onto_devices(tmp_path, state):
    """Checkpoints are mesh-agnostic: restore with explicit shardings."""
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(5, state)
    dev = jax.devices()[0]
    sharding = jax.sharding.SingleDeviceSharding(dev)
    shardings = jax.tree.map(lambda _: sharding, state)
    step, restored = ck.restore_latest(state, shardings)
    assert step == 5
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding == sharding


# --- data pipeline ------------------------------------------------------------
def test_data_determinism():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=9)
    a = make_batch(cfg, 17)
    b = make_batch(cfg, 17)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, 18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=2)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == (2, 64)
    assert b["labels"].shape == (2, 64)


def test_data_resume_equivalence():
    """Restarting at step k yields the same stream as never failing."""
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=2, seed=3)
    run1 = [make_batch(cfg, s)["tokens"] for s in range(6)]
    run2 = [make_batch(cfg, s)["tokens"] for s in range(3, 6)]
    for a, b in zip(run1[3:], run2):
        assert np.array_equal(a, b)


def test_data_in_vocab_range():
    cfg = DataConfig(vocab_size=100, seq_len=256, global_batch=2)
    b = make_batch(cfg, 0)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 100
