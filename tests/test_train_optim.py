"""Training substrate: optimizer, microbatching, compression, loss descent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.data.pipeline import DataConfig, make_batch
from repro.distributed.compression import (cross_pod_int8_psum,
                                           quantize_dequantize_tree)
from repro.models.registry import build_model
from repro.optim.adamw import (AdamW, apply_updates, clip_by_global_norm,
                               constant_lr, global_norm, warmup_cosine)
from repro.train.train_step import StepConfig, init_train_state, make_train_step


def test_adamw_descends_quadratic():
    opt = AdamW(lr=constant_lr(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert abs(float(params["w"])) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) == pytest.approx(20.0)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1e-3, rel=0.1)
    assert float(sched(jnp.asarray(100))) < 2e-4


def test_loss_decreases_short_training():
    cfg = SMOKES["qwen1.5-0.5b"]
    model = build_model(cfg)
    opt = AdamW(lr=constant_lr(3e-3))
    step = jax.jit(make_train_step(model, opt,
                                   StepConfig(remat="none")))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
    losses = []
    for i in range(15):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_microbatch_accumulation_matches_full_batch():
    cfg = SMOKES["qwen1.5-0.5b"]
    model = build_model(cfg)
    opt = AdamW(lr=constant_lr(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
    s1 = jax.jit(make_train_step(model, opt, StepConfig(remat="none",
                                                        microbatches=1)))
    s2 = jax.jit(make_train_step(model, opt, StepConfig(remat="none",
                                                        microbatches=2)))
    _, m1 = s1(state, batch)
    _, m2 = s2(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]),
                                                   rel=2e-2)


def test_quantize_dequantize_small_error():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    out = quantize_dequantize_tree(g)
    err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"])))
    assert err <= scale / 127.0 + 1e-6


def test_compressed_train_step_runs():
    cfg = SMOKES["qwen1.5-0.5b"]
    model = build_model(cfg)
    opt = AdamW(lr=constant_lr(1e-3))
    step = jax.jit(make_train_step(
        model, opt, StepConfig(remat="none", compress_cross_pod=True)))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=2)
    batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
    _, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
