"""Fault-tolerant fleet scheduling (ISSUE 5).

Covers the acceptance surface: with retry/timeout machinery ENABLED but
zero injected failures, the fleet at one worker / ``in_flight=1`` replays
the frozen sequential driver bit-for-bit for every registered searcher;
under deterministic fault injection on ``VirtualWorkerPool`` (targeted
test failures, lane kills, stragglers) failed tests are retried on other
lanes with bounded attempts, twice-failing configs are marked known-bad,
abandoned worker-seconds are charged into ``busy``; the gain-priority
scheduler parks jobs already inside the well-performing band and unparks
them when a freshly published model shows more remaining gain; elastic
``in_flight`` stays within its bounds; the subprocess pool drains buffered
results before surfacing lane/fleet death as data; the store supersedes
model artifacts by revision on merge and GCs with ``prune``.
"""
import numpy as np
import pytest

from repro.core import SPECS, ReplayEvaluator, record_space, train_model
from repro.core.account import EvalAccount, Observation
from repro.core.evaluate import ElasticInFlight, VirtualAsyncEvaluator
from repro.core.searcher import (SEARCHERS, make_searcher, run_search,
                                 sequential_run_search)
from repro.fleet import (FAIL_LANE, FAIL_POOL, FAIL_TEST, FailedResult,
                         FleetTuner, TuningJob, VirtualWorkerPool, WorkItem,
                         job_from_registry)
from repro.serve.autotune import (ServeWorkloadStats, serve_space,
                                  serve_workload_fn)
from repro.tuning import ConfigStore

HW = SPECS["tpu_v5e"]
STATS = ServeWorkloadStats()


@pytest.fixture(scope="module")
def gemm():
    from repro.kernels.registry import BENCHMARKS

    bm = BENCHMARKS["matmul"]
    sp = bm.make_space()
    return record_space(sp, lambda c: bm.workload_fn(c, bm.inputs["128"]),
                        HW)


class RecordingPool(VirtualWorkerPool):
    """Virtual pool that records every submitted WorkItem and the peak
    number of concurrently outstanding tests."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.items = []
        self.max_out = 0

    def submit(self, item):
        self.items.append(item)
        super().submit(item)
        self.max_out = max(self.max_out, self.outstanding())


# =============================================================================
# Golden: retry machinery enabled, zero failures => bit-identical traces
# =============================================================================
@pytest.mark.parametrize("name", sorted(SEARCHERS))
def test_retry_enabled_zero_failures_bit_identical(name, gemm):
    """Failure handling must cost nothing when nothing fails: the fleet at
    1 worker / in_flight=1 with retries+straggler policy on replays the
    frozen sequential driver bit-for-bit, for every registered searcher."""
    model = train_model(gemm, kind="exact")
    space = gemm.space
    store = ConfigStore()
    store.save_model(space.name, "128", "tpu_v5e", model, space)
    job = job_from_registry("matmul", "128", "tpu_v5e", budget=40, seed=3,
                            searcher=name)
    rep = FleetTuner([job], VirtualWorkerPool(workers=1), store=store,
                     in_flight=1, publish_models=False,
                     retries=2, straggler_factor=50.0).run()
    s = make_searcher(name, space, seed=3, model=model, cores=HW.cores)
    ev = ReplayEvaluator(gemm)
    sequential_run_search(s, ev, 40)
    r = rep.results[0]
    assert r.trace == ev.trace                 # bit-identical, full trace
    assert r.history == ev.history()
    assert r.failures == 0 and r.abandoned_s == 0.0
    assert r.known_bad == [] and not r.parked


# =============================================================================
# Retry / known-bad on deterministic fault injection
# =============================================================================
def test_failed_test_retries_on_another_lane():
    """First attempt of the first test fails; the retry goes out excluding
    the failed lane and lands, so the job still resolves its full budget
    with every runtime measured — and the wasted attempt is charged."""
    pool = RecordingPool(
        workers=2,
        fail_fn=lambda item: "boom" if item.uid == 0 else None)
    job = job_from_registry("matmul", "128", "tpu_v4", budget=6, seed=0,
                            searcher="random")
    rep = FleetTuner([job], pool, store=None, publish_models=False,
                     retries=2).run()
    r = rep.results[0]
    assert r.trials == 6 and len(r.history) == 6
    assert all(np.isfinite(rt) for _, rt in r.history)
    assert r.failures == 1 and r.known_bad == []
    assert r.abandoned_s > 0.0 and rep.abandoned == r.abandoned_s
    assert r.busy > rep.elapsed * 0  # busy includes the abandoned attempt
    retry = [it for it in pool.items if it.attempt == 1]
    assert len(retry) == 1
    assert retry[0].index == pool.items[0].index
    assert retry[0].exclude == (0,)            # exclude-and-resubmit
    assert rep.max_retries_used == 1


def test_config_failing_twice_is_marked_known_bad():
    """A config whose measurement fails twice stops being retried: it is
    marked known-bad and resolves as an inf row in trace/history, so the
    budget still terminates and nothing is silently dropped."""
    bad = {}

    def fail_fn(item):
        bad.setdefault("index", item.index)
        return "boom" if item.index == bad["index"] else None

    pool = VirtualWorkerPool(workers=2, fail_fn=fail_fn)
    job = job_from_registry("matmul", "128", "tpu_v4", budget=6, seed=0,
                            searcher="random")
    rep = FleetTuner([job], pool, store=None, publish_models=False,
                     retries=2, known_bad_after=2).run()
    r = rep.results[0]
    assert r.known_bad == [bad["index"]]
    assert r.failures == 2                     # original + exactly 1 retry
    assert rep.max_retries_used == 1           # "at most twice" holds
    assert r.trials == 6 and len(r.history) == 6
    inf_rows = [(i, rt) for i, rt in r.history if not np.isfinite(rt)]
    assert inf_rows == [(bad["index"], float("inf"))]
    assert r.best_index is not None and np.isfinite(r.best_runtime)
    assert rep.known_bad == 1


def test_retry_budget_exhaustion_is_not_known_bad():
    """known-bad is reserved for configs whose own measurement failed
    known_bad_after times: exhausting a smaller retry budget on a single
    transient failure resolves the test unmeasured without condemning
    the config."""
    pool = VirtualWorkerPool(
        workers=2,
        fail_fn=lambda item: "boom" if item.uid == 0 else None)
    job = job_from_registry("matmul", "128", "tpu_v4", budget=4, seed=0,
                            searcher="random")
    rep = FleetTuner([job], pool, store=None, publish_models=False,
                     retries=0, known_bad_after=2).run()
    r = rep.results[0]
    assert r.failures == 1 and r.trials == 4
    assert r.known_bad == [] and rep.known_bad == 0
    assert sum(1 for _, rt in r.history if not np.isfinite(rt)) == 1


def test_lane_kill_mid_run_recovers():
    """Kill 1 of 2 lanes mid-run: in-flight tests on it fail as kind
    'lane' (not counted against their configs) and are retried on the
    survivor; every job completes with finite measurements."""
    def jobs():
        return [job_from_registry("matmul", "128", hw, budget=12, seed=1,
                                  searcher="random")
                for hw in ("tpu_v4", "tpu_v5e")]

    base = FleetTuner(jobs(), VirtualWorkerPool(workers=2), store=None,
                      publish_models=False).run()
    pool = VirtualWorkerPool(workers=2,
                             kill_lane_at={1: base.elapsed * 0.3})
    rep = FleetTuner(jobs(), pool, store=None, publish_models=False,
                     retries=2).run()
    for r in rep.results:
        assert r.trials == 12 and len(r.history) == 12
        assert all(np.isfinite(rt) for _, rt in r.history)
        assert r.known_bad == []               # lane faults aren't configs
    assert rep.failures >= 1
    assert pool.alive_workers() == 1


def test_fleet_survives_total_pool_death():
    """Every lane dead: tests resolve as unmeasured (inf) rows instead of
    raising, and the job reports best_index=None with a full trace."""
    pool = VirtualWorkerPool(workers=1, kill_lane_at={0: 0.0})
    job = job_from_registry("matmul", "128", "tpu_v4", budget=4, seed=0,
                            searcher="random")
    rep = FleetTuner([job], pool, store=None, publish_models=False,
                     retries=1).run()
    r = rep.results[0]
    assert r.best_index is None and r.best_runtime == float("inf")
    assert r.best_config == {}
    assert r.trials == 4
    assert all(not np.isfinite(rt) for _, rt in r.history)


def test_straggler_timeout_resubmits_and_charges():
    """A test running way past the job's rolling cost estimate is timed
    out and resubmitted on another lane; its late result is dropped but
    the burned lane-seconds are charged as abandoned work."""
    slow = {}

    def cost_scale(item):
        slow.setdefault("uid", item.uid)
        return 200.0 if item.uid == slow["uid"] else 1.0

    pool = VirtualWorkerPool(workers=2, cost_scale=cost_scale)
    job = job_from_registry("matmul", "128", "tpu_v4", budget=16, seed=2,
                            searcher="random")
    rep = FleetTuner([job], pool, store=None, publish_models=False,
                     retries=2, straggler_factor=3.0).run()
    r = rep.results[0]
    assert rep.timeouts == 1
    assert r.trials == 16 and len(r.history) == 16
    assert all(np.isfinite(rt) for _, rt in r.history)
    # the straggler burned ~200x a normal test on its lane; that cost is
    # real and must appear in busy via record_abandoned
    assert r.abandoned_s > 10 * (r.busy - r.abandoned_s) / 16
    assert r.busy > r.abandoned_s > 0.0


def test_record_abandoned_accounts_busy_not_steps():
    acct = EvalAccount()
    acct.record_completion(1, 1.0, cost=2.0, finished_at=2.0)
    acct.record_abandoned(3.0)
    assert acct.busy == 5.0
    assert acct.abandoned == 3.0 and acct.abandoned_count == 1
    assert acct.steps == 1 and len(acct.trace) == 1
    assert acct.best_index == 1


# =============================================================================
# Gain-priority dispatch: prefer gain, park inside the band, unpark
# =============================================================================
def _serve_job(name, hw, bucket="p4n3", budget=12, seed=5, searcher=None):
    return TuningJob(name=name, space=serve_space(),
                     workload_fn=serve_workload_fn(16, 40, 12, STATS),
                     hardware=hw, bucket=bucket, budget=budget, seed=seed,
                     searcher=searcher)


def _seed_store(store, bucket, hw_key):
    space = serve_space()
    rec = record_space(space, serve_workload_fn(16, 40, 12, STATS),
                       SPECS["tpu_v4"])
    store.save_model(space.name, bucket, hw_key,
                     train_model(rec, kind="exact"), space)


def test_priority_prefers_higher_remaining_gain(monkeypatch):
    """Two model-backed jobs: the one whose prediction says convergence is
    still buying latency gets the lanes; the zero-gain job waits, so the
    high-gain job finishes its budget first."""
    def fake_pred(model, space, hw):
        # job A (tpu_v4): predicted best ~0 => remaining gain ~ its best
        # job B (tpu_v5e): predicted best huge => remaining gain clamps to 0
        val = 1e-9 if hw.name == "tpu_v4" else 1e6
        return np.full(len(space), val)

    monkeypatch.setattr("repro.fleet.tuner.predicted_runtimes", fake_pred)
    store = ConfigStore()
    _seed_store(store, "p4n3", "tpu_v4")
    jobs = [_serve_job("A", "tpu_v4", budget=10, searcher="random"),
            _serve_job("B", "tpu_v5e", budget=10, searcher="random")]
    pool = RecordingPool(workers=2)
    rep = FleetTuner(jobs, pool, store=store, in_flight=2,
                     publish_models=False).run()
    by = rep.by_job()
    assert by["A"].trials == 10 and by["B"].trials == 10
    assert by["A"].elapsed < by["B"].elapsed   # A monopolized the lanes
    assert pool.items[-1].job == "B"           # B's tail ran last


def test_warm_job_inside_band_is_parked(monkeypatch):
    """A warm-started job whose first measurement already sits within
    park_factor of its predicted best stops consuming budget."""
    monkeypatch.setattr("repro.fleet.tuner.predicted_runtimes",
                        lambda m, s, hw: np.full(len(s), 1e6))
    store = ConfigStore()
    _seed_store(store, "p4n3", "tpu_v4")
    job = _serve_job("warm", "tpu_v4", budget=20)
    rep = FleetTuner([job], VirtualWorkerPool(workers=2), store=store,
                     publish_models=False, park_factor=1.1).run()
    r = rep.results[0]
    assert r.warm_started and r.parked
    assert 0 < r.trials < 20                   # budget saved, not spent
    assert rep.parked == 1


def test_parked_job_unparks_on_better_model_publish(monkeypatch):
    """A job parked on a stale artifact's pessimistic prediction resumes
    when a model published later in the run shows more remaining gain."""
    calls = {"v5e": 0}

    def fake_pred(model, space, hw):
        if hw.name == "tpu_v5e":               # job B
            calls["v5e"] += 1
            # stale artifact at _start: pessimistic => B parks instantly;
            # re-priced after A publishes: optimistic => B must unpark
            return np.full(len(space),
                           1e6 if calls["v5e"] == 1 else 1e-9)
        return np.full(len(space), 1e-9)       # job A: never parks

    monkeypatch.setattr("repro.fleet.tuner.predicted_runtimes", fake_pred)
    store = ConfigStore()
    _seed_store(store, "b", "tpu_v5e")         # B's warm-start artifact
    jobs = [_serve_job("A", "tpu_v4", bucket="a", budget=6,
                       searcher="random"),
            _serve_job("B", "tpu_v5e", bucket="b", budget=10)]
    rep = FleetTuner(jobs, VirtualWorkerPool(workers=2), store=store,
                     publish_models=True, park_factor=1.1).run()
    by = rep.by_job()
    assert by["B"].warm_started and by["B"].parked     # it WAS parked...
    assert by["B"].trials == 10                # ...but resumed to budget
    assert calls["v5e"] >= 2                   # re-priced after publish
    # A's completion published the model B re-priced against
    assert store.get_model_dict(serve_space().name, "a", "tpu_v4") \
        is not None


# =============================================================================
# Elastic in_flight
# =============================================================================
def test_elastic_controller_bounds():
    c = ElasticInFlight(lo=2, hi=8)
    assert c.target(4) == 4                    # no samples: lane count
    for _ in range(8):
        c.observe(0.01)
    assert c.target(4) == 4                    # zero variance: no queue
    v = ElasticInFlight(lo=2, hi=8)
    for d in (0.01, 1.0) * 6:
        v.observe(d)
    assert 4 < v.target(4) <= 8                # variance deepens the queue
    assert ElasticInFlight(lo=1, hi=1).target(4) == 1     # clamped
    assert ElasticInFlight(lo=6, hi=9).target(2) == 6     # floor
    with pytest.raises(ValueError):
        ElasticInFlight(lo=0, hi=4)
    with pytest.raises(ValueError):
        ElasticInFlight(lo=4, hi=2)
    c.observe(float("inf"))                    # ignored, no poisoning
    c.observe(-1.0)
    assert c.target(4) == 4


def test_run_search_elastic_respects_budget(gemm):
    ev = VirtualAsyncEvaluator(ReplayEvaluator(gemm), workers=4)
    s = make_searcher("random", gemm.space, seed=2)
    run_search(s, ev, 30, in_flight=2, in_flight_max=6)
    assert ev.steps == 30
    assert ev.outstanding() == 0


def test_run_search_elastic_pinned_matches_sequential(gemm):
    """lo == hi == 1 degenerates to the fixed driver: still golden."""
    s_seq = make_searcher("random", gemm.space, seed=7)
    s_el = make_searcher("random", gemm.space, seed=7)
    ev_seq, ev_el = ReplayEvaluator(gemm), ReplayEvaluator(gemm)
    sequential_run_search(s_seq, ev_seq, 25)
    run_search(s_el, ev_el, 25, in_flight=1, in_flight_max=1)
    assert ev_el.trace == ev_seq.trace


def test_run_search_rejects_bad_elastic_bounds(gemm):
    s = make_searcher("random", gemm.space, seed=0)
    with pytest.raises(ValueError):
        run_search(s, ReplayEvaluator(gemm), 10, in_flight=4,
                   in_flight_max=2)


def test_fleet_elastic_in_flight_stays_within_bounds():
    """High-variance measurement costs grow the fleet's outstanding work
    above the lane count but never past in_flight_max; a fixed window
    never exceeds in_flight."""
    def eval_fn(index, profile):
        cost = 0.5 if index % 2 else 0.001
        return 0.001 * (index + 1), None, cost

    def job():
        return TuningJob(name="j", space=serve_space(), workload_fn=None,
                         hardware="tpu_v4", budget=24, seed=3,
                         searcher="random", eval_fn=eval_fn)

    elastic = RecordingPool(workers=2)
    rep = FleetTuner([job()], elastic, store=None, publish_models=False,
                     in_flight=2, in_flight_max=6).run()
    assert rep.results[0].trials == 24
    assert 2 < elastic.max_out <= 6
    assert rep.in_flight_max == 6
    fixed = RecordingPool(workers=2)
    FleetTuner([job()], fixed, store=None, publish_models=False,
               in_flight=2).run()
    assert fixed.max_out <= 2
    with pytest.raises(ValueError):
        FleetTuner([job()], RecordingPool(workers=2), in_flight=4,
                   in_flight_max=2)


# =============================================================================
# Profile searchers tolerate failed (counter-less) profile tests
# =============================================================================
@pytest.mark.parametrize("name", ["profile", "profile_local"])
def test_profile_searcher_survives_failed_profile(name, gemm):
    model = train_model(gemm, kind="exact")
    s = make_searcher(name, gemm.space, seed=0, model=model,
                      cores=HW.cores)
    first = s.propose(1)
    assert first and first[0].profile
    s.observe([Observation(index=first[0].index, runtime=float("inf"),
                           counters=None)])
    nxt = s.propose(1)                         # re-anchors, doesn't crash
    assert nxt and nxt[0].profile
    assert nxt[0].index != first[0].index


# =============================================================================
# Subprocess pool: lane death surfaces as data, buffered results survive
# =============================================================================
@pytest.mark.slow
def test_subprocess_lane_death_drains_before_fleet_dead():
    """Kill 1 of 2 lanes (then both): completed results are never lost,
    lane death comes back as FailedResult(kind='lane'), and an all-dead
    fleet surfaces as per-item kind='pool' failures instead of raising
    from collect/submit (pre-fix: RuntimeError lost buffered results)."""
    from repro.fleet import SubprocessWorkerPool

    ok = {"kernel": "matmul", "input": "128", "hw": "tpu_v4"}
    pool = SubprocessWorkerPool(workers=2, devices_per_worker=0)
    try:
        pool.submit(WorkItem(uid=1, job="j", index=0, payload=dict(ok)))
        res1 = pool.collect(timeout=120)
        assert res1.uid == 1 and res1.error is None
        assert np.isfinite(res1.runtime)
        # crash the lane with a test in flight
        pool.submit(WorkItem(uid=2, job="j", index=1,
                             payload={"sim_crash": True}))
        res2 = pool.collect(timeout=120)
        assert isinstance(res2, FailedResult)
        assert res2.uid == 2 and res2.kind == FAIL_LANE
        # the surviving lane still serves work — no "all dead" raise
        pool.submit(WorkItem(uid=3, job="j", index=2, payload=dict(ok)))
        res3 = pool.collect(timeout=120)
        assert res3.uid == 3 and res3.error is None
        assert res3.runtime == res1.runtime or np.isfinite(res3.runtime)
        # injected per-test failure is kind "test", lane stays alive
        pool.submit(WorkItem(uid=4, job="j", index=3,
                             payload={"sim_fail": True}))
        res4 = pool.collect(timeout=120)
        assert res4.kind == FAIL_TEST and "InjectedFailure" in res4.error
        # kill the survivor: fleet is now dead
        pool.submit(WorkItem(uid=5, job="j", index=4,
                             payload={"sim_crash": True}))
        res5 = pool.collect(timeout=120)
        assert res5.kind == FAIL_LANE
        pool.submit(WorkItem(uid=6, job="j", index=5, payload=dict(ok)))
        res6 = pool.collect(timeout=120)
        assert isinstance(res6, FailedResult) and res6.kind == FAIL_POOL
        assert "died" in res6.error
        assert pool.alive_workers() == 0
    finally:
        pool.close()


# =============================================================================
# Store: artifact revisions supersede on merge; prune GC
# =============================================================================
def test_model_retrain_bumps_revision(gemm):
    model = train_model(gemm, kind="exact")
    store = ConfigStore()
    store.save_model(gemm.space.name, "b", "hw", model, gemm.space,
                     n_obs=10)
    assert store.get_model_dict(gemm.space.name, "b", "hw")["revision"] == 1
    store.save_model(gemm.space.name, "b", "hw", model, gemm.space,
                     n_obs=50)
    art = store.get_model_dict(gemm.space.name, "b", "hw")
    assert art["revision"] == 2 and art["n_obs"] == 50


def test_model_merge_resolves_by_revision(tmp_path, gemm):
    """Pre-fix, a model retrained on more observations tied with its stale
    ancestor (setdefault kept whichever writer saved last-but-loaded-first);
    now the higher revision supersedes on merge."""
    model = train_model(gemm, kind="exact")
    space = gemm.space
    path = str(tmp_path / "s.json")
    a = ConfigStore(path)
    a.save_model(space.name, "b", "hw", model, space, n_obs=10)   # rev 1
    b = ConfigStore(path)                      # loads rev 1
    b.save_model(space.name, "b", "hw", model, space, n_obs=50)   # rev 2
    a.save()          # a still holds rev 1: must adopt rev 2 on merge
    final = ConfigStore(path)
    art = final.get_model_dict(space.name, "b", "hw")
    assert art["revision"] == 2 and art["n_obs"] == 50
    assert a.get_model_dict(space.name, "b", "hw")["revision"] == 2


def test_store_prune_gcs_and_stays_pruned(tmp_path):
    path = str(tmp_path / "s.json")
    store = ConfigStore(path)
    for hw in ("hw1", "hw2"):
        store.put("sp", "b", hw, config={"X": 1}, runtime=1.0, trials=1)
        store.put_model_dict("sp", "b", hw, {"kind": "stub"})
    store.put("other", "b", "hw1", config={"X": 1}, runtime=1.0, trials=1)
    # dry_run reports what WOULD drop without mutating (or saving)
    preview = store.prune(keep_hardware={"hw1"}, dry_run=True)
    assert preview["dropped"] == 2
    assert preview["dropped_entries"] == 1
    assert preview["dropped_models"] == 1
    assert store.get("sp", "b", "hw2") is not None        # untouched
    stats = store.prune(keep_hardware={"hw1"})
    assert stats == preview                               # preview was honest
    assert stats["kept_entries"] == 2 and stats["kept_models"] == 1
    assert store.get("sp", "b", "hw2") is None
    assert store.get_model_dict("sp", "b", "hw2") is None
    assert store.get("sp", "b", "hw1") is not None
    # pruned keys must NOT be resurrected from the on-disk copy
    again = ConfigStore(path)
    assert again.get("sp", "b", "hw2") is None
    assert again.get_model_dict("sp", "b", "hw2") is None
    # field combinations
    assert store.prune(keep_spaces={"sp"})["dropped"] == 1   # drops "other"
    assert store.prune(keep_buckets={"b"})["dropped"] == 0   # nothing left
    assert ConfigStore(path).get("other", "b", "hw1") is None
