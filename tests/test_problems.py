"""Unified ``TuningProblem`` abstraction (ISSUE 8).

The acceptance surface: every registered kernel routed through the
``KernelProblem`` adapter produces a bit-identical ask-tell trace to the
legacy ``job_from_registry`` path; the ``ConfigStore`` speaks the
``kind|space|bucket|hardware`` key schema while still loading pre-refactor
version-1 files; sharding and serve problems expose real spaces, portable
counter workloads, and deterministic evaluators; ``parse_problem`` gives
actionable errors; and the service daemon resolves ``kind="problem"``
submits through the registry.
"""
import json

import pytest

from repro.core import SPECS
from repro.fleet import (FleetTuner, VirtualWorkerPool, job_from_problem,
                         job_from_registry)
from repro.tuning import ConfigStore
from repro.tuning.problem import (KernelProblem, list_problems, make_problem,
                                  parse_problem, problem_kinds,
                                  system_problems)
from repro.tuning.store import (VERSION, content_crc, legacy_kind, split_key,
                                store_key, upgrade_key)

HW = "tpu_v4"


def _run_single_lane(job):
    pool = VirtualWorkerPool(workers=1)
    try:
        rep = FleetTuner([job], pool, store=None, in_flight=1,
                         publish_models=False).run()
    finally:
        pool.close()
    return rep.results[0]


# =============================================================================
# Golden gate: the kernel adapter is bit-identical to the legacy path
# =============================================================================
def test_kernel_adapter_golden_every_registered_kernel():
    """job_from_problem(KernelProblem) must replay the exact legacy trace
    for EVERY registered kernel benchmark — the refactor costs nothing."""
    from repro.kernels.registry import BENCHMARKS

    for kernel in sorted(BENCHMARKS):
        for input_key in sorted(BENCHMARKS[kernel].inputs):
            legacy = job_from_registry(kernel, input_key, HW,
                                       budget=8, seed=3)
            adapter = job_from_problem(KernelProblem(kernel, input_key),
                                       HW, budget=8, seed=3,
                                       name=legacy.name)
            assert adapter.kind == "kernel"
            assert adapter.bucket == legacy.bucket
            r_legacy = _run_single_lane(legacy)
            r_adapter = _run_single_lane(adapter)
            assert r_adapter.trace == r_legacy.trace, \
                f"{kernel}/{input_key} diverged"
            assert r_adapter.history == r_legacy.history
            assert r_adapter.best_config == r_legacy.best_config


# =============================================================================
# Store key schema: v2 keys, v1 files keep loading
# =============================================================================
def test_store_key_schema_and_legacy_inference():
    assert store_key("gemm", "2048", "tpu_v4") == "kernel|gemm|2048|tpu_v4"
    assert store_key("serve_online", "p1n1", "hw") == \
        "serve|serve_online|p1n1|hw"
    assert store_key("sharding_x", "b", "hw", kind="sharding") == \
        "sharding|sharding_x|b|hw"
    # 3-part (v1) keys split with the kind inferred from the space name
    assert split_key("gemm|2048|tpu_v4") == \
        ("kernel", "gemm", "2048", "tpu_v4")
    assert split_key("serve_online|p1n1|hw") == \
        ("serve", "serve_online", "p1n1", "hw")
    assert upgrade_key("gemm|2048|tpu_v4") == "kernel|gemm|2048|tpu_v4"
    assert upgrade_key("sharding|s|b|h") == "sharding|s|b|h"  # idempotent
    assert legacy_kind("serve_online") == "serve"
    assert legacy_kind("gemm") == "kernel"
    with pytest.raises(ValueError):
        split_key("only|two")
    with pytest.raises(ValueError):
        store_key("sp|ace", "b", "hw")


def test_store_loads_pre_refactor_v1_file(tmp_path):
    """A literal version-1 store file (3-part keys, no kind fields) must
    load with keys upgraded, resolve through kind-aware gets, survive
    prune(keep_kinds=), and re-save in version-2 form."""
    entries = {
        "gemm|2048|tpu_v4": {
            "space": "gemm", "bucket": "2048", "hardware": "tpu_v4",
            "config": {"TILE": 128}, "runtime": 0.002, "trials": 9,
            "meta": {},
        },
        "serve_online|p1n1|tpu_v5e": {
            "space": "serve_online", "bucket": "p1n1",
            "hardware": "tpu_v5e",
            "config": {"BATCH": 8, "MAX_SEQ": 64},
            "runtime": 0.01, "trials": 6, "meta": {},
        },
    }
    models = {"gemm|2048|tpu_v4": {"format": "repro.tppc_model",
                                   "revision": 3}}
    path = str(tmp_path / "v1_store.json")
    with open(path, "w") as f:
        json.dump({"format": "repro.config_store", "version": 1,
                   "crc": content_crc(entries, models),
                   "entries": entries, "models": models}, f)

    store = ConfigStore(path)
    assert not store.quarantined
    assert len(store) == 2
    # upgraded keys, kind-aware resolution (explicit and inferred)
    e = store.get("gemm", "2048", "tpu_v4", kind="kernel")
    assert e is not None and e.config == {"TILE": 128}
    assert e.kind == "kernel" and e.key == "kernel|gemm|2048|tpu_v4"
    assert store.get("gemm", "2048", "tpu_v4") is e     # legacy call site
    s = store.get("serve_online", "p1n1", "tpu_v5e")
    assert s is not None and s.kind == "serve"
    assert store.get_model_dict("gemm", "2048", "tpu_v4",
                                kind="kernel")["revision"] == 3
    # a serve-kind get must NOT see the kernel entry
    assert store.get("gemm", "2048", "tpu_v4", kind="serve") is None

    stats = store.prune(keep_kinds={"kernel"})
    assert stats["dropped_entries"] == 1
    assert store.get("serve_online", "p1n1", "tpu_v5e") is None
    assert store.get("gemm", "2048", "tpu_v4") is not None

    # the autosaved file is now the current version with 4-part keys
    # throughout
    with open(path) as f:
        d = json.load(f)
    assert d["version"] == VERSION == 3
    assert set(d["entries"]) == {"kernel|gemm|2048|tpu_v4"}
    assert set(d["models"]) == {"kernel|gemm|2048|tpu_v4"}
    reopened = ConfigStore(path)
    assert reopened.get("gemm", "2048", "tpu_v4").trials == 9


def test_store_kinds_do_not_collide(tmp_path):
    """Two problems sharing a space name but differing in kind hold
    independent artifacts under the same (space, bucket, hardware)."""
    store = ConfigStore(str(tmp_path / "s.json"))
    store.put("sp", "b", "hw", config={"A": 1}, runtime=1.0, trials=1,
              kind="kernel")
    store.put("sp", "b", "hw", config={"A": 2}, runtime=2.0, trials=2,
              kind="sharding")
    assert len(store) == 2
    assert store.get("sp", "b", "hw", kind="kernel").config == {"A": 1}
    assert store.get("sp", "b", "hw", kind="sharding").config == {"A": 2}


# =============================================================================
# Registry: specs, errors, enumeration
# =============================================================================
def test_problem_registry_kinds_and_listing():
    kinds = problem_kinds()
    assert {"kernel", "serve", "sharding"} <= set(kinds)
    specs = list_problems()
    assert all(":" in s for s in specs)
    assert any(s.startswith("kernel:matmul/") for s in specs)
    assert any(s.startswith("sharding:") for s in specs)
    assert "serve:p9n9" in specs
    # every listed spec round-trips through parse_problem
    for spec in specs:
        p = parse_problem(spec)
        assert p.spec == spec
        assert len(p.space()) > 0


def test_parse_problem_errors_list_valid_kinds():
    with pytest.raises(ValueError) as ei:
        parse_problem("bogus")                      # no colon
    assert "kind:name" in str(ei.value) and "kernel" in str(ei.value)
    with pytest.raises(KeyError) as ei:
        parse_problem("wat:thing")                  # unknown kind
    assert "valid kinds" in str(ei.value)
    with pytest.raises(KeyError):
        make_problem("kernel", "no_such_kernel/1")
    with pytest.raises(KeyError):
        KernelProblem("matmul", "no_such_input")


def test_system_problems_covers_three_kinds():
    problems = system_problems("qwen2.5-3b", kernels=["matmul"])
    kinds = [p.kind for p in problems]
    assert kinds == ["kernel", "sharding", "serve"]
    jobs = [job_from_problem(p, HW, budget=4, seed=0) for p in problems]
    assert {j.kind for j in jobs} == {"kernel", "sharding", "serve"}
    # kernel jobs replay the cost model; system jobs measure in-process
    assert jobs[0].eval_fn is None
    assert jobs[1].eval_fn is not None and jobs[2].eval_fn is not None


# =============================================================================
# Sharding problem: space, portable counters, deterministic evaluator
# =============================================================================
def test_sharding_problem_space_and_counters():
    from repro.distributed.tuning import ShardingProblem

    p = ShardingProblem.from_name("qwen2.5-3b/train_4k", seed=5)
    sp = p.space()
    params = {pp.name: list(pp.values) for pp in sp.parameters}
    assert set(params) == {"MESH", "FSDP", "SEQ", "GA"}
    assert params["GA"] == [1, 2, 4]
    # 7 meshes x FSDP x SEQ x GA = 84 minus the constraint-pruned layouts
    assert len(sp) == 72
    wl = p.workload_fn()
    counters = wl(sp[0])
    # portable counters only: every feature must be a modeled counter the
    # TP→PC model can learn (the lane derate folds into MXU_FLOPS)
    assert "LANE_E_HINT" not in counters
    assert {"MXU_FLOPS", "HBM_RD", "HBM_WR", "ICI_B"} <= set(counters)
    assert all(v >= 0.0 for v in counters.values())


def test_sharding_evaluator_deterministic_and_skewed():
    from repro.distributed.tuning import ShardingProblem

    p = ShardingProblem.from_name("qwen2.5-3b/train_4k", seed=5)
    sp = p.space()
    hw = SPECS["tpu_v5e"]
    ev = p.make_evaluator(hw)
    r1 = ev(3, True)
    r2 = ev(3, True)
    assert r1[0] == r2[0] and r1[2] == r2[2]        # bit-reproducible
    assert r1[1] is not None                         # profiled counters
    assert ev(3, False)[1] is None                   # plain test: no counters
    # the measured backend applies skews/jitter the analytic model lacks
    from repro.core import costmodel
    wl = p.workload_fn()
    analytic = float(costmodel.execute(wl(sp[3]), hw).runtime)
    assert ev(3, False)[0] != analytic
    assert p.measured_runtime(sp[3], hw) > 0.0


def test_sharding_problem_tunes_through_fleet(tmp_path):
    from repro.distributed.tuning import ShardingProblem

    p = ShardingProblem.from_name("qwen2.5-3b/train_4k", seed=0)
    job = job_from_problem(p, "tpu_v5e", budget=10, seed=0)
    assert job.kind == "sharding"
    store = ConfigStore(str(tmp_path / "s.json"))
    pool = VirtualWorkerPool(workers=2)
    try:
        rep = FleetTuner([job], pool, store=store).run()
    finally:
        pool.close()
    r = rep.results[0]
    assert r.trials == 10 and r.best_runtime > 0.0
    entry = store.get(job.space.name, job.bucket, job.hardware_key,
                      kind="sharding")
    assert entry is not None and entry.config == r.best_config


# =============================================================================
# Serve problem: feasibility pricing + explicit shape override
# =============================================================================
def test_serve_problem_feasibility_and_shape_override():
    from repro.serve.autotune import INFEASIBLE_S, ServeProblem

    p = ServeProblem("p9n9")
    plen, new = p.rep_shape
    need = plen + new
    sp = p.space()
    hw = SPECS["tpu_v5e"]
    ev = p.make_evaluator(hw)
    saw_infeasible = saw_feasible = False
    for i in range(len(sp)):
        rt = ev(i, False)[0]
        if int(sp[i]["MAX_SEQ"]) < need:
            assert rt >= INFEASIBLE_S
            saw_infeasible = True
        else:
            assert rt < INFEASIBLE_S
            saw_feasible = True
    assert saw_infeasible and saw_feasible

    # the service path measures at the CLIENT's representative shape
    p2 = ServeProblem("p9n9", shape=(16, 6))
    assert p2.rep_shape == (16, 6)
    assert p2.bucket == "p9n9"
    with pytest.raises(ValueError):
        ServeProblem("not-a-bucket")


# =============================================================================
# Service: kind="problem" submits resolve through the registry
# =============================================================================
def test_daemon_problem_submit_end_to_end(tmp_path):
    from repro.fleet import VirtualWorkerPool as Pool
    from repro.service import (ServiceClient, ServiceError, TuningDaemon)
    from repro.service import protocol as P

    d = TuningDaemon(Pool(workers=2), ConfigStore(),
                     default_trial_budget=5)
    d.start()
    try:
        with ServiceClient(d.address) as c:
            r = c.submit_problem("t", "kernel:matmul/2048", HW)
            res = c.result(r["request_id"], timeout=120)
            assert res["state"] == "done" and res["trials"] == 5
            # repeat resolves store-only under the kind-namespaced key
            repeat = c.submit_problem("t2", "kernel:matmul/2048", HW)
            assert repeat["state"] == "done" and repeat["trials"] == 0
            # a non-kernel kind runs through the same daemon
            r2 = c.submit_problem("t", "serve:p1n1", HW,
                                  params={"arch": "qwen2.5-3b"}, budget=4)
            res2 = c.result(r2["request_id"], timeout=120)
            assert res2["state"] == "done" and res2["trials"] == 4
            with pytest.raises(ServiceError) as ei:
                c.submit_problem("t", "wat:thing", HW)
            assert ei.value.code == P.E_UNKNOWN_PROBLEM
            with pytest.raises(ServiceError) as ei:
                c.submit_problem("t", "no-colon", HW)
            assert ei.value.code == P.E_UNKNOWN_PROBLEM
    finally:
        d.shutdown(drain=False)
        assert d.wait(timeout=60)
