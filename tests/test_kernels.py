"""Per-kernel shape/dtype/config sweeps vs the pure-jnp oracles
(interpret mode executes the kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # seeded sampling shim (no pip deps)
    from _hypothesis_fallback import given, settings, st

from repro.kernels.attention.space import AttentionInput
from repro.kernels.conv2d.space import ConvInput
from repro.kernels.coulomb.space import CoulombInput
from repro.kernels.matmul.space import GemmInput
from repro.kernels.nbody.space import NBodyInput
from repro.kernels.registry import BENCHMARKS
from repro.kernels.transpose.space import TransposeInput

# interpret-mode kernel execution dominates the suite's wall clock; these
# sweeps run as a separate CI job (pytest -m slow)
pytestmark = pytest.mark.slow

RNG = np.random.default_rng(42)


def _check(name, inp, cfg, tol=2e-4, **kw):
    bm = BENCHMARKS[name]
    args = bm.make_args(inp, RNG)
    out = bm.run(cfg, *args, interpret=True, **kw)
    ref = bm.ref(*args, **kw) if name == "coulomb" else bm.ref(*args)
    err = float(jnp.max(jnp.abs(out - ref)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert err / scale < tol, f"{name} cfg={cfg} rel err {err/scale:.2e}"


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (192, 256, 128),
                                   (256, 192, 320), (64, 512, 96)])
@pytest.mark.parametrize("cfg", [
    {"BLOCK_M": 64, "BLOCK_N": 128, "BLOCK_K": 128, "LOOP_ORDER": "mnk",
     "ACC_F32": 1},
    {"BLOCK_M": 128, "BLOCK_N": 128, "BLOCK_K": 256, "LOOP_ORDER": "nmk",
     "ACC_F32": 1},
])
def test_matmul_sweep(m, n, k, cfg):
    _check("matmul", GemmInput(m, n, k), cfg)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_matmul_property_shapes(mm, nn, kk):
    """Any multiple-of-64 shape matches the oracle."""
    cfg = {"BLOCK_M": 64, "BLOCK_N": 128, "BLOCK_K": 64,
           "LOOP_ORDER": "mnk", "ACC_F32": 1}
    _check("matmul", GemmInput(64 * mm, 64 * nn, 64 * kk), cfg)


@pytest.mark.parametrize("m,n", [(128, 128), (200, 264), (96, 512)])
@pytest.mark.parametrize("bm_,bn", [(64, 128), (128, 64), (32, 256)])
def test_transpose_sweep(m, n, bm_, bn):
    _check("transpose", TransposeInput(m, n),
           {"BLOCK_M": bm_, "BLOCK_N": bn, "STAGE_OUT": 0})


@pytest.mark.parametrize("gs,na", [(16, 32), (16, 40), (8, 16)])
@pytest.mark.parametrize("z,chunk", [(2, 16), (4, 8), (8, 64)])
def test_coulomb_sweep(gs, na, z, chunk):
    cfg = {"Z_IT": z, "BY": 8, "BX": 128, "ATOM_CHUNK": chunk,
           "ATOMS_IN_SMEM": 0}
    _check("coulomb", CoulombInput(gs, na), cfg, tol=5e-4, grid_size=gs)


@pytest.mark.parametrize("n", [128, 200, 256])
@pytest.mark.parametrize("bi,bj", [(64, 64), (128, 32), (32, 128)])
def test_nbody_sweep(n, bi, bj):
    cfg = {"BLOCK_I": bi, "BLOCK_J": bj, "J_UNROLL": 1, "KEEP_PAIRWISE": 0}
    _check("nbody", NBodyInput(n), cfg, tol=1e-3)


@pytest.mark.parametrize("h,w", [(64, 128), (96, 160)])
@pytest.mark.parametrize("by,bx,unroll", [(32, 128, 1), (64, 128, 0)])
def test_conv2d_sweep(h, w, by, bx, unroll):
    cfg = {"BY": by, "BX": bx, "UNROLL_TAPS": unroll, "FILTER_SMEM": 0,
           "DMA_DEPTH": 1}
    _check("conv2d", ConvInput(h, w, 5), cfg, tol=1e-3)


@pytest.mark.parametrize("s,d", [(256, 64), (384, 128)])
@pytest.mark.parametrize("bq,bk", [(128, 128), (128, 256)])
def test_attention_sweep(s, d, bq, bk):
    cfg = {"BLOCK_Q": bq, "BLOCK_K": bk, "KEEP_P": 0, "Q_PREFETCH": 1}
    _check("attention", AttentionInput(1, 2, s, d), cfg, tol=2e-3)


def test_all_benchmarks_have_space_and_workload():
    for name, bm in BENCHMARKS.items():
        sp = bm.make_space()
        assert len(sp) > 16, name
        w = bm.workload_fn(sp[0], bm.default_input)
        assert w.get("VMEM_WS", 0) > 0, name
        assert w.get("GRID", 0) >= 1, name
