"""Tuning-as-a-service: protocol, sharded store, tenants, daemon (ISSUE 6).

The acceptance surface: a daemon multiplexes many tenants onto one fleet
over a localhost socket; a repeat (kernel, bucket, hardware) key resolves
store-only with ZERO trials; identical in-flight requests coalesce;
per-tenant worker-seconds budgets reject/park the over-spender without
touching anyone else; shutdown drains gracefully; and the serve path's
``OnlineAutotuner`` routes drift retunes through the service, falling
back in-process when the daemon is unreachable.
"""
import dataclasses
import os

import pytest

from repro.core.hwspec import get as hwget
from repro.fleet import VirtualWorkerPool
from repro.service import (ProtocolError, ServiceClient, ServiceError,
                           ShardedConfigStore, TuningDaemon, validate_request)
from repro.service import protocol as P
from repro.service.tenants import AdmissionError, TenantManager
from repro.tuning import ConfigStore

HW = "tpu_v4"


# =============================================================================
# Wire protocol
# =============================================================================
def test_protocol_roundtrip():
    msg = {"op": "ping"}
    assert P.decode(P.encode(msg)) == msg


def test_protocol_rejects_garbage():
    with pytest.raises(ProtocolError):
        P.decode(b"not json\n")
    with pytest.raises(ProtocolError):
        P.decode(b"[1, 2]\n")            # not an object
    with pytest.raises(ProtocolError):
        validate_request({"op": "frobnicate"})
    with pytest.raises(ProtocolError):
        validate_request({})


def test_protocol_submit_kernel_validation():
    req = validate_request({"op": "submit", "tenant": "t", "kind": "kernel",
                            "kernel": "matmul", "hardware": HW})
    assert req["seed"] == 0 and req["budget"] is None
    for broken in (
        {"op": "submit", "kind": "kernel", "kernel": "matmul",
         "hardware": HW},                             # no tenant
        {"op": "submit", "tenant": "", "kind": "kernel",
         "kernel": "matmul", "hardware": HW},         # empty tenant
        {"op": "submit", "tenant": "t", "kind": "kernel",
         "hardware": HW},                             # no kernel
        {"op": "submit", "tenant": "t", "kind": "kernel",
         "kernel": "matmul", "hardware": HW, "budget": 0},
        {"op": "submit", "tenant": "t", "kind": "kernel",
         "kernel": "matmul", "hardware": HW, "budget": True},  # bool != int
        {"op": "submit", "tenant": "t", "kind": "wat",
         "kernel": "matmul", "hardware": HW},
    ):
        with pytest.raises(ProtocolError):
            validate_request(broken)


def test_protocol_submit_serve_validation():
    base = {"op": "submit", "kind": "serve", "tenant": "t", "hardware": HW,
            "bucket": "p1n1", "bucket_shape": [16, 6],
            "batch_sizes": [1, 2, 4], "max_seqs": [32, 64]}
    req = validate_request(base)
    assert req["space"] == "serve_online" and req["calib_n"] == 16
    with pytest.raises(ProtocolError):
        validate_request({**base, "bucket_shape": [16]})      # not a pair
    with pytest.raises(ProtocolError):
        validate_request({**base, "batch_sizes": []})
    with pytest.raises(ProtocolError):
        validate_request({**base, "max_seqs": [32, -1]})


def test_protocol_request_id_ops():
    for op in ("status", "result", "cancel"):
        assert validate_request({"op": op, "request_id": "r1"}) == \
            {"op": op, "request_id": "r1"}
        with pytest.raises(ProtocolError):
            validate_request({"op": op})


# =============================================================================
# Sharded store
# =============================================================================
def test_sharded_store_api_parity(tmp_path):
    """Keys written through the facade read back identically to a plain
    store, across shard files, and survive a reopen."""
    root = str(tmp_path / "corpus")
    store = ShardedConfigStore(root, n_shards=3)
    keys = [("sp", f"b{i}", hw) for i in range(4)
            for hw in ("tpu_v4", "tpu_v5e")]
    for i, (s, b, h) in enumerate(keys):
        store.put(s, b, h, config={"X": i}, runtime=float(i + 1), trials=i)
    assert len(store) == len(keys)
    shard_files = [f for f in os.listdir(root) if f.startswith("shard-")]
    assert len(shard_files) > 1          # actually partitioned
    reopened = ShardedConfigStore(root)
    assert reopened.n_shards == 3        # metafile wins over the default
    for i, (s, b, h) in enumerate(keys):
        e = reopened.get(s, b, h)
        assert e is not None and e.config == {"X": i}
    assert {e.key for e in reopened.entries()} == \
        {f"kernel|{s}|{b}|{h}" for s, b, h in keys}


def test_sharded_store_nearest_model_tiers(tmp_path):
    """The portability tiering must see the UNION of all shards."""
    store = ShardedConfigStore(str(tmp_path / "c"), n_shards=4)
    art = {"format": "repro.tppc_model"}
    store.put_model_dict("sp", "bucketA", "hw1", dict(art))
    store.put_model_dict("sp", "bucketB", "hw2", dict(art))
    # exact hit
    assert store.nearest_model_key("sp", "bucketA", "hw1") == \
        "kernel|sp|bucketA|hw1"
    # same bucket, other hardware beats same hardware, other bucket
    assert store.nearest_model_key("sp", "bucketA", "hw2") == \
        "kernel|sp|bucketA|hw1"
    # same hardware, other bucket
    assert store.nearest_model_key("sp", "bucketC", "hw2") == \
        "kernel|sp|bucketB|hw2"
    assert store.nearest_model_key("other", "bucketA", "hw1") is None


def test_sharded_store_batched_save_flushes_dirty_shards(tmp_path):
    root = str(tmp_path / "c")
    store = ShardedConfigStore(root, n_shards=4, autosave=False)
    store.put("sp", "b1", "hw", config={"X": 1}, runtime=1.0, trials=1)
    store.put("sp", "b2", "hw", config={"X": 2}, runtime=2.0, trials=1)
    assert len(ShardedConfigStore(root)) == 0      # nothing flushed yet
    store.save()
    assert len(ShardedConfigStore(root)) == 2


def test_sharded_store_prune_aggregates(tmp_path):
    store = ShardedConfigStore(str(tmp_path / "c"), n_shards=3)
    for hw in ("tpu_v4", "tpu_v5e"):
        for b in ("b1", "b2", "b3"):
            store.put("sp", b, hw, config={}, runtime=1.0, trials=1)
    preview = store.prune(keep_hardware={"tpu_v4"}, dry_run=True)
    assert preview["dropped_entries"] == 3 and len(store) == 6
    stats = store.prune(keep_hardware={"tpu_v4"})
    assert stats == preview
    assert len(store) == 3
    # pruning persisted: the dropped keys do not resurrect on reopen
    assert len(ShardedConfigStore(str(tmp_path / "c"))) == 3


# =============================================================================
# Tenant policy
# =============================================================================
def test_tenant_admission_limits():
    tm = TenantManager(max_tenants=2, max_queued_per_tenant=1)
    a = tm.admit("a")
    tm.admit("b")
    with pytest.raises(AdmissionError):
        tm.admit("c")
    tm.check_submit(a)
    a.queued = 1
    with pytest.raises(AdmissionError):
        tm.check_submit(a)


def test_tenant_budget_exhaustion_and_topup():
    tm = TenantManager()
    ts = tm.admit("t", budget_s=1.0)
    tm.charge(ts, 0.6)
    tm.check_submit(ts)                  # still solvent
    tm.charge(ts, 0.6)
    assert ts.exhausted
    with pytest.raises(AdmissionError) as ei:
        tm.check_submit(ts)
    assert ei.value.code == P.E_BUDGET
    tm.admit("t", budget_s=10.0)         # top-up re-opens the account
    assert not ts.exhausted
    tm.check_submit(ts)


def test_tenant_fairness_least_spent_first():
    tm = TenantManager()
    for name, spend in (("hog", 9.0), ("mid", 1.0), ("new", 0.0)):
        tm.charge(tm.admit(name), spend)
    assert tm.fairness_order(["hog", "mid", "new"]) == ["new", "mid", "hog"]


# =============================================================================
# Daemon: in-process deterministic driving (no sockets, no loop thread)
# =============================================================================
def _daemon(store=None, **kw):
    d = TuningDaemon(VirtualWorkerPool(workers=4),
                     store if store is not None else ConfigStore(),
                     default_trial_budget=6, **kw)
    d.tuner.begin()
    return d


def _drive(d, until, max_iters=2000):
    for _ in range(max_iters):
        if until():
            return
        d._admit_pending()
        d.tuner.step(max_wait=0.01)
        d._meter()
    raise AssertionError("daemon did not converge")


def _submit_kernel(d, tenant, kernel="matmul", input="2048", hw=HW, **kw):
    return d.handle(validate_request(dict(
        op="submit", kind="kernel", tenant=tenant, kernel=kernel,
        input=input, hardware=hw, **kw)))


def test_daemon_cold_then_store_hit():
    d = _daemon()
    r1 = _submit_kernel(d, "a")
    assert r1["ok"] and r1["state"] == "queued"
    rid = r1["request_id"]
    _drive(d, lambda: d._records[rid].state == "done")
    res = d.handle({"op": "result", "request_id": rid})
    assert res["ok"] and res["trials"] == 6 and res["source"] == "tuned"
    # repeat key: answered inline from the store with zero trials
    r2 = _submit_kernel(d, "b")
    assert r2["state"] == "done" and r2["trials"] == 0
    assert r2["source"] == "store"
    assert r2["config"] == res["config"]


def test_daemon_coalesces_identical_inflight_requests():
    d = _daemon()
    r1 = _submit_kernel(d, "a")
    r2 = _submit_kernel(d, "b")          # same key, primary still queued
    assert r2["coalesced"] == r1["request_id"]
    _drive(d, lambda: d._records[r2["request_id"]].state == "done")
    res = d.handle({"op": "result", "request_id": r2["request_id"]})
    assert res["trials"] == 0 and res["source"] == "coalesced"
    # the follower's tenant paid nothing; the primary's paid the tuning
    assert d.tenants.get("b").spent_s == 0.0
    assert d.tenants.get("a").spent_s > 0.0


def test_daemon_unknown_kernel_and_request():
    d = _daemon()
    r = _submit_kernel(d, "a", kernel="no_such_kernel")
    assert not r["ok"] and r["code"] == P.E_UNKNOWN_KERNEL
    for op in ("status", "result", "cancel"):
        r = d.handle({"op": op, "request_id": "r999999"})
        assert not r["ok"] and r["code"] == P.E_UNKNOWN_REQUEST


def test_daemon_cancel_queued_and_running():
    d = _daemon(max_active_jobs=1)
    r1 = _submit_kernel(d, "a", kernel="matmul")
    r2 = _submit_kernel(d, "a", kernel="transpose", input=None)
    d._admit_pending()                   # r1 running, r2 still queued
    c2 = d.handle({"op": "cancel", "request_id": r2["request_id"]})
    assert c2["cancelled"]
    assert d._records[r2["request_id"]].state == "cancelled"
    d.tuner.step(max_wait=0.01)          # a few trials land for r1
    c1 = d.handle({"op": "cancel", "request_id": r1["request_id"]})
    assert c1["cancelled"]
    rec1 = d._records[r1["request_id"]]
    assert rec1.state == "cancelled"
    res = d.handle({"op": "result", "request_id": r1["request_id"]})
    assert not res["ok"] and res["code"] == P.E_NOT_DONE
    # nothing was published for a cancelled tuning run
    assert len(d.store) == 0


def test_daemon_meters_and_parks_over_budget_tenant():
    """The over-spender is rejected/parked; other tenants are untouched."""
    d = _daemon(tenants=TenantManager(max_active_per_tenant=1))
    rp = _submit_kernel(d, "poor", kernel="matmul", tenant_budget_s=1e-7)
    rq = _submit_kernel(d, "poor", kernel="transpose", input=None)
    rr = _submit_kernel(d, "rich", kernel="conv2d", input=None)
    done = lambda rid: d._records[rid].state in ("done", "cancelled")
    _drive(d, lambda: done(rp["request_id"]) and done(rr["request_id"]))
    poor = d.tenants.get("poor")
    assert poor.exhausted and poor.spent_s > 1e-7
    # the queued request was parked, not silently dropped
    d._admit_pending()
    assert d._records[rq["request_id"]].state == "parked"
    # new submits from the exhausted tenant bounce with the budget code
    r4 = _submit_kernel(d, "poor", kernel="attention", input=None)
    assert not r4["ok"] and r4["code"] == P.E_BUDGET
    # the solvent tenant's request completed normally
    assert d._records[rr["request_id"]].state == "done"
    assert not d.tenants.get("rich").exhausted
    # request-level metering adds up to the tenant ledger
    recs = [d._records[r["request_id"]] for r in (rp, rq)]
    assert abs(sum(r.spent_s for r in recs) - poor.spent_s) < 1e-9


def test_daemon_drain_resolves_running_as_cancelled():
    d = _daemon()
    r1 = _submit_kernel(d, "a", budget=50)
    d._admit_pending()
    d.tuner.step(max_wait=0.01)          # strictly fewer than 50 trials in
    d._draining = True                   # what shutdown() sets...
    d.tuner.stop()
    while d.tuner.step(max_wait=0.01):   # ...and the loop thread drains
        pass
    rep = d.tuner.finish()
    rec = d._records[r1["request_id"]]
    assert rec.state == "cancelled"
    assert rep.results and rep.results[0].cancelled
    assert 0 < rec.trials < 50           # partial progress was collected


def test_daemon_serve_kind_submit(tmp_path):
    d = _daemon(store=ShardedConfigStore(str(tmp_path / "c"), n_shards=2))
    r = d.handle(validate_request({
        "op": "submit", "kind": "serve", "tenant": "engine-1",
        "hardware": HW, "bucket": "p2n2", "bucket_shape": [40, 12],
        "batch_sizes": [1, 2, 4, 8, 16], "max_seqs": [32, 64, 96, 128]}))
    rid = r["request_id"]
    _drive(d, lambda: d._records[rid].state == "done")
    res = d.handle({"op": "result", "request_id": rid})
    assert res["ok"]
    # the winner holds the bucket's representative shape
    assert res["config"]["MAX_SEQ"] >= 40 + 12
    entry = d.store.get("serve_online", "p2n2", HW)
    assert entry is not None and entry.config == res["config"]


def test_daemon_serve_kind_unregistered_hardware_ships_spec():
    """A replica whose hardware label isn't in the registry (a CPU host)
    ships its pricing spec's numbers; the daemon prices on them and keys
    the store by the spec fingerprint — without the payload the submit
    is rejected, not mispriced."""
    import dataclasses as dc

    from repro.core import hwspec
    from repro.core.hwspec import SPECS

    d = _daemon()
    base = dict(op="submit", kind="serve", tenant="replica",
                hardware="cpu", bucket="p2n2", bucket_shape=[40, 12],
                batch_sizes=[1, 2, 4, 8], max_seqs=[64, 96, 128])
    r = d.handle(validate_request(dict(base)))
    assert not r["ok"] and r["code"] == P.E_BAD_REQUEST

    spec = dc.replace(SPECS[HW], name="cpu")
    r = d.handle(validate_request(dict(
        base, hardware_spec=dc.asdict(spec))))
    rid = r["request_id"]
    _drive(d, lambda: d._records[rid].state == "done")
    res = d.handle({"op": "result", "request_id": rid})
    assert res["ok"] and res["config"]["MAX_SEQ"] >= 40 + 12
    # keyed by fingerprint, so two replicas with the same label but
    # different silicon don't collide
    entry = d.store.get("serve_online", "p2n2", hwspec.fingerprint(spec))
    assert entry is not None and entry.config == res["config"]
    # ...and a repeat submit with the same spec is a store hit
    r2 = d.handle(validate_request(dict(
        base, hardware_spec=dc.asdict(spec))))
    assert r2["state"] == "done" and r2["trials"] == 0


def test_daemon_stats_shape():
    d = _daemon()
    _submit_kernel(d, "a")
    st = d.handle({"op": "stats"})
    assert st["ok"] and not st["draining"]
    assert st["fleet"]["jobs"] == 0      # not admitted yet (no loop ran)
    assert "a" in st["tenants"]
    assert st["requests"] == {"queued": 1}


# =============================================================================
# Daemon over a real socket (threaded loop + client)
# =============================================================================
@pytest.fixture()
def live_daemon(tmp_path):
    store = ShardedConfigStore(str(tmp_path / "corpus"), n_shards=2)
    d = TuningDaemon(VirtualWorkerPool(workers=4), store,
                     default_trial_budget=6)
    d.start()
    yield d
    d.shutdown(drain=False)
    assert d.wait(timeout=60)


def test_daemon_socket_end_to_end(live_daemon):
    with ServiceClient(live_daemon.address) as c:
        assert c.ping()["version"] == P.PROTOCOL_VERSION
        r = c.submit_kernel("a", "matmul", HW, input="2048")
        res = c.result(r["request_id"], timeout=120)
        assert res["state"] == "done" and res["trials"] == 6
        repeat = c.submit_kernel("other-tenant", "matmul", HW, input="2048")
        assert repeat["state"] == "done" and repeat["trials"] == 0
        st = c.stats()
        assert st["tenants"]["other-tenant"]["store_hits"] == 1
        assert st["store_entries"] >= 1


def test_daemon_socket_rejects_malformed_line(live_daemon):
    import socket as socketlib

    with socketlib.create_connection(live_daemon.address, timeout=10) as s:
        s.sendall(b"this is not json\n")
        resp = P.decode(s.makefile("rb").readline())
        assert not resp["ok"] and resp["code"] == P.E_BAD_REQUEST


def test_daemon_socket_drain_shutdown(live_daemon):
    with ServiceClient(live_daemon.address) as c:
        assert c.shutdown(drain=True)["draining"]
        assert live_daemon.wait(timeout=60)
        with pytest.raises(ServiceError):
            ServiceClient(live_daemon.address).ping()


# =============================================================================
# OnlineAutotuner --service routing
# =============================================================================
def _serve_tuner(service, hardware_name=HW, **kw):
    from repro.serve.autotune import (OnlineAutotuner, ServeWorkloadStats,
                                      SyntheticServeBackend, serve_space)

    hw = hwget(HW)
    stats = ServeWorkloadStats()
    backend = SyntheticServeBackend(hw, stats, seed=1)
    return backend, OnlineAutotuner(
        backend, store=ConfigStore(), space=serve_space(), hw=hw,
        stats=stats, hardware_name=hardware_name, service=service,
        max_live_trials=6, **kw)


def _requests(n=8, plen=20, new=8):
    import numpy as np

    from repro.serve.engine import Request

    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(1, 100, size=plen),
                    max_new_tokens=new) for i in range(n)]


def test_online_autotuner_routes_via_service(live_daemon):
    backend, tuner = _serve_tuner(f"127.0.0.1:{live_daemon.port}")
    _, rep = tuner.serve(_requests())
    assert rep.via_service and not rep.reused and rep.live_trials == 0
    assert backend.measure_calls == 0    # zero live trials on the engine
    # adopted locally: revisiting the bucket is a plain local store hit
    _, rep2 = tuner.serve(_requests())
    assert not rep2.drift
    tuner._active = None                 # force a re-ensure
    _, rep3 = tuner.serve(_requests())
    assert rep3.reused and not rep3.via_service


def test_online_autotuner_falls_back_when_unreachable():
    backend, tuner = _serve_tuner("127.0.0.1:1", service_timeout=2.0)
    _, rep = tuner.serve(_requests())
    assert not rep.via_service and rep.live_trials > 0
    assert backend.measure_calls == rep.live_trials


def test_online_autotuner_routes_with_unregistered_hardware(live_daemon):
    """A replica labeled outside the spec registry (jax.default_backend()
    says "cpu") still routes via the service: its pricing spec rides
    along with the submit instead of silently falling back."""
    backend, tuner = _serve_tuner(f"127.0.0.1:{live_daemon.port}",
                                  hardware_name="cpu")
    _, rep = tuner.serve(_requests())
    assert rep.via_service and rep.live_trials == 0
    assert backend.measure_calls == 0
    # adopted into the local store under the replica's own label
    assert tuner.store.get(tuner.space.name, rep.bucket, "cpu") is not None
