"""Crash safety: journal, recovery, self-healing clients, store integrity.

The acceptance surface (ISSUE 7): every accepted request survives a
daemon SIGKILL — the write-ahead journal makes submits durable before
the client sees a request id, ``recover=True`` replays it (finished
requests answer from the store, interrupted ones resume with their
REMAINING trial budget, tenant spend is restored), idempotency keys
dedupe retried submits across restarts, damaged store files quarantine
instead of crashing the load path, and the client distinguishes
"request never sent" from "response never read" so a lost response can
never fork a duplicate paid tuning run.
"""
import json
import os
import signal
import socket as socketlib
import subprocess
import sys
import time

import pytest

from repro.fleet import VirtualWorkerPool
from repro.service import (RequestJournal, ServiceClient, ServiceError,
                           ServiceUnavailable, ShardedConfigStore,
                           TuningDaemon)
from repro.service import protocol as P
from repro.service.client import _TransportFailure
from repro.service.journal import EV_SUBMIT, replay
from repro.tuning import ConfigStore

HW = "tpu_v4"


# =============================================================================
# Journal: append, replay, damage tolerance
# =============================================================================
def test_journal_roundtrip(tmp_path):
    path = str(tmp_path / "j.jsonl")
    with RequestJournal(path) as j:
        j.append(EV_SUBMIT, rid="r000001", key="a|b|c")
        j.append("done", rid="r000001", result={"runtime": 1.5})
    events, stats = replay(path)
    assert [e["ev"] for e in events] == ["submit", "done"]
    assert stats.events == 2 and stats.corrupt == 0 and stats.torn == 0
    assert stats.last_seq == 2
    # a reopened journal continues the sequence
    with RequestJournal(path) as j2:
        j2.replay()
        rec = j2.append("cancelled", rid="r000002")
    assert rec["seq"] == 3


def test_journal_replay_forgives_torn_tail_and_corrupt_interior(tmp_path):
    path = str(tmp_path / "j.jsonl")
    with RequestJournal(path) as j:
        j.append(EV_SUBMIT, rid="r1")
        j.append(EV_SUBMIT, rid="r2")
        j.append(EV_SUBMIT, rid="r3")
    lines = open(path, "rb").read().splitlines(keepends=True)
    # flip a byte inside record 2 (interior corruption) and tear the tail
    lines[1] = lines[1].replace(b'"rid":"r2"', b'"rid":"rX"')
    lines.append(b'{"seq": 4, "ev": "done", "tru')       # SIGKILL scar
    with open(path, "wb") as f:
        f.writelines(lines)
    events, stats = replay(path)
    assert [e["rid"] for e in events] == ["r1", "r3"]
    assert stats.corrupt == 1 and stats.torn == 1


# =============================================================================
# Daemon recovery (in-process crash drills: no sockets, no loop thread)
# =============================================================================
def _daemon(store, **kw):
    d = TuningDaemon(VirtualWorkerPool(workers=4), store,
                     default_trial_budget=6, **kw)
    d.tuner.begin()
    return d


def _drive(d, until, max_iters=2000):
    for _ in range(max_iters):
        if until():
            return
        d._admit_pending()
        d.tuner.step(max_wait=0.01)
        d._meter()
    raise AssertionError("daemon did not converge")


def _submit(d, tenant, idem=None, budget_s=None, kernel="matmul",
            input="2048"):
    return d.handle(P.validate_request(dict(
        op="submit", kind="kernel", tenant=tenant, kernel=kernel,
        input=input, hardware=HW, idempotency_key=idem,
        tenant_budget_s=budget_s)))


def _fleet_trials(d):
    return sum(js.account.steps for js in d.tuner._states)


def test_recover_resumes_interrupted_job_with_remaining_budget(tmp_path):
    jpath = str(tmp_path / "journal.jsonl")
    store = ShardedConfigStore(str(tmp_path / "corpus"), n_shards=2)
    d = _daemon(store, journal=jpath)
    rid = _submit(d, "a", idem="k1", budget_s=60.0)["request_id"]
    # a few ticks of progress, then the "crash": abandon the daemon
    # (journal fsyncs per append, so nothing needs a clean shutdown)
    _drive(d, lambda: d._records[rid].trials >= 2)
    before = _fleet_trials(d)
    assert 0 < before < 6
    spent_before = d._records[rid].spent_s
    d.journal.close()

    store2 = ShardedConfigStore(str(tmp_path / "corpus"), n_shards=2)
    d2 = _daemon(store2, journal=jpath, recover=True)
    assert d2.recovery["resubmitted"] == 1
    rec = d2._records[rid]
    assert rec.recovered and rec.resumed_trials == before
    _drive(d2, lambda: d2._records[rid].state == "done")
    res = d2.handle({"op": "result", "request_id": rid})
    # total trials across both incarnations == the budget, not 2x it
    assert res["ok"] and res["trials"] == 6
    assert before + _fleet_trials(d2) == 6
    # tenant spend carried over and kept accruing
    ts = d2.tenants.snapshot()["a"]
    assert ts["budget_s"] == 60.0
    assert ts["spent_s"] >= round(spent_before, 6) > 0


def test_recover_restores_done_requests_and_tenant_spend(tmp_path):
    jpath = str(tmp_path / "journal.jsonl")
    store = ShardedConfigStore(str(tmp_path / "corpus"), n_shards=2)
    d = _daemon(store, journal=jpath)
    rid = _submit(d, "a", idem="k1", budget_s=60.0)["request_id"]
    _drive(d, lambda: d._records[rid].state == "done")
    want = d.handle({"op": "result", "request_id": rid})
    spent = d.tenants.snapshot()["a"]["spent_s"]
    d.journal.close()

    d2 = _daemon(ShardedConfigStore(str(tmp_path / "corpus"), n_shards=2),
                 journal=jpath, recover=True)
    assert d2.recovery["restored_done"] == 1
    got = d2.handle({"op": "result", "request_id": rid})
    assert got["config"] == want["config"]
    assert got["trials"] == want["trials"] == 6
    assert d2.tenants.snapshot()["a"]["spent_s"] == pytest.approx(spent)
    # the restored request still dedupes an idempotent resubmit
    again = _submit(d2, "a", idem="k1")
    assert again["request_id"] == rid and again["deduped"]
    # and a fresh submit of the same key is a plain store hit
    fresh = _submit(d2, "b")
    assert fresh["state"] == "done" and fresh["trials"] == 0
    assert fresh["source"] == "store"


def test_recover_rebuilds_store_from_journal_after_shard_loss(tmp_path):
    jpath = str(tmp_path / "journal.jsonl")
    corpus = str(tmp_path / "corpus")
    d = _daemon(ShardedConfigStore(corpus, n_shards=2), journal=jpath)
    rid = _submit(d, "a")["request_id"]
    _drive(d, lambda: d._records[rid].state == "done")
    key = d._records[rid].key
    d.journal.close()
    # vaporize the whole corpus: every shard gone
    for f in os.listdir(corpus):
        if f.startswith("shard-"):
            os.unlink(os.path.join(corpus, f))

    d2 = _daemon(ShardedConfigStore(corpus, n_shards=2),
                 journal=jpath, recover=True)
    assert d2.recovery["repaired_entries"] == 1
    kind, space, bucket, hw = key.split("|")
    entry = d2.store.get(space, bucket, hw, kind=kind)
    assert entry is not None and entry.meta.get("recovered")
    # repeat submit: answered from the repaired store, zero trials
    r = _submit(d2, "b")
    assert r["state"] == "done" and r["trials"] == 0


def test_idempotent_resubmit_dedupes_in_flight(tmp_path):
    d = _daemon(ShardedConfigStore(str(tmp_path / "c"), n_shards=2),
                journal=str(tmp_path / "j.jsonl"))
    r1 = _submit(d, "a", idem="once")
    r2 = _submit(d, "a", idem="once")          # retried before resolution
    assert r2["request_id"] == r1["request_id"] and r2["deduped"]
    assert r2["state"] == "queued"
    # a different tenant's identical key is NOT deduped (keys are
    # per-tenant) — it coalesces like any identical in-flight request
    r3 = _submit(d, "b", idem="once")
    assert r3["request_id"] != r1["request_id"]
    assert r3.get("coalesced") == r1["request_id"]
    ts = d.tenants.snapshot()["a"]
    assert ts["submitted"] == 1                # the retry was not admitted


def test_recover_requires_journal(tmp_path):
    with pytest.raises(ValueError):
        TuningDaemon(VirtualWorkerPool(workers=2), ConfigStore(),
                     recover=True)


def test_health_op_in_process(tmp_path):
    d = _daemon(ShardedConfigStore(str(tmp_path / "c"), n_shards=2),
                journal=str(tmp_path / "j.jsonl"))
    h = d.handle({"op": "health"})
    assert h["ok"] and h["live"] and h["ready"]
    assert h["journal_enabled"] and h["store_writable"]
    d.shutdown(drain=False)
    h2 = d.handle({"op": "health"})
    assert h2["draining"] and not h2["ready"]


# =============================================================================
# Store integrity: quarantine instead of crash
# =============================================================================
def test_config_store_quarantines_truncated_file(tmp_path):
    path = str(tmp_path / "store.json")
    s = ConfigStore(path)
    s.put("sp", "128", HW, config={"BM": 32}, runtime=1.0, trials=4)
    with open(path, "r+b") as f:           # tear the file mid-JSON
        f.truncate(os.path.getsize(path) // 2)
    s2 = ConfigStore(path)                 # must not raise
    assert len(s2) == 0 and s2.quarantined
    assert os.path.exists(path + ".corrupt")
    # the store is usable again immediately
    s2.put("sp", "128", HW, config={"BM": 64}, runtime=2.0, trials=1)
    assert ConfigStore(path).get("sp", "128", HW) is not None


def test_config_store_quarantines_checksum_mismatch(tmp_path):
    path = str(tmp_path / "store.json")
    s = ConfigStore(path)
    s.put("sp", "128", HW, config={"BM": 32}, runtime=1.0, trials=4)
    d = json.load(open(path))
    key = next(iter(d["entries"]))
    d["entries"][key]["runtime"] = 0.001   # bit-rot without updating crc
    json.dump(d, open(path, "w"))
    s2 = ConfigStore(path)
    assert len(s2) == 0 and s2.quarantined


def test_sharded_store_quarantines_bad_shard_and_meta(tmp_path):
    root = str(tmp_path / "corpus")
    s = ShardedConfigStore(root, n_shards=2)
    s.put("sp", "128", HW, config={"BM": 32}, runtime=1.0, trials=4)
    for f in os.listdir(root):             # damage every file on disk
        with open(os.path.join(root, f), "w") as fh:
            fh.write('{"torn')
    s2 = ShardedConfigStore(root, n_shards=2)   # must not raise
    assert s2.n_shards == 2 and len(s2) == 0
    assert os.path.exists(os.path.join(root, "shards.json"))
    s2.put("sp", "128", HW, config={"BM": 64}, runtime=2.0, trials=1)
    assert ShardedConfigStore(root).get("sp", "128", HW) is not None


# =============================================================================
# Client self-healing: sent-vs-unsent, idempotent-only retry
# =============================================================================
def _failing_client(failures, monkeypatch):
    """Client whose first ``len(failures)`` round trips raise as scripted."""
    c = ServiceClient(("127.0.0.1", 1), retries=3, backoff=0.001)
    calls = {"n": 0}

    def fake(obj):
        i = calls["n"]
        calls["n"] += 1
        if i < len(failures):
            raise _TransportFailure(failures[i], "scripted failure")
        return {"ok": True, "echo": obj}

    monkeypatch.setattr(c, "_round_trip", fake)
    return c, calls


def test_client_retries_unsent_requests(monkeypatch):
    c, calls = _failing_client([False, False], monkeypatch)   # never sent
    assert c.call({"op": "submit"})["ok"]
    assert calls["n"] == 3


def test_client_refuses_to_retry_sent_non_idempotent(monkeypatch):
    c, calls = _failing_client([True], monkeypatch)           # response lost
    with pytest.raises(ServiceUnavailable) as ei:
        c.call({"op": "submit"})
    assert "may have been received" in str(ei.value)
    assert calls["n"] == 1


def test_client_retries_sent_idempotent(monkeypatch):
    c, calls = _failing_client([True, True], monkeypatch)
    assert c.call({"op": "status"}, idempotent=True)["ok"]
    assert calls["n"] == 3


def test_client_deadline_bounds_retries(monkeypatch):
    c, _ = _failing_client([False] * 10, monkeypatch)
    c.retries = 100
    c.backoff = 0.05
    t0 = time.monotonic()
    with pytest.raises(ServiceUnavailable):
        c.call({"op": "ping"}, idempotent=True, deadline_s=0.2)
    assert time.monotonic() - t0 < 2.0


# =============================================================================
# Protocol: oversize line bound (regression for read_line)
# =============================================================================
def test_protocol_read_line_bound():
    import io
    big = b"x" * (P.MAX_LINE_BYTES + 10) + b"\n"
    with pytest.raises(P.ProtocolError):
        P.read_line(io.BytesIO(big))
    assert P.read_line(io.BytesIO(b"small\n")) == b"small\n"
    assert P.read_line(io.BytesIO(b"")) is None


def test_daemon_socket_rejects_oversize_line(tmp_path):
    d = TuningDaemon(VirtualWorkerPool(workers=2),
                     ShardedConfigStore(str(tmp_path / "c"), n_shards=2),
                     default_trial_budget=4)
    d.start()
    try:
        with socketlib.create_connection(d.address, timeout=10) as s:
            s.sendall(b'{"op": "ping", "pad": "'
                      + b"x" * (P.MAX_LINE_BYTES + 100) + b'"}\n')
            resp = P.decode(s.makefile("rb").readline())
            assert not resp["ok"] and resp["code"] == P.E_BAD_REQUEST
            # the daemon closed the connection after answering
            s.settimeout(10)
            assert s.recv(1) == b""
    finally:
        d.shutdown(drain=False)
        assert d.wait(timeout=60)


# =============================================================================
# Full SIGKILL drill: live daemon, kill -9, restart --recover, same handle
# =============================================================================
def _free_port():
    s = socketlib.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _spawn_daemon(tmp_path, port, recover=False):
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.daemon",
           "--backend", "virtual", "--workers", "4",
           "--store-dir", str(tmp_path / "corpus"), "--shards", "2",
           "--journal", str(tmp_path / "journal.jsonl"),
           "--port", str(port), "--budget", "6"]
    if recover:
        cmd.append("--recover")
    proc = subprocess.Popen(cmd, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    deadline = time.time() + 60
    while time.time() < deadline:
        line = proc.stdout.readline()
        if "tuning service on" in line:
            return proc
        if proc.poll() is not None:
            break
    raise AssertionError(
        f"daemon did not come up: {proc.stdout.read()}")


@pytest.mark.slow
def test_sigkill_recover_end_to_end(tmp_path):
    port = _free_port()
    proc = _spawn_daemon(tmp_path, port)
    try:
        c = ServiceClient(("127.0.0.1", port), timeout=30)
        c.wait_ready(timeout=30)
        r = c.submit_kernel("a", "matmul", HW, input="2048", budget=40,
                            tenant_budget_s=120.0, idempotency_key="boom")
        rid = r["request_id"]
        # let some trials land, then SIGKILL mid-tuning
        deadline = time.time() + 60
        while time.time() < deadline:
            if c.status(rid)["trials"] >= 2:
                break
            time.sleep(0.05)
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
        with pytest.raises(ServiceError):
            c.ping()

        proc = _spawn_daemon(tmp_path, port, recover=True)
        c.wait_ready(timeout=30)
        # the ORIGINAL request id resolves on the recovered daemon
        res = c.result(rid, timeout=120)
        assert res["state"] == "done" and res["trials"] == 40
        st = c.status(rid)
        assert st["recovered"]
        # the idempotency key still points at the original request
        again = c.submit_kernel("a", "matmul", HW, input="2048",
                                budget=40, idempotency_key="boom")
        assert again["request_id"] == rid and again.get("deduped")
        assert c.stats()["tenants"]["a"]["spent_s"] > 0
        c.shutdown(drain=True)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)
