"""Sharding rules, HLO cost parser, and multi-device integration
(the 512-device dry-run path is covered by launch/dryrun.py; here we check
the machinery on small in-process examples + an 8-device subprocess)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.distributed.sharding import ShardingRules, default_rules, spec_for
from repro.roofline.analysis import parse_collectives
from repro.roofline.hlo_parse import analyze


class _FakeMesh:
    shape = {"data": 16, "model": 16}


def test_spec_for_divisibility():
    rules = default_rules(multi_pod=False)
    mesh = _FakeMesh()
    # divisible dims shard; non-divisible are dropped (replicated)
    s = spec_for(mesh, rules, ("vocab", "embed"), (256000, 4096))
    assert s == jax.sharding.PartitionSpec("model", "data")
    s = spec_for(mesh, rules, ("kv", None), (8, 64))   # 8 kv heads vs 16-way
    assert s == jax.sharding.PartitionSpec()


def test_spec_for_no_double_axis_use():
    rules = default_rules(multi_pod=False)
    s = spec_for(_FakeMesh(), rules, ("mlp", "heads"), (1024, 1024))
    # both map to "model": the second must be dropped
    assert s == jax.sharding.PartitionSpec("model")


def test_hlo_parser_scales_scan_bodies():
    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    cost = analyze(compiled.as_text())
    expect = 7 * 2 * 128 ** 3
    assert abs(cost.flops - expect) / expect < 0.05


def test_hlo_parser_transcendentals():
    def f(x):
        return jnp.exp(x).sum()
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
    cost = analyze(compiled.as_text())
    assert cost.transcendentals >= 1024


def test_parse_collectives_text():
    hlo = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  %ag.1 = bf16[64,128]{1,0} all-gather(%y), dimensions={0}
"""
    st = parse_collectives(hlo)
    assert st.count_by_op == {"all-reduce": 1, "all-gather": 1}
    assert st.bytes_by_op["all-reduce"] == 4096.0
    assert st.bytes_by_op["all-gather"] == 64 * 128 * 2


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import SMOKES
    from repro.models.registry import build_model
    from repro.optim.adamw import AdamW, constant_lr
    from repro.train.train_step import StepConfig, init_train_state, make_train_step
    from repro.distributed.sharding import default_rules, param_shardings
    from repro.distributed.api import activation_sharding
    from repro.distributed.sharding import make_act_resolver

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = default_rules(multi_pod=False)
    model = build_model(SMOKES["qwen2.5-3b"])
    opt = AdamW(lr=constant_lr(1e-3))
    step = make_train_step(model, opt, StepConfig(remat="none"))
    with mesh:
        with activation_sharding(make_act_resolver(mesh, rules)):
            state = init_train_state(model, opt, jax.random.PRNGKey(0))
            p_sh = param_shardings(mesh, rules, model.specs(), state.params)
            state = state._replace(params=jax.tree.map(jax.device_put, state.params, p_sh))
            npr = np.random.default_rng(0)
            batch = {
                "tokens": jnp.asarray(npr.integers(0, 512, (8, 32)), jnp.int32),
                "labels": jnp.asarray(npr.integers(0, 512, (8, 32)), jnp.int32),
            }
            state, metrics = jax.jit(step)(state, batch)
            loss = float(metrics["loss"])
            assert np.isfinite(loss), loss
            print("MULTIDEV_OK", loss)
""")


@pytest.mark.slow
def test_multidevice_train_step_subprocess():
    """Real 8-device SPMD execution (numerics, not just compile) — by far
    the suite's single slowest test (minutes of subprocess XLA compiles)."""
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
