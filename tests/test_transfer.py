"""Cross-space model transfer (ISSUE 10).

The acceptance surface: structural space signatures key TP→PC models by
what they ARE (hashed parameter slots + counter sets + problem kind)
instead of what they're named; the store grows a fifth, compatible-space
warm-start tier BELOW the four exact-space tiers (which must stay
bit-identical); transfer never crosses problem kinds; signature-less v2
store files upgrade in place; and the fleet threads a distrust-and-verify
``TransferredWarmStart`` through its warm-start path, surfacing
``source:"transfer"`` + similarity in service responses.
"""
import json
import os

import numpy as np
import pytest

from repro.core import hwspec
from repro.core.model import TransferredModel
from repro.core.searcher import TransferredWarmStart
from repro.core.tuning_space import TuningParameter, TuningSpace
from repro.fleet import (FleetTuner, VirtualWorkerPool, job_from_registry)
from repro.service import ShardedConfigStore, TuningDaemon, validate_request
from repro.tuning import ConfigStore, TuningSession
from repro.tuning.problem import make_problem
from repro.tuning.serialize import (artifact_signature, ensure_signature,
                                    model_from_dict, model_to_dict,
                                    rebind_model_dict)
from repro.tuning.signature import (DEFAULT_TRANSFER_THRESHOLD, ParamSlot,
                                    SpaceSignature, map_parameters,
                                    match_slots, similarity,
                                    transfer_compatible)
from repro.tuning.store import content_crc, store_key

HW = hwspec.PRODUCTION


def _kernel_sig(name):
    return SpaceSignature.from_problem(make_problem("kernel", name))


def _trained(kernel, model_kind="tree"):
    p = make_problem("kernel", kernel)
    sp = p.space()
    sess = TuningSession(sp, p.workload_fn(), hw=HW, seed=0)
    return sess.train(kind=model_kind, sample="deliberate"), sp


# =============================================================================
# Signatures: structure, matching, similarity
# =============================================================================
def test_signature_roundtrip_and_hash_stability():
    sig = _kernel_sig("conv2d/4096")
    again = SpaceSignature.from_dict(sig.to_dict())
    assert again == sig
    assert again.sig_hash == sig.sig_hash
    assert sig.kind == "kernel" and sig.counters   # workload counters sampled


def test_signature_rejects_wrong_format_and_version():
    d = _kernel_sig("matmul").to_dict()
    with pytest.raises(ValueError):
        SpaceSignature.from_dict(dict(d, format="other"))
    with pytest.raises(ValueError):
        SpaceSignature.from_dict(dict(d, version=99))


def test_match_slots_renamed_and_extended_parameters():
    a = [ParamSlot.of(TuningParameter("BLOCK", (8, 16, 32))),
         ParamSlot.of(TuningParameter("FLAG", (False, True)))]
    # BLOCK renamed to TILE (same values: pairs by structure hash);
    # FLAG extended is impossible (binary), keep it named
    b = [ParamSlot.of(TuningParameter("FLAG", (False, True))),
         ParamSlot.of(TuningParameter("TILE", (8, 16, 32)))]
    pairs = {(i, j): s for i, j, s in match_slots(a, b)}
    assert pairs[(0, 1)] == 1.0          # renamed, identical value set
    assert pairs[(1, 0)] == 1.0          # same name
    # extended value list: same name pairs with partial-credit Jaccard
    c = [ParamSlot.of(TuningParameter("BLOCK", (8, 16, 32, 64)))]
    pairs2 = match_slots(a, c)
    (i, j, s), = [p for p in pairs2 if p[0] == 0]
    assert j == 0 and s == pytest.approx(3 / 4)


def test_similarity_symmetric_and_bounded():
    sigs = [_kernel_sig(n) for n in ("matmul", "conv2d/4096", "nbody")]
    for a in sigs:
        assert similarity(a, a) == pytest.approx(1.0)
        for b in sigs:
            s = similarity(a, b)
            assert 0.0 <= s <= 1.0
            assert s == pytest.approx(similarity(b, a))


def test_transfer_compatible_never_crosses_kinds():
    sig = _kernel_sig("conv2d/4096")
    # identical structure under a different kind must NOT be compatible,
    # at any threshold
    other = SpaceSignature(kind="serve", space=sig.space, slots=sig.slots,
                           counters=sig.counters)
    assert similarity(sig, other) == pytest.approx(1.0)
    assert not transfer_compatible(sig, other, threshold=0.0)
    assert transfer_compatible(sig, sig)


def test_kernel_pairs_clear_threshold_serve_does_not():
    """The conservative default separates sibling kernel spaces from the
    serve geometry space — the empirical basis of the default."""
    conv = _kernel_sig("conv2d/4096")
    for name in ("matmul", "nbody", "coulomb", "transpose", "attention"):
        assert similarity(conv, _kernel_sig(name)) \
            >= DEFAULT_TRANSFER_THRESHOLD, name
    serve = SpaceSignature.from_problem(make_problem("serve", "p1n1"))
    kernelized = SpaceSignature(kind="kernel", space=serve.space,
                                slots=serve.slots, counters=serve.counters)
    assert similarity(conv, kernelized) < DEFAULT_TRANSFER_THRESHOLD


# =============================================================================
# Serializer: signature-carrying artifacts + rebinding
# =============================================================================
@pytest.mark.parametrize("model_kind", ["tree", "quadratic", "exact"])
def test_artifact_carries_signature_and_roundtrips(model_kind):
    model, sp = _trained("matmul", model_kind)
    d = model_to_dict(model, sp, kind="kernel")
    assert d["signature"]["format"] == "repro.space_signature"
    sig = artifact_signature(d)
    assert sig is not None and sig.kind == "kernel"
    assert set(sig.counters) == set(model.counter_names)
    m2 = model_from_dict(d)
    assert m2.signature == sig
    # byte-level round trip through JSON
    d2 = json.loads(json.dumps(d))
    assert artifact_signature(d2) == sig


def test_ensure_signature_upgrades_legacy_artifacts():
    model, sp = _trained("matmul")
    d = model_to_dict(model, sp, kind="kernel")
    legacy = {k: v for k, v in d.items() if k != "signature"}
    fixed = ensure_signature(legacy, kind="kernel")
    assert artifact_signature(fixed) == artifact_signature(d)
    # already-signed artifacts come back unchanged (same object)
    assert ensure_signature(fixed, kind="kernel") is fixed
    # unsignable artifacts pass through untouched instead of raising
    junk = {"format": "repro.tppc_model"}
    assert ensure_signature(junk) is junk


def test_rebind_model_dict_predicts_shared_counters():
    model, sp = _trained("matmul")
    d = model_to_dict(model, sp, kind="kernel")
    target = make_problem("kernel", "conv2d/4096")
    tsp, tsig = target.space(), SpaceSignature.from_problem(target)
    tm = rebind_model_dict(d, tsp, tsig, source_key="k", similarity=0.5)
    assert isinstance(tm, TransferredModel)
    assert set(tm.counter_names) <= set(model.counter_names)
    assert set(tm.counter_names) <= set(tsig.counters)
    # scalar and batched paths agree, over the whole target space
    mat = tm.predict_matrix(tsp)
    assert mat.shape == (len(tsp), len(tm.counter_names))
    for i in (0, len(tsp) // 2, len(tsp) - 1):
        p = tm.predict(tsp[i])
        for j, n in enumerate(tm.counter_names):
            assert mat[i, j] == pytest.approx(p[n])
    # translated configs always hold DECLARED source values
    src_by_name = {pp.name: set(pp.values) for pp in sp.parameters}
    cfg = tm.translate(tsp[0])
    assert set(cfg) == set(src_by_name)
    for name, v in cfg.items():
        assert v in src_by_name[name]


# =============================================================================
# Store: fifth tier below the untouched legacy four
# =============================================================================
def _store_with_kernel_models(*kernels):
    store = ConfigStore()
    for k in kernels:
        model, sp = _trained(k)
        store.save_model(sp.name, "default", "tpu_v5e", model, sp,
                         kind="kernel")
    return store


def test_transfer_tier_engages_only_after_legacy_tiers_miss():
    store = _store_with_kernel_models("matmul", "transpose")
    conv = make_problem("kernel", "conv2d/4096")
    sig = SpaceSignature.from_problem(conv)
    # never-seen space: legacy ladder misses, transfer tier hits
    assert store.nearest_model_key("conv2d", "4096", "tpu_v5e",
                                   kind="kernel") is None
    found = store.nearest_transfer_key(sig, "4096", "tpu_v5e")
    assert found is not None
    key, sim = found
    assert sim >= DEFAULT_TRANSFER_THRESHOLD
    model, mkey, msim = store.load_transfer_model(sig, "4096", "tpu_v5e",
                                                  conv.space())
    assert (mkey, msim) == (key, sim)
    assert isinstance(model, TransferredModel)
    assert model.source_key == key
    # once a model exists for the exact space, the legacy ladder answers
    # and transfer no longer offers anything new for that space
    cmodel, csp = _trained("conv2d/4096")
    store.save_model(csp.name, "4096", "tpu_v5e", cmodel, csp,
                     kind="kernel")
    assert store.nearest_model_key(csp.name, "4096", "tpu_v5e",
                                   kind="kernel") \
        == store_key(csp.name, "4096", "tpu_v5e", kind="kernel")
    refound = store.nearest_transfer_key(sig, "4096", "tpu_v5e")
    assert refound is not None and refound[0] != \
        store_key(csp.name, "4096", "tpu_v5e", kind="kernel")


def test_transfer_tier_kind_isolation_in_store():
    """A serve-kind artifact with a signature IDENTICAL to the kernel
    job's space must never cross kinds through the transfer tier."""
    store = ConfigStore()
    model, sp = _trained("matmul")
    d = model_to_dict(model, sp, kind="kernel")
    # forge the same artifact under the serve kind (space renamed so the
    # key parses as a different space of that kind)
    forged = dict(d, space=dict(d["space"], name="serve_gemmish"))
    forged.pop("signature")
    store.put_model_dict("serve_gemmish", "default", "tpu_v5e", forged,
                         kind="serve")
    sig = _kernel_sig("matmul")
    sig = SpaceSignature(kind="kernel", space="somewhere_else",
                         slots=sig.slots, counters=sig.counters)
    assert store.nearest_transfer_key(sig, "default", "tpu_v5e",
                                      threshold=0.0) is None
    # the same structure under the matching kind IS offered
    store.put_model_dict("gemmish", "default", "tpu_v5e",
                         dict(d, space=dict(d["space"], name="gemmish")),
                         kind="kernel")
    assert store.nearest_transfer_key(sig, "default", "tpu_v5e") is not None


def test_store_v2_file_upgrades_to_v3_with_signatures(tmp_path):
    store = _store_with_kernel_models("matmul")
    path = str(tmp_path / "store.json")
    store.save(path)
    d = json.load(open(path))
    assert d["version"] == 3
    # regress the file to version 2: signature-less artifacts
    for m in d["models"].values():
        m.pop("signature", None)
    d["version"] = 2
    d["crc"] = content_crc(d["entries"], d["models"])
    with open(path, "w") as f:
        json.dump(d, f)
    # v2 loads; signatures recomputed in memory; transfer tier works
    s2 = ConfigStore(path)
    conv = make_problem("kernel", "conv2d/4096")
    sig = SpaceSignature.from_problem(conv)
    assert s2.nearest_transfer_key(sig, "4096", "tpu_v5e") is not None
    # any write persists the upgrade: v3 on disk, signatures embedded
    s2.put(space="x", bucket="b", hardware="h", config={"A": 1},
           runtime=1.0, trials=1, kind="kernel")
    d2 = json.load(open(path))
    assert d2["version"] == 3
    assert all("signature" in m for m in d2["models"].values())
    # and reloads cleanly
    s3 = ConfigStore(path)
    assert s3.nearest_transfer_key(sig, "4096", "tpu_v5e") is not None


def test_model_index_matches_brute_force_through_mutations():
    """The (kind, space)-bucketed index must stay exact through put,
    merge, prune and reload — nearest_model_key answers must equal the
    pre-index brute-force scan."""
    art = {"format": "repro.tppc_model"}
    store = ConfigStore()
    keys = [("spA", "b1", "h1", "kernel"), ("spA", "b2", "h1", "kernel"),
            ("spA", "b1", "h2", "kernel"), ("spB", "b1", "h1", "kernel"),
            ("serve_x", "b1", "h1", "serve"), ("spA", "b3", "h3", "sharding")]
    for s, b, h, kk in keys:
        store.put_model_dict(s, b, h, dict(art), kind=kk)

    def brute(space, bucket, hardware, kind):
        from repro.tuning.store import split_key
        exact = store_key(space, bucket, hardware, kind=kind)
        if exact in store._models:
            return exact
        tiers = ([], [], [])
        for k in sorted(store._models):
            kk, s, b, h = split_key(k)
            if kk != kind or s != space:
                continue
            if b == bucket:
                tiers[0].append(k)
            elif h == hardware:
                tiers[1].append(k)
            else:
                tiers[2].append(k)
        for t in tiers:
            if t:
                return t[0]
        return None

    probes = [("spA", "b1", "h1", "kernel"), ("spA", "b9", "h1", "kernel"),
              ("spA", "b9", "h9", "kernel"), ("spA", "b1", "h1", "serve"),
              ("spB", "b9", "h9", "kernel"), ("spC", "b1", "h1", "kernel"),
              ("serve_x", "zz", "h1", "serve")]

    def check():
        for s, b, h, kk in probes:
            assert store.nearest_model_key(s, b, h, kind=kk) \
                == brute(s, b, h, kk), (s, b, h, kk)

    check()
    store.prune(keep_spaces={"spA", "serve_x"})
    check()
    store._merge_from({"format": "repro.config_store", "version": 3,
                       "entries": {},
                       "models": {"kernel|spB|b7|h7": dict(art)}})
    check()
    store.put_model_dict("spA", "b1", "h1", dict(art), kind="kernel")
    check()


def test_sharded_store_transfer_tier_and_rebalance_index(tmp_path):
    store = ShardedConfigStore(str(tmp_path / "c"), n_shards=3)
    model, sp = _trained("matmul")
    store.save_model(sp.name, "default", "tpu_v5e", model, sp,
                     kind="kernel")
    conv = make_problem("kernel", "conv2d/4096")
    sig = SpaceSignature.from_problem(conv)
    found = store.nearest_transfer_key(sig, "4096", "tpu_v5e")
    assert found is not None and found[1] >= DEFAULT_TRANSFER_THRESHOLD
    m, key, sim = store.load_transfer_model(sig, "4096", "tpu_v5e",
                                            conv.space())
    assert isinstance(m, TransferredModel) and (key, sim) == found
    # reopen: per-shard indexes rebuilt from disk, same answers
    s2 = ShardedConfigStore(str(tmp_path / "c"), n_shards=3)
    assert s2.nearest_transfer_key(sig, "4096", "tpu_v5e") == found
    # kind isolation holds across shards too
    bad = SpaceSignature(kind="serve", space=sig.space, slots=sig.slots,
                         counters=sig.counters)
    assert s2.nearest_transfer_key(bad, "4096", "tpu_v5e",
                                   threshold=0.0) is None


def test_load_transfer_ensemble_blends_all_compatible_sources():
    store = _store_with_kernel_models("matmul", "transpose", "nbody")
    conv = make_problem("kernel", "conv2d/4096")
    sig = SpaceSignature.from_problem(conv)
    ens, key, sim = store.load_transfer_ensemble(sig, "4096", "tpu_v5e",
                                                 conv.space())
    assert ens is not None and len(ens) == 3
    # best-first: member similarities descend, top is the provenance
    sims = [s for _, s in ens.members]
    assert sims == sorted(sims, reverse=True)
    assert (ens.source_key, ens.similarity) == (key, sim)
    assert store.nearest_transfer_key(sig, "4096", "tpu_v5e") == (key, sim)
    for m, _ in ens.members:
        assert isinstance(m, TransferredModel)
    # limit caps the committee at the most preferred sources
    ens2, key2, _ = store.load_transfer_ensemble(
        sig, "4096", "tpu_v5e", conv.space(), limit=2)
    assert len(ens2) == 2 and key2 == key

    from repro.core.tuner import ensemble_runtime_scores
    scores = ensemble_runtime_scores(ens, conv.space(), HW)
    assert scores.shape == (len(conv.space()),)
    assert np.all(scores >= 1.0 - 1e-12)     # relative: 1.0 = consensus best
    # deterministic: same committee, same ranking
    again = ensemble_runtime_scores(ens, conv.space(), HW)
    assert np.array_equal(np.argsort(scores, kind="stable"),
                          np.argsort(again, kind="stable"))


# =============================================================================
# TransferredWarmStart: distrust-and-verify
# =============================================================================
def _drain(searcher, runtime_of):
    """Run the ask-tell protocol to exhaustion; return visit order."""
    from repro.core.account import Observation

    visited = []
    while not searcher.done:
        cands = searcher.propose(4)
        if not cands:
            if searcher.done:
                break
            continue
        obs = [Observation(index=c.index, runtime=runtime_of(c.index))
               for c in cands]
        visited.extend(c.index for c in cands)
        searcher.observe(obs)
    return visited


def test_transferred_warm_start_trusts_a_good_order():
    space = TuningSpace([TuningParameter("X", tuple(range(16)))], name="s")
    order = list(range(16))              # exactly the true ranking
    s = TransferredWarmStart(space, order=order, seed=0, verify=3)
    visited = _drain(s, runtime_of=lambda i: float(i + 1))
    assert s.trusted is True
    assert visited[:3] == order[:3]      # head of the prior first
    probes = visited[3:6]
    # after the wave: the REST of the transferred order, in order
    rest = [i for i in order if i not in set(visited[:6])]
    assert visited[6:6 + len(rest)] == rest
    assert sorted(visited) == list(range(16))      # full coverage
    assert len(visited) == 16                      # no repeats


def test_transferred_warm_start_distrusts_a_bad_order():
    space = TuningSpace([TuningParameter("X", tuple(range(16)))], name="s")
    order = list(range(15, -1, -1))      # exactly backwards: worst first
    s = TransferredWarmStart(space, order=order, seed=0, verify=3)
    visited = _drain(s, runtime_of=lambda i: float(i + 1))
    assert s.trusted is False
    # after the wave the searcher abandons the transferred order for the
    # seed-shuffled walk — NOT the prior's (bad) continuation
    wave = visited[:6]
    after = visited[6:]
    assert after != [i for i in order if i not in set(wave)]
    assert sorted(visited) == list(range(16))
    assert len(visited) == 16


def test_transferred_warm_start_empty_order_is_plain_walk():
    space = TuningSpace([TuningParameter("X", tuple(range(8)))], name="s")
    s = TransferredWarmStart(space, seed=3)
    visited = _drain(s, runtime_of=float)
    assert sorted(visited) == list(range(8))


# =============================================================================
# Fleet integration + exact-path golden
# =============================================================================
def _run_fleet(store, transfer=True, kernel="conv2d", inp="4096", seed=0):
    pool = VirtualWorkerPool(workers=4)
    try:
        ft = FleetTuner(
            [job_from_registry(kernel, inp, "tpu_v5e", budget=20,
                               seed=seed)],
            pool, store=store, transfer=transfer, publish_models=False)
        report = ft.run()
    finally:
        pool.close()
    assert ft.train_errors == [], ft.train_errors
    return report.results[0]


def test_fleet_transfers_onto_never_seen_kernel():
    store = _store_with_kernel_models("matmul")
    res = _run_fleet(store, transfer=True)
    assert res.searcher == "transfer_warm_start"
    assert res.warm_started
    assert res.transfer_from is not None
    assert res.transfer_similarity >= DEFAULT_TRANSFER_THRESHOLD
    # the published entry records the provenance
    e = store.get("conv2d", "4096", "tpu_v5e", kind="kernel")
    assert e is not None
    assert e.meta["transfer_from"] == res.transfer_from
    assert e.meta["transfer_similarity"] == res.transfer_similarity


def test_fleet_no_transfer_flag_pins_legacy_ladder():
    store = _store_with_kernel_models("matmul")
    res = _run_fleet(store, transfer=False)
    assert res.searcher == "random"
    assert res.transfer_from is None and res.transfer_similarity is None


def test_exact_warm_start_trace_identical_with_transfer_enabled():
    """The transfer tier must be invisible when any legacy tier hits:
    bit-identical traces with transfer on and off."""
    base = _store_with_kernel_models("conv2d/4096")
    runs = {}
    for flag in (True, False):
        store = ConfigStore()
        store._models = dict(base._models)
        store._reindex_models()
        runs[flag] = _run_fleet(store, transfer=flag)
    on, off = runs[True], runs[False]
    assert on.searcher == off.searcher == "warm_start"
    assert on.trace == off.trace
    assert on.history == off.history
    assert on.best_index == off.best_index
    assert on.transfer_from is None and off.transfer_from is None


def test_cold_fleet_trace_identical_with_transfer_enabled():
    """Empty store: transfer enabled must change nothing about a cold
    run (there is nothing to transfer from)."""
    on = _run_fleet(ConfigStore(), transfer=True)
    off = _run_fleet(ConfigStore(), transfer=False)
    assert on.searcher == off.searcher == "random"
    assert on.trace == off.trace


# =============================================================================
# Service: source:"transfer" + similarity on the wire
# =============================================================================
def test_daemon_surfaces_transfer_source_and_stats():
    store = _store_with_kernel_models("matmul")
    d = TuningDaemon(VirtualWorkerPool(workers=4), store,
                     default_trial_budget=6)
    d.tuner.begin()
    r = d.handle(validate_request(dict(
        op="submit", kind="kernel", tenant="t", kernel="conv2d",
        input="4096", hardware="tpu_v5e")))
    assert r["ok"]
    rid = r["request_id"]
    for _ in range(2000):
        if d._records[rid].state == "done":
            break
        d._admit_pending()
        d.tuner.step(max_wait=0.01)
        d._meter()
    res = d.handle({"op": "result", "request_id": rid})
    assert res["ok"]
    assert res["source"] == "transfer"
    assert res["transfer_from"] is not None
    assert res["similarity"] >= DEFAULT_TRANSFER_THRESHOLD
    assert res["warm_started"]
    stats = d.handle({"op": "stats"})
    assert stats["transfers"] == 1
    assert stats["sources"].get("transfer") == 1
