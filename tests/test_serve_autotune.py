"""Online serving autotuner: shape buckets, ConfigStore persistence, drift
detection, warm-started live tuning, and the golden ask-tell trace."""
import json
import os

import numpy as np
import pytest
from test_serve import EchoModel

from repro.core.hwspec import SPECS
from repro.serve.autotune import (INFEASIBLE_S, EngineBackend,
                                  OnlineAutotuner, ServeWorkloadStats,
                                  ShapeBucketer, SyntheticServeBackend,
                                  serve_space, serve_workload_fn)
from repro.serve.engine import Request
from repro.tuning import TuningSession
from repro.tuning.store import ConfigStore, StoreEntry, store_key

HW_TRUE = SPECS["tpu_v4"]
HW_TRAIN = SPECS["tpu_v5e"]
STATS = ServeWorkloadStats()


def reqs(plen, new, n=8, uid0=0):
    return [Request(uid=uid0 + i, prompt=np.ones(plen, np.int32),
                    max_new_tokens=new) for i in range(n)]


def make_tuner(store=None, seed=0, **kw):
    backend = SyntheticServeBackend(HW_TRUE, STATS, seed=seed)
    tuner = OnlineAutotuner(
        backend, store=store, bucketer=ShapeBucketer(max_prompt=96,
                                                     max_new=32),
        hw=HW_TRUE, train_hw=HW_TRAIN, stats=STATS, seed=seed, **kw)
    return tuner, backend


# =============================================================================
# Shape buckets
# =============================================================================
def test_bucketer_deciles():
    b = ShapeBucketer(max_prompt=100, max_new=50)
    assert b.bucket_of(0, 0).key == "p0n0"
    assert b.bucket_of(19, 9).key == "p1n1"
    assert b.bucket_of(99, 49).key == "p9n9"
    assert b.bucket_of(500, 500).key == "p9n9"   # clamped to the top decile


def test_bucket_rep_shape_is_upper_edge():
    b = ShapeBucketer(max_prompt=100, max_new=50)
    bucket = b.bucket_of(12, 6)
    assert bucket.key == "p1n1"
    assert b.rep_shape(bucket) == (20, 10)


def test_serve_workload_fn_amortizes_weight_reads():
    wl = serve_workload_fn(16, 12, 6, STATS)
    rd1 = wl({"BATCH": 1, "MAX_SEQ": 32})["HBM_RD"]
    rd8 = wl({"BATCH": 8, "MAX_SEQ": 32})["HBM_RD"]
    assert rd8 < rd1 / 4  # fewer waves -> fewer weight streams


# =============================================================================
# ConfigStore
# =============================================================================
def test_store_key_rejects_separator():
    with pytest.raises(ValueError):
        store_key("a|b", "c", "d")


def test_config_store_round_trip(tmp_path):
    path = str(tmp_path / "store.json")
    store = ConfigStore(path)
    entry = store.put("serve_online", "p1n1", "tpu_v4",
                      config={"BATCH": 8, "MAX_SEQ": 32},
                      runtime=0.012, trials=6, meta={"history": [[15, 0.012]]})
    assert isinstance(entry, StoreEntry)
    # autosaved: a fresh store sees the entry
    again = ConfigStore(path)
    got = again.get("serve_online", "p1n1", "tpu_v4")
    assert got is not None
    assert got.config == {"BATCH": 8, "MAX_SEQ": 32}
    assert got.trials == 6
    assert got.meta["history"] == [[15, 0.012]]
    assert again.get("serve_online", "p9n9", "tpu_v4") is None
    # the file is schema-tagged JSON with kind-namespaced keys
    with open(path) as f:
        d = json.load(f)
    assert d["format"] == "repro.config_store" and d["version"] == 3
    assert set(d["entries"]) == {"serve|serve_online|p1n1|tpu_v4"}


def test_config_store_in_memory_has_no_file(tmp_path):
    store = ConfigStore()
    store.put("s", "b", "h", config={"X": 1}, runtime=1.0, trials=1)
    assert len(store) == 1
    with pytest.raises(ValueError):
        store.save()


def test_session_model_store_round_trip(tmp_path):
    """TuningSession <-> ConfigStore: train, persist, reload bound to the
    same space — the portable-model artifact survives the store."""
    space = serve_space()
    wl = serve_workload_fn(16, 20, 7, STATS)
    session = TuningSession(space, wl, hw=HW_TRUE, seed=0)
    session.train(train_hw=HW_TRAIN, kind="tree", sample="full")
    store = ConfigStore(str(tmp_path / "store.json"))
    session.save_model_to_store(store, "p1n1")
    fresh = TuningSession(space, wl, hw=HW_TRUE, seed=0)
    model = fresh.load_model_from_store(store, "p1n1")
    assert model is not None
    ref = session.model.predict(space[3])
    got = model.predict(space[3])
    assert got.keys() == ref.keys()
    for k in ref:
        assert got[k] == pytest.approx(ref[k])
    assert fresh.load_model_from_store(store, "p9n9") is None


# =============================================================================
# Online tuner: drift, retune, reuse
# =============================================================================
def test_first_tick_tunes_then_steady_state_is_free():
    tuner, backend = make_tuner()
    _, rep = tuner.serve(reqs(12, 6))
    assert rep.drift and not rep.reused
    assert 0 < rep.live_trials <= tuner.max_live_trials
    _, rep2 = tuner.serve(reqs(12, 6, uid0=100))
    assert not rep2.drift and rep2.live_trials == 0
    assert backend.measure_calls == rep.live_trials


def test_drift_triggers_retune_and_return_is_reuse():
    tuner, backend = make_tuner(window=8)
    _, r0 = tuner.serve(reqs(12, 6))
    _, r1 = tuner.serve(reqs(80, 28, uid0=100))
    assert r1.drift and not r1.reused and r1.live_trials > 0
    assert r1.config["MAX_SEQ"] >= 80 + 28  # feasible for the new bucket
    # the mix returns to the first bucket: store hit, zero live trials
    _, r2 = tuner.serve(reqs(12, 6, uid0=200))
    assert r2.drift and r2.reused and r2.live_trials == 0
    assert r2.config == r0.config


def test_store_persists_across_tuner_restarts(tmp_path):
    path = str(tmp_path / "store.json")
    tuner1, _ = make_tuner(store=ConfigStore(path))
    _, rep1 = tuner1.serve(reqs(12, 6))
    assert rep1.live_trials > 0
    # "restart": fresh tuner + backend over the same file
    tuner2, backend2 = make_tuner(store=ConfigStore(path))
    _, rep2 = tuner2.serve(reqs(12, 6))
    assert rep2.drift and rep2.reused and rep2.live_trials == 0
    assert rep2.config == rep1.config
    assert backend2.measure_calls == 0


def test_model_artifact_is_persisted(tmp_path):
    path = str(tmp_path / "store.json")
    store = ConfigStore(path)
    tuner, _ = make_tuner(store=store)
    tuner.serve(reqs(12, 6))
    assert store.get_model_dict("serve_online", "p1n1", "tpu_v4") is not None


def test_ranking_excludes_infeasible_max_seq():
    tuner, _ = make_tuner()
    bucket = tuner.bucketer.bucket_of(80, 28)
    plen, new = tuner.bucketer.rep_shape(bucket)
    for i in tuner.ranking(bucket):
        assert tuner.space[i]["MAX_SEQ"] >= plen + new


def test_live_trials_never_exceed_budget():
    tuner, _ = make_tuner(max_live_trials=3)
    _, rep = tuner.serve(reqs(12, 6))
    assert rep.live_trials <= 3


def test_oversize_top_decile_requests_tune_feasibly():
    """Regression: requests clamped into the top decile can exceed the
    bucket's representative edge; tuning must only trial configs the real
    calibration wave fits in (not persist an infeasible 'best')."""
    backend = SyntheticServeBackend(HW_TRUE, STATS, seed=0)
    tuner = OnlineAutotuner(
        backend, bucketer=ShapeBucketer(max_prompt=16, max_new=8),
        space=serve_space(batch_sizes=(1, 2, 4), max_seqs=(16, 32, 64)),
        hw=HW_TRUE, train_hw=HW_TRAIN, stats=STATS, seed=0)
    _, rep = tuner.serve(reqs(40, 8))    # plen 40 >> max_prompt 16
    assert rep.config["MAX_SEQ"] >= 48   # fits the real calibration wave
    entry = tuner.store.get(tuner.space.name, rep.bucket, "tpu_v4")
    assert entry.runtime < INFEASIBLE_S  # never persisted a garbage trial


# =============================================================================
# Golden ask-tell trace (fixed seed, deterministic fake engine)
# =============================================================================
# First drift event of the p1n1 bucket under seed 0: the warm_start searcher
# walks the portable model's predicted-runtime ranking; index = 5*batch_idx
# + seq_idx over BATCH (1,2,4,8,16) x MAX_SEQ (32,64,96,128,192).
GOLDEN_P1N1_TRIAL_ORDER = [15, 16, 10, 11, 12, 13, 14, 17]
GOLDEN_P1N1_CONFIG = {"BATCH": 8, "MAX_SEQ": 32}


def test_golden_ask_tell_trace():
    tuner, _ = make_tuner()
    _, rep = tuner.serve(reqs(12, 6))
    assert rep.bucket == "p1n1"
    assert [i for i, _ in rep.history] == GOLDEN_P1N1_TRIAL_ORDER
    assert rep.config == GOLDEN_P1N1_CONFIG
    # the winning trial's runtime is what the store records
    runtimes = dict(rep.history)
    best_idx = tuner.space.index_of(rep.config)
    assert min(runtimes.values()) == pytest.approx(runtimes[best_idx])


def test_golden_trace_is_reproducible():
    t1, _ = make_tuner()
    t2, _ = make_tuner()
    _, r1 = t1.serve(reqs(12, 6))
    _, r2 = t2.serve(reqs(12, 6))
    assert r1.history == r2.history
    assert r1.config == r2.config


# =============================================================================
# Backends
# =============================================================================
def test_synthetic_backend_is_deterministic_and_feasibility_aware():
    b1 = SyntheticServeBackend(HW_TRUE, STATS, seed=3)
    b2 = SyntheticServeBackend(HW_TRUE, STATS, seed=3)
    cfg = {"BATCH": 4, "MAX_SEQ": 64}
    r = reqs(12, 6)
    assert b1.measure(cfg, r) == b2.measure(cfg, r)
    assert b1.measure({"BATCH": 4, "MAX_SEQ": 32}, reqs(40, 12)) \
        == INFEASIBLE_S


def test_engine_backend_shares_params_and_warms_tail_waves():
    backend = EngineBackend(EchoModel(), seq_round=16)
    backend.measure({"BATCH": 2, "MAX_SEQ": 16}, reqs(3, 2, n=3))
    backend.measure({"BATCH": 2, "MAX_SEQ": 32}, reqs(3, 2, n=3))
    # 3 requests under batch 2 -> full wave (2) AND masked tail (1) warmed
    assert backend._warmed[(2, 16)] == {1, 2}
    # one parameter set shared by every trial engine
    assert all(e.params is backend.params for e in backend.engines.values())


def test_engine_backend_measures_and_serves_real_engine():
    backend = EngineBackend(EchoModel(), seq_round=16)
    cfg = {"BATCH": 2, "MAX_SEQ": 16}
    r = reqs(3, 2, n=3)
    dt = backend.measure(cfg, r)
    assert dt > 0.0
    assert backend.measure({"BATCH": 2, "MAX_SEQ": 4}, r) == INFEASIBLE_S
    out = backend.serve(cfg, r)
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 2 for v in out.values())
    # oversize stragglers bump the cache length instead of failing
    out2 = backend.serve({"BATCH": 2, "MAX_SEQ": 4}, r)
    assert set(out2) == {0, 1, 2}


def test_online_tuner_with_real_engine_backend(tmp_path):
    stats = ServeWorkloadStats(param_bytes=1e6, d_model=32, n_layers=2)
    backend = EngineBackend(EchoModel(), seq_round=16)
    store = ConfigStore(str(tmp_path / "store.json"))
    tuner = OnlineAutotuner(
        backend, store=store, bucketer=ShapeBucketer(max_prompt=8, max_new=4),
        space=serve_space(batch_sizes=(1, 2), max_seqs=(16, 32)),
        hw=SPECS["tpu_v5e"], stats=stats, max_live_trials=3, seed=0)
    out, rep = tuner.serve(reqs(4, 2, n=4))
    assert rep.drift and 0 < rep.live_trials <= 3
    assert all(len(v) == 2 for v in out.values())
    assert store.get(tuner.space.name, rep.bucket, "tpu_v5e") is not None


def test_empty_tick_is_a_noop():
    tuner, _ = make_tuner()
    out, rep = tuner.serve([])
    assert out == {} and rep is None
