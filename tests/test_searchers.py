"""Searcher behaviour: Algorithm 1, baselines, experiment harness."""
import numpy as np
import pytest

from repro.core import (BasinHoppingSearcher, ProfileBasedSearcher,
                        RandomSearcher, ReplayEvaluator, SPECS,
                        StarchartSearcher, record_space,
                        run_search_experiment, train_model)
from repro.kernels.registry import BENCHMARKS

HW = SPECS["tpu_v5e"]


@pytest.fixture(scope="module")
def gemm_recorded():
    bm = BENCHMARKS["matmul"]
    sp = bm.make_space()
    return record_space(sp, lambda c: bm.workload_fn(c, bm.default_input), HW)


def test_random_explores_without_replacement(gemm_recorded):
    s = RandomSearcher(gemm_recorded.space, seed=1)
    ev = ReplayEvaluator(gemm_recorded)
    s.search(ev, max_steps=50)
    assert ev.steps == 50
    assert len(ev.evaluated) == 50


def test_profile_searcher_runs_and_respects_budget(gemm_recorded):
    model = train_model(gemm_recorded, kind="exact")
    s = ProfileBasedSearcher(gemm_recorded.space, model, cores=HW.cores,
                             seed=2)
    ev = ReplayEvaluator(gemm_recorded)
    s.search(ev, max_steps=30)
    assert ev.steps <= 30
    assert ev.best_index is not None


def test_profile_beats_random_on_gemm(gemm_recorded):
    """The paper's core claim (Table 5), statistically, small-n."""
    model = train_model(gemm_recorded, kind="exact")
    st_p = run_search_experiment(
        lambda s: ProfileBasedSearcher(gemm_recorded.space, model,
                                       cores=HW.cores, seed=s),
        gemm_recorded, repeats=60)
    st_r = run_search_experiment(
        lambda s: RandomSearcher(gemm_recorded.space, seed=s),
        gemm_recorded, repeats=60)
    assert st_p.mean_steps < st_r.mean_steps


def test_portable_model_still_beats_random(gemm_recorded):
    """Model trained on v4 data, tuning on v5e (paper §4.4)."""
    bm = BENCHMARKS["matmul"]
    rec_v4 = record_space(gemm_recorded.space,
                          lambda c: bm.workload_fn(c, bm.default_input),
                          SPECS["tpu_v4"])
    model = train_model(rec_v4, kind="tree")
    st_p = run_search_experiment(
        lambda s: ProfileBasedSearcher(gemm_recorded.space, model,
                                       cores=HW.cores, seed=s),
        gemm_recorded, repeats=60)
    st_r = run_search_experiment(
        lambda s: RandomSearcher(gemm_recorded.space, seed=s),
        gemm_recorded, repeats=60)
    assert st_p.mean_steps < st_r.mean_steps


def test_basin_hopping_finds_well_performing(gemm_recorded):
    s = BasinHoppingSearcher(gemm_recorded.space, seed=3)
    ev = ReplayEvaluator(gemm_recorded)
    s.search(ev, max_steps=len(gemm_recorded.space))
    thresh = gemm_recorded.best_runtime * 1.1
    assert ev.best_runtime <= thresh * 2  # converges somewhere decent


def test_starchart_protocol(gemm_recorded):
    s = StarchartSearcher(gemm_recorded.space, seed=4)
    ev = ReplayEvaluator(gemm_recorded)
    s.search(ev, max_steps=len(gemm_recorded.space))
    assert s.model_build_steps > 0
    assert ev.steps >= s.model_build_steps


def test_exhaustive_budget_finds_optimum(gemm_recorded):
    for factory in (lambda: RandomSearcher(gemm_recorded.space, seed=5),):
        ev = ReplayEvaluator(gemm_recorded)
        factory().search(ev, max_steps=len(gemm_recorded.space))
        assert ev.best_runtime == pytest.approx(gemm_recorded.best_runtime)


def test_profiled_steps_cost_more_time(gemm_recorded):
    ev = ReplayEvaluator(gemm_recorded)
    t_fast = ev.measure(0)
    fast_elapsed = ev.elapsed
    ev2 = ReplayEvaluator(gemm_recorded)
    ev2.profile(0)
    assert ev2.elapsed > fast_elapsed


def test_profile_local_searcher(gemm_recorded):
    """Beyond-paper §3.9.1 extension: gradient-following local phase."""
    from repro.core.searcher import ProfileLocalSearcher
    model = train_model(gemm_recorded, kind="exact")
    st_l = run_search_experiment(
        lambda s: ProfileLocalSearcher(gemm_recorded.space, model,
                                       cores=HW.cores, seed=s),
        gemm_recorded, repeats=60)
    st_r = run_search_experiment(
        lambda s: RandomSearcher(gemm_recorded.space, seed=s),
        gemm_recorded, repeats=60)
    assert st_l.mean_steps < st_r.mean_steps
