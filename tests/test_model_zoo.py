"""All 10 assigned architectures: reduced-config smoke tests — one forward/
train step on CPU asserting output shapes + no NaNs — plus decode/prefill
consistency and serving-cache shape checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKES
from repro.models.config import SHAPES, shape_applicable
from repro.models.registry import build_model

RNG = jax.random.PRNGKey(0)
NPR = np.random.default_rng(0)


def _batch(cfg, b=2, s=16):
    batch = {
        "tokens": jnp.asarray(NPR.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(NPR.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            NPR.standard_normal((b, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            NPR.standard_normal((b, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_smoke_train_step(name):
    cfg = SMOKES[name]
    m = build_model(cfg)
    params = m.init(RNG)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: m.loss(p, batch, remat="none"))(params)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_decode_matches_prefill(name):
    cfg = SMOKES[name]
    m = build_model(cfg)
    params = m.init(RNG)
    B, S, MAX = 2, 8, 12
    toks = jnp.asarray(NPR.integers(0, cfg.vocab_size, (B, MAX)), jnp.int32)
    extra = {}
    if cfg.frontend == "audio":
        extra["frames"] = jnp.asarray(
            NPR.standard_normal((B, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    _, cache = m.prefill(params, {"tokens": toks[:, :S], **extra},
                         max_seq=MAX)
    for t in range(2):
        lg_dec, cache = m.decode(params, cache,
                                 {"tokens": toks[:, S + t:S + t + 1]})
        lg_ref, _ = m.prefill(params, {"tokens": toks[:, :S + t + 1],
                                       **extra}, max_seq=MAX)
        err = float(jnp.max(jnp.abs(lg_dec[:, 0] - lg_ref[:, -1])))
        scale = float(jnp.max(jnp.abs(lg_ref))) + 1e-9
        assert err / scale < 1e-4, f"{name} step {t}: rel {err/scale:.2e}"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_abstract_shapes(name):
    """FULL configs are exercised abstractly (no allocation)."""
    cfg = ARCHS[name]
    m = build_model(cfg)
    abstract = m.abstract()
    n = m.param_count()
    assert n > 1e8, name  # every assigned arch is at least 100M params
    specs = m.specs()
    flat_a = jax.tree.leaves(abstract)
    assert len(flat_a) > 0
    # every leaf has a spec of matching rank
    def walk(a, s):
        if isinstance(a, dict):
            for k in a:
                walk(a[k], s[k])
        else:
            assert len(s) == len(a.shape), (a.shape, s)
    walk(abstract, specs)


def test_param_counts_match_public_numbers():
    expect = {
        "deepseek-v2-236b": (236e9, 0.08),
        "llama4-scout-17b-a16e": (109e9, 0.05),
        "qwen2.5-3b": (3.1e9, 0.05),
        "command-r-plus-104b": (104e9, 0.05),
        "qwen1.5-0.5b": (0.46e9, 0.05),
        "gemma-2b": (2.5e9, 0.05),
        "zamba2-2.7b": (2.7e9, 0.15),
        "xlstm-125m": (0.125e9, 0.35),
        "internvl2-76b": (70e9, 0.05),   # LLM part only (ViT stubbed)
        "seamless-m4t-large-v2": (2.3e9, 0.15),
    }
    for name, (target, tol) in expect.items():
        n = build_model(ARCHS[name]).param_count()
        assert abs(n - target) / target < tol, (name, n / 1e9)


def test_shape_applicability_rules():
    assert shape_applicable(ARCHS["zamba2-2.7b"], SHAPES["long_500k"])[0]
    assert shape_applicable(ARCHS["xlstm-125m"], SHAPES["long_500k"])[0]
    assert not shape_applicable(ARCHS["gemma-2b"], SHAPES["long_500k"])[0]
    assert not shape_applicable(ARCHS["deepseek-v2-236b"],
                                SHAPES["long_500k"])[0]


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_input_specs_cover_all_shapes(name):
    cfg = ARCHS[name]
    m = build_model(cfg)
    for shape in SHAPES.values():
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        specs = m.input_specs(shape)
        assert "tokens" in specs
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)
