"""Async evaluation core + fleet orchestrator.

Covers the ISSUE 4 acceptance surface: the event-driven driver replays the
legacy sequential driver bit-identically at ``in_flight=1`` for every
registered searcher; out-of-order completions are accounted in completion
order; the ``FleetTuner`` shares one store across hardware targets and
warm-starts new arrivals from the nearest artifact in ≤ half the cold
trials; hardware naming drift maps to one store key; the subprocess worker
backend (slow) agrees with the in-process backends.
"""
import numpy as np
import pytest

from repro.core import SPECS, ReplayEvaluator, record_space, train_model
from repro.core.account import Candidate, EvalAccount
from repro.core.evaluate import VirtualAsyncEvaluator
from repro.core.hwspec import (fingerprint, get, hardware_key,
                               normalize_name)
from repro.core.searcher import (SEARCHERS, make_searcher, run_search,
                                 sequential_run_search)
from repro.fleet import (FleetTuner, ThreadWorkerPool, TuningJob,
                         VirtualWorkerPool, job_from_registry)
from repro.serve.autotune import (ServeWorkloadStats, serve_space,
                                  serve_workload_fn)
from repro.tuning import ConfigStore

HW = SPECS["tpu_v5e"]
STATS = ServeWorkloadStats()
BUCKET_SHAPES = {"p1n1": (16, 6), "p8n8": (80, 28), "p4n3": (40, 12)}


@pytest.fixture(scope="module")
def gemm_recorded():
    from repro.kernels.registry import BENCHMARKS

    bm = BENCHMARKS["matmul"]
    sp = bm.make_space()
    return record_space(sp, lambda c: bm.workload_fn(c, bm.default_input), HW)


# =============================================================================
# Golden: in_flight=1 event-driven == legacy sequential, full trace
# =============================================================================
@pytest.mark.parametrize("name", sorted(SEARCHERS))
def test_async_driver_golden_vs_sequential(name, gemm_recorded):
    """Every registered searcher: identical trace, history and account."""
    model = train_model(gemm_recorded, kind="exact")
    ctx = dict(model=model, cores=HW.cores)
    s_seq = make_searcher(name, gemm_recorded.space, seed=3, **ctx)
    s_evt = make_searcher(name, gemm_recorded.space, seed=3, **ctx)
    ev_seq, ev_evt = ReplayEvaluator(gemm_recorded), \
        ReplayEvaluator(gemm_recorded)
    sequential_run_search(s_seq, ev_seq, 40)
    run_search(s_evt, ev_evt, 40, in_flight=1)
    assert ev_evt.trace == ev_seq.trace            # bit-identical, full trace
    assert ev_evt.history() == ev_seq.history()
    assert ev_evt.best_index == ev_seq.best_index
    assert ev_evt.elapsed == ev_seq.elapsed


def test_run_search_rejects_bad_in_flight(gemm_recorded):
    s = make_searcher("random", gemm_recorded.space, seed=0)
    with pytest.raises(ValueError):
        run_search(s, ReplayEvaluator(gemm_recorded), 10, in_flight=0)


def test_run_search_in_flight_respects_budget(gemm_recorded):
    ev = VirtualAsyncEvaluator(ReplayEvaluator(gemm_recorded), workers=4)
    s = make_searcher("random", gemm_recorded.space, seed=2)
    run_search(s, ev, 17, in_flight=4)
    assert ev.steps == 17                      # outstanding drained, on budget
    assert ev.outstanding() == 0


# =============================================================================
# Out-of-order completion accounting
# =============================================================================
def test_account_records_completion_order():
    acct = EvalAccount()
    acct.record_completion(5, 3.0, cost=3.0, finished_at=3.0)
    acct.record_completion(4, 1.0, cost=9.0, finished_at=4.0)
    assert acct.steps == 2
    assert acct.elapsed == 4.0                 # completion frontier, not sum
    assert acct.busy == 12.0                   # worker-seconds ARE the sum
    assert acct.trace == [(1, 3.0, 3.0), (2, 4.0, 1.0)]
    assert acct.best_index == 4


def test_virtual_async_out_of_order(gemm_recorded):
    """A cheap config submitted after an expensive one finishes first."""
    ev = VirtualAsyncEvaluator(ReplayEvaluator(gemm_recorded), workers=2)
    rts = gemm_recorded.runtimes
    slow, fast = int(np.argmax(rts)), int(np.argmin(rts))
    ev.submit([Candidate(slow), Candidate(fast)])
    first = ev.collect()[0]
    second = ev.collect()[0]
    assert first.index == fast and second.index == slow
    times = [t for _, t, _ in ev.trace]
    assert times == sorted(times)              # trace in completion order
    assert ev.elapsed < ev.busy                # 2 lanes compressed the clock


def test_virtual_async_single_worker_matches_sequential(gemm_recorded):
    """workers=1 degrades to the sequential cost model exactly."""
    ev_async = VirtualAsyncEvaluator(ReplayEvaluator(gemm_recorded),
                                     workers=1)
    ev_seq = ReplayEvaluator(gemm_recorded)
    for idx in (3, 11, 7):
        ev_async.submit([Candidate(idx)])
        ev_async.collect()
        ev_seq.measure(idx)
    assert ev_async.trace == ev_seq.trace
    assert ev_async.elapsed == ev_seq.elapsed


def test_default_shim_submit_collect_matches_measure_many(gemm_recorded):
    ev_a, ev_b = ReplayEvaluator(gemm_recorded), ReplayEvaluator(gemm_recorded)
    cands = [Candidate(2), Candidate(9), Candidate(4)]
    ev_a.submit(cands)
    obs_a = ev_a.collect()
    obs_b = ev_b.measure_many(cands)
    assert obs_a == obs_b
    assert ev_a.trace == ev_b.trace
    assert ev_a.outstanding() == 0


# =============================================================================
# Fleet orchestration
# =============================================================================
def _serve_jobs(hw: str, budget: int = 25, seed: int = 7):
    jobs = []
    for bucket, (plen, new) in BUCKET_SHAPES.items():
        jobs.append(TuningJob(
            name=f"serve/{bucket}@{hw}", space=serve_space(),
            workload_fn=serve_workload_fn(16, plen, new, STATS),
            hardware=hw, bucket=bucket, budget=budget, seed=seed))
    return jobs


def _well_threshold(bucket: str, hw: str) -> float:
    plen, new = BUCKET_SHAPES[bucket]
    rec = record_space(serve_space(),
                       serve_workload_fn(16, plen, new, STATS), SPECS[hw])
    return rec.best_runtime * 1.1


def test_fleet_shares_store_and_warm_starts(tmp_path):
    """3 jobs × 2 hardware targets, one store: wave 2 warm-starts from the
    wave-1 artifacts and converges in ≤ half the cold trials."""
    store = ConfigStore(str(tmp_path / "fleet.json"))
    pool = VirtualWorkerPool(workers=4)
    rep1 = FleetTuner(_serve_jobs("tpu_v4"), pool, store=store,
                      in_flight=4).run()
    rep2 = FleetTuner(_serve_jobs("tpu_v5e"), pool, store=store,
                      in_flight=4).run()
    assert all(not r.warm_started for r in rep1.results)
    assert all(r.warm_started for r in rep2.results)
    assert len(store) == 6                        # one entry per job
    cold = warm = 0
    for hw, rep in (("tpu_v4", rep1), ("tpu_v5e", rep2)):
        for r in rep.results:
            t = r.trials_to_threshold(_well_threshold(r.bucket, hw))
            assert t is not None
            if r.warm_started:
                warm += t
            else:
                cold += t
    assert warm <= cold / 2                       # the amortization claim
    # the store survives a restart with both hardware keys populated
    again = ConfigStore(str(tmp_path / "fleet.json"))
    assert again.get("serve_online", "p1n1", "tpu_v4") is not None
    assert again.get("serve_online", "p1n1", "tpu_v5e") is not None


def test_fleet_wall_clock_beats_sequential():
    """Same jobs, same budgets: 4 workers compress the virtual wall-clock."""
    def run(workers):
        jobs = _serve_jobs("tpu_v4", budget=20)
        for j in jobs:
            j.searcher = "random"                 # identical work both ways
        pool = VirtualWorkerPool(workers=workers)
        return FleetTuner(jobs, pool, store=None, in_flight=workers,
                          publish_models=False).run()
    seq, fleet = run(1), run(4)
    assert abs(seq.busy - fleet.busy) < 1e-9      # identical measurements
    assert fleet.elapsed < seq.elapsed / 2        # ≥2x compressed (conserv.)


def test_fleet_thread_pool_runs():
    """ThreadWorkerPool end-to-end with a blocking eval_fn."""
    import time as _time

    def eval_fn(index, profile):
        _time.sleep(0.002)
        return 0.001 * (index + 1), None, 0.002
    jobs = [TuningJob(name=f"j{i}", space=serve_space(),
                      workload_fn=None, hardware="tpu_v4", budget=6,
                      seed=i, searcher="random", eval_fn=eval_fn)
            for i in range(3)]
    pool = ThreadWorkerPool(workers=4)
    try:
        rep = FleetTuner(jobs, pool, store=None,
                         publish_models=False).run()
    finally:
        pool.close()
    assert sorted(r.trials for r in rep.results) == [6, 6, 6]
    for r in rep.results:
        assert r.best_runtime == min(rt for _, rt in r.history)


def test_fleet_rejects_duplicate_job_names():
    jobs = _serve_jobs("tpu_v4")[:1] * 2
    with pytest.raises(ValueError):
        FleetTuner(jobs, VirtualWorkerPool(1))


def test_fleet_schedules_jobs_round_robin():
    """The first fill wave spreads lanes across jobs, not 2 lanes to one
    job and 0 to another (regression: cursor skew in the fill loop)."""
    submitted = []

    class RecordingPool(VirtualWorkerPool):
        def submit(self, item):
            submitted.append(item.job)
            super().submit(item)

    jobs = _serve_jobs("tpu_v4", budget=8)
    for j in jobs:
        j.searcher = "random"
    FleetTuner(jobs, RecordingPool(workers=4), store=None,
               in_flight=4, publish_models=False).run()
    names = [j.name for j in jobs]
    assert submitted[:4] == [names[0], names[1], names[2], names[0]]


def test_fleet_job_results_use_run_relative_clock():
    """A pool reused across runs must not leak its clock into per-job
    accounts: every job's elapsed stays within the run's own makespan."""
    pool = VirtualWorkerPool(workers=4)
    FleetTuner(_serve_jobs("tpu_v4", budget=10), pool, store=None,
               publish_models=False).run()
    rep2 = FleetTuner(_serve_jobs("tpu_v5e", budget=10), pool, store=None,
                      publish_models=False).run()
    for r in rep2.results:
        assert 0.0 < r.elapsed <= rep2.elapsed + 1e-12
        assert all(0.0 <= t <= rep2.elapsed + 1e-12
                   for _, t, _ in r.trace)


# =============================================================================
# Incremental fleet API (begin/step/finish, add/cancel/stop) — ISSUE 6
# =============================================================================
def test_fleet_incremental_loop_matches_run():
    """``run()`` is exactly begin + step-until-idle + finish; a manual
    incremental drive must produce bit-identical per-job results."""
    rep_run = FleetTuner(_serve_jobs("tpu_v4", budget=12),
                         VirtualWorkerPool(workers=4), store=None,
                         publish_models=False, in_flight=4).run()
    tuner = FleetTuner(_serve_jobs("tpu_v4", budget=12),
                       VirtualWorkerPool(workers=4), store=None,
                       publish_models=False, in_flight=4)
    tuner.begin()
    while tuner.step():
        pass
    rep_inc = tuner.finish()
    by_job = {r.job: r for r in rep_run.results}
    assert len(rep_inc.results) == len(rep_run.results)
    for r in rep_inc.results:
        ref = by_job[r.job]
        assert r.trace == ref.trace
        assert r.best_index == ref.best_index
        assert r.best_runtime == ref.best_runtime
    assert rep_inc.elapsed == rep_run.elapsed


def test_fleet_add_job_while_running():
    """A service fleet starts empty and takes jobs mid-flight."""
    done = []
    tuner = FleetTuner([], VirtualWorkerPool(workers=2), store=None,
                       publish_models=False, allow_empty=True,
                       on_job_done=lambda r: done.append(r.job))
    tuner.begin()
    jobs = _serve_jobs("tpu_v4", budget=6)
    tuner.add_job(jobs[0])
    for _ in range(4):
        tuner.step(max_wait=0.01)
    tuner.add_job(jobs[1])               # injected while job 0 is in flight
    while tuner.step(max_wait=0.01):
        pass
    rep = tuner.finish()
    assert sorted(done) == sorted(j.name for j in jobs[:2])
    assert all(not r.cancelled and r.trials == 6 for r in rep.results)
    with pytest.raises(ValueError):      # duplicate names still rejected
        tuner.add_job(jobs[0])


def test_fleet_cancel_job_mid_run(tmp_path):
    """Cancelling abandons in-flight tests, bills their cost, resolves a
    partial ``cancelled`` result, and publishes nothing for that job."""
    store = ConfigStore(str(tmp_path / "s.json"))
    jobs = _serve_jobs("tpu_v4", budget=20)[:2]    # < space size (25)
    tuner = FleetTuner(jobs, VirtualWorkerPool(workers=2), store=store,
                       in_flight=2)
    tuner.begin()
    for _ in range(3):
        tuner.step(max_wait=0.01)
    assert tuner.cancel_job(jobs[0].name)
    assert not tuner.cancel_job(jobs[0].name)     # already resolved
    assert not tuner.cancel_job("no_such_job")
    while tuner.step(max_wait=0.01):
        pass
    rep = tuner.finish()
    by_job = {r.job: r for r in rep.results}
    cancelled = by_job[jobs[0].name]
    survivor = by_job[jobs[1].name]
    assert cancelled.cancelled and cancelled.trials < 20
    assert not survivor.cancelled and survivor.trials == 20
    # only the surviving job published to the store
    assert store.get("serve_online", survivor.bucket, "tpu_v5e") is None
    assert store.get("serve_online", survivor.bucket, "tpu_v4") is not None
    assert store.get("serve_online", cancelled.bucket, "tpu_v4") is None


def test_fleet_stop_drains_in_flight():
    """``stop()`` collects what is already on the pool (billed to busy)
    but submits nothing new; unfinished jobs resolve as cancelled."""
    tuner = FleetTuner(_serve_jobs("tpu_v4", budget=40),
                       VirtualWorkerPool(workers=4), store=None,
                       publish_models=False, in_flight=4)
    tuner.begin()
    tuner.step(max_wait=0.01)
    assert not tuner.stopping
    tuner.stop()
    assert tuner.stopping
    while tuner.step(max_wait=0.01):
        pass
    rep = tuner.finish()
    assert all(r.cancelled for r in rep.results)
    assert all(r.trials < 40 for r in rep.results)
    total_trials = sum(r.trials for r in rep.results)
    assert 0 < total_trials <= 8         # first fill wave only (4 + refills)
    assert rep.busy > 0.0


def test_fleet_progress_snapshot():
    tuner = FleetTuner(_serve_jobs("tpu_v4", budget=6),
                       VirtualWorkerPool(workers=2), store=None,
                       publish_models=False)
    tuner.begin()
    p0 = tuner.progress()
    assert p0["jobs"] == 3 and p0["jobs_done"] == 0
    while tuner.step(max_wait=0.01):
        pass
    tuner.finish()
    p1 = tuner.progress()
    assert p1["jobs_done"] == 3
    assert p1["busy_s"] > 0.0 and 0.0 < p1["utilization"] <= 1.0


def test_unregistered_hardware_ships_spec_payload():
    """Fingerprint store keys can't be resolved by name in a worker
    subprocess, so payloads carry the spec's numbers instead."""
    import dataclasses as dc

    from repro.fleet.tuner import _JobState

    custom = dc.replace(SPECS["tpu_v4"], name="lab_chip")
    job = job_from_registry("matmul", "128", "tpu_v4", budget=4)
    job.hardware = custom
    js = _JobState(job)
    payload = js.payload_for(0, False)
    assert "hw" not in payload
    assert hwspec_roundtrip(payload["hw_spec"]) == custom
    # registered hardware still travels by (normalized) name
    js2 = _JobState(job_from_registry("matmul", "128", "TPUv4", budget=4))
    assert js2.payload_for(0, False)["hw"] == "tpu_v4"


def hwspec_roundtrip(d):
    from repro.core.hwspec import HardwareSpec
    return HardwareSpec(**d)


# =============================================================================
# Hardware naming drift / fingerprint keys
# =============================================================================
def test_hwspec_get_tolerates_naming_drift():
    assert get("TPUv4") is SPECS["tpu_v4"]
    assert get("tpu-v4") is SPECS["tpu_v4"]
    assert get("TPU_V5E") is SPECS["tpu_v5e"]
    with pytest.raises(KeyError):
        get("gtx_9000")


def test_hardware_key_normalizes():
    assert hardware_key("TPUv4") == "tpu_v4"
    assert hardware_key(SPECS["tpu_v4"]) == "tpu_v4"
    assert hardware_key("tpu_v4") == hardware_key("TPU-v4")
    assert normalize_name("My GPU (rev B)") == "my_gpu_rev_b"


def test_hardware_key_fingerprints_unregistered_spec():
    import dataclasses
    custom = dataclasses.replace(SPECS["tpu_v4"], name="lab_chip")
    key = hardware_key(custom)
    assert key == fingerprint(custom)
    assert "lab_chip" in key and key == hardware_key(custom)  # stable


def test_store_hits_survive_naming_drift(tmp_path):
    """The satellite's end-to-end claim: drifted names share entries."""
    store = ConfigStore(str(tmp_path / "s.json"))
    store.put("sp", "b", hardware_key("TPUv4"), config={"X": 1},
              runtime=1.0, trials=3)
    assert store.get("sp", "b", hardware_key("tpu_v4")) is not None
    assert store.get("sp", "b", hardware_key(SPECS["tpu_v4"])) is not None


# =============================================================================
# Nearest-model lookup
# =============================================================================
def test_nearest_model_preference_order(gemm_recorded):
    model = train_model(gemm_recorded, kind="tree")
    space = gemm_recorded.space
    store = ConfigStore()
    store.save_model(space.name, "bucketA", "hw1", model, space)
    store.save_model(space.name, "bucketB", "hw2", model, space)
    # exact
    assert store.nearest_model_key(space.name, "bucketA", "hw1") \
        == f"kernel|{space.name}|bucketA|hw1"
    # same bucket, other hardware beats same hardware, other bucket
    assert store.nearest_model_key(space.name, "bucketA", "hw2") \
        == f"kernel|{space.name}|bucketA|hw1"
    # same hardware, other bucket
    assert store.nearest_model_key(space.name, "bucketC", "hw2") \
        == f"kernel|{space.name}|bucketB|hw2"
    # any model of the space
    assert store.nearest_model_key(space.name, "bucketC", "hw9") \
        == f"kernel|{space.name}|bucketA|hw1"
    # unknown space: nothing
    assert store.nearest_model_key("other_space", "b", "h") is None
    m, key = store.load_nearest_model(space.name, "bucketA", "hw2",
                                      bind_space=space)
    assert m is not None and key.endswith("bucketA|hw1")


# =============================================================================
# Serving tuner through the async driver
# =============================================================================
def test_online_autotuner_in_flight_matches_sequential(tmp_path):
    """With the synchronous backend shim, in_flight>1 tunes identically."""
    from repro.serve.autotune import OnlineAutotuner, SyntheticServeBackend
    from repro.serve.engine import Request

    def run(in_flight, path):
        backend = SyntheticServeBackend(SPECS["tpu_v4"], STATS, seed=0)
        tuner = OnlineAutotuner(backend, store=ConfigStore(path),
                                hw=SPECS["tpu_v4"], stats=STATS,
                                in_flight=in_flight, seed=0)
        reqs = [Request(uid=i, prompt=np.ones(12, np.int32),
                        max_new_tokens=6) for i in range(8)]
        _, rep = tuner.serve(reqs)
        return rep
    r1 = run(1, str(tmp_path / "a.json"))
    r4 = run(4, str(tmp_path / "b.json"))
    assert r1.config == r4.config
    assert r1.history == r4.history


# =============================================================================
# Subprocess worker backend (slow: spawns interpreters)
# =============================================================================
@pytest.mark.slow
def test_subprocess_pool_matches_virtual():
    """2 worker processes, each with a 2-device jax host runtime, agree
    with the in-process virtual backend on what they measured."""
    from repro.fleet import SubprocessWorkerPool

    def jobs():
        return [job_from_registry("matmul", "128", hw, budget=8, seed=3,
                                  searcher="random")
                for hw in ("tpu_v4", "tpu_v5e")]

    pool = SubprocessWorkerPool(workers=2, devices_per_worker=2)
    try:
        rep_sub = FleetTuner(jobs(), pool, store=None,
                             publish_models=False).run()
    finally:
        pool.close()
    rep_virt = FleetTuner(jobs(), VirtualWorkerPool(workers=2), store=None,
                          publish_models=False).run()
    sub = {r.job: r for r in rep_sub.results}
    virt = {r.job: r for r in rep_virt.results}
    for name in sub:
        assert sub[name].trials == virt[name].trials
        # same configs measured to the same runtimes (cost model is pure)
        assert sorted(sub[name].history) == sorted(virt[name].history)
        assert sub[name].best_runtime == pytest.approx(
            virt[name].best_runtime)
