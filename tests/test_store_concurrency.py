"""ConfigStore under concurrent writers (ISSUE 4 satellite).

The regression scenario: two tuner processes open the same store file,
then each persists its own entry.  Before the read-merge-write ``save()``,
the second writer's atomic replace silently clobbered the first writer's
key (last-writer-wins on the whole file); with the file lock + merge, both
keys survive, and a conflicting key resolves to the better runtime.
"""
import json
import multiprocessing
import sys

import pytest

from repro.tuning import ConfigStore


def _writer(path: str, tag: int, barrier) -> None:
    store = ConfigStore(path)          # both load the (empty) file first
    barrier.wait(timeout=30)           # ...so neither has the other's key
    store.put("sp", f"bucket{tag}", "hw", config={"X": tag},
              runtime=1.0 + tag, trials=tag + 1)


def _conflict_writer(path: str, runtime: float, barrier) -> None:
    store = ConfigStore(path)
    barrier.wait(timeout=30)
    store.put("sp", "b", "hw", config={"RT": runtime}, runtime=runtime,
              trials=1)


@pytest.mark.skipif(sys.platform == "win32", reason="needs fork + flock")
def test_concurrent_writers_keep_both_entries(tmp_path):
    """Fails on pre-merge main: the slower writer clobbered the faster's
    entry and the final file held 1 entry instead of 2."""
    path = str(tmp_path / "store.json")
    ConfigStore(path).save()           # seed an empty store file
    ctx = multiprocessing.get_context("fork")
    barrier = ctx.Barrier(2)
    procs = [ctx.Process(target=_writer, args=(path, tag, barrier))
             for tag in (0, 1)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=60)
        assert p.exitcode == 0
    merged = ConfigStore(path)
    assert len(merged) == 2
    for tag in (0, 1):
        entry = merged.get("sp", f"bucket{tag}", "hw")
        assert entry is not None and entry.config == {"X": tag}


@pytest.mark.skipif(sys.platform == "win32", reason="needs fork + flock")
def test_conflicting_key_resolves_to_better_runtime(tmp_path):
    path = str(tmp_path / "store.json")
    ConfigStore(path).save()
    ctx = multiprocessing.get_context("fork")
    barrier = ctx.Barrier(2)
    procs = [ctx.Process(target=_conflict_writer, args=(path, rt, barrier))
             for rt in (2.0, 1.0)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=60)
        assert p.exitcode == 0
    entry = ConfigStore(path).get("sp", "b", "hw")
    assert entry is not None
    assert entry.runtime == 1.0        # the faster tuning result won


def test_merge_on_save_within_process(tmp_path):
    """Single-process view of the same semantics (no races involved)."""
    path = str(tmp_path / "store.json")
    a = ConfigStore(path)
    b = ConfigStore(path)              # opened before a writes anything
    a.put("sp", "bA", "hw", config={"X": 1}, runtime=1.0, trials=1)
    b.put("sp", "bB", "hw", config={"X": 2}, runtime=2.0, trials=1)
    # b's save merged a's entry from disk instead of clobbering it
    final = ConfigStore(path)
    assert len(final) == 2
    # ...and b's in-memory view absorbed it too (fleet-wide visibility)
    assert b.get("sp", "bA", "hw") is not None


def test_save_merge_false_overwrites(tmp_path):
    path = str(tmp_path / "store.json")
    a = ConfigStore(path)
    a.put("sp", "bA", "hw", config={"X": 1}, runtime=1.0, trials=1)
    fresh = ConfigStore()
    fresh.put("sp", "bB", "hw", config={"X": 2}, runtime=2.0, trials=1)
    fresh.save(path, merge=False)      # intentional reset
    final = ConfigStore(path)
    assert len(final) == 1 and final.get("sp", "bB", "hw") is not None


def _sharded_daemon_writer(root: str, tag: int, barrier) -> None:
    """One 'daemon': opens the shared sharded corpus, then publishes
    entries and revisioned model artifacts for keys overlapping the
    other daemon's."""
    from repro.service import ShardedConfigStore

    store = ShardedConfigStore(root, n_shards=3)
    barrier.wait(timeout=30)
    # disjoint keys: each daemon's private tenants
    store.put("sp", f"own{tag}", "hw", config={"X": tag},
              runtime=1.0 + tag, trials=1)
    # overlapping entry key: better runtime must win the merge
    store.put("sp", "shared", "hw", config={"RT": tag},
              runtime=2.0 - tag, trials=1)
    # overlapping model key: HIGHER revision must win the merge
    store.put_model_dict("sp", "shared", "hw",
                         {"format": "repro.tppc_model", "tag": tag},
                         revision=10 + tag)


@pytest.mark.skipif(sys.platform == "win32", reason="needs fork + flock")
def test_concurrent_daemons_share_sharded_corpus(tmp_path):
    """Two daemon processes over one sharded corpus: disjoint keys both
    survive, a conflicting entry resolves to the better runtime, and a
    conflicting model artifact resolves to the highest revision."""
    from repro.service import ShardedConfigStore

    root = str(tmp_path / "corpus")
    ctx = multiprocessing.get_context("fork")
    barrier = ctx.Barrier(2)
    procs = [ctx.Process(target=_sharded_daemon_writer,
                         args=(root, tag, barrier)) for tag in (0, 1)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=60)
        assert p.exitcode == 0
    merged = ShardedConfigStore(root, n_shards=3)
    assert len(merged) == 3            # own0, own1, shared
    for tag in (0, 1):
        assert merged.get("sp", f"own{tag}", "hw") is not None
    shared = merged.get("sp", "shared", "hw")
    assert shared is not None and shared.runtime == 1.0   # tag=1's result
    model = merged.get_model_dict("sp", "shared", "hw")
    assert model is not None and model["revision"] == 11  # highest revision
    assert model["tag"] == 1


@pytest.mark.skipif(sys.platform == "win32", reason="needs fork + flock")
def test_sharded_corpus_shard_count_agreement(tmp_path):
    """A second opener requesting a different shard count adopts the
    recorded one — both processes must partition keys identically."""
    from repro.service import ShardedConfigStore

    root = str(tmp_path / "corpus")
    first = ShardedConfigStore(root, n_shards=5)
    second = ShardedConfigStore(root, n_shards=2)
    assert first.n_shards == second.n_shards == 5
    first.put("sp", "b", "hw", config={"X": 1}, runtime=1.0, trials=1)
    assert ShardedConfigStore(root).get("sp", "b", "hw") is not None


def test_save_refuses_to_merge_foreign_file(tmp_path):
    path = str(tmp_path / "store.json")
    with open(path, "w") as f:
        json.dump({"format": "something_else", "version": 9}, f)
    store = ConfigStore()
    store.put("sp", "b", "hw", config={"X": 1}, runtime=1.0, trials=1)
    with pytest.raises(ValueError):
        store.save(path)
    # explicit merge=False is the documented escape hatch
    store.save(path, merge=False)
    assert ConfigStore(path).get("sp", "b", "hw") is not None
