"""Golden equivalence: the array-native scoring engine vs the scalar path.

The vectorization PR must change SPEED only.  This suite pins:

* ``predict_matrix`` == per-config ``predict`` for all three model families;
* ``score_space`` == a ``score_configuration`` loop, bit for bit;
* the inlined weighted draw == ``Generator.choice``, same rng stream;
* vectorized ``ProfileBasedSearcher``/``ProfileLocalSearcher`` traces ==
  the frozen scalar implementations (``repro.core._scalar_reference``),
  step for step at fixed seeds;
* the array-backed space (O(1) ``index_of``, hashed ``neighbours``,
  vectorized deliberate sampling) == the original full scans.

Runs on a jax-free synthetic recorded space so it stays fast in CI.
"""
import numpy as np
import pytest

from repro.core import (DecisionTreeModel, ExactCounterModel,
                        QuadraticRegressionModel, ReplayEvaluator, SPECS,
                        TuningParameter, TuningSpace,
                        deliberate_training_sample, prediction_matrix,
                        run_search)
from repro.core import counters as C
from repro.core import scoring
from repro.core._scalar_reference import (ScalarProfileBasedSearcher,
                                          ScalarProfileLocalSearcher,
                                          scalar_neighbours)
from repro.core.counters import CounterSet, PC_OPS, PC_STRESS
from repro.core.evaluate import RecordedSpace
from repro.core.searcher import ProfileBasedSearcher, ProfileLocalSearcher

CORES = SPECS["tpu_v5e"].cores


def make_space():
    return TuningSpace([
        TuningParameter("bx", (1, 2, 4, 8, 16, 32)),
        TuningParameter("by", (1, 2, 4, 8)),
        TuningParameter("unroll", (1, 2, 4)),
        TuningParameter("layout", ("row", "col")),
        TuningParameter("vec", (0, 1)),
    ], constraints=[lambda c: c["bx"] * c["by"] <= 128])


@pytest.fixture(scope="module")
def recorded():
    rng = np.random.default_rng(7)
    sp = make_space()
    counters, runtimes = [], np.empty(len(sp))
    for i, cfg in enumerate(sp):
        scale = 2.0 if cfg["vec"] else 1.0
        ops = {
            C.HBM_RD: scale * (1e6 / cfg["bx"] + 1e4 * cfg["by"]),
            C.HBM_WR: 1e5 + 1e3 * cfg["unroll"],
            C.VMEM_RD: 1e5 * cfg["bx"] * cfg["by"],
            C.MXU_FLOPS: 4e8,
            C.VPU_OPS: 1e5 * cfg["unroll"],
            C.ISSUE_OPS: 1e5 * (cfg["bx"] + cfg["by"]),
            C.GRID: float(4096 // (cfg["bx"] * cfg["by"])),
            C.VMEM_WS: 4096.0 * cfg["bx"] * cfg["by"],
        }
        stress = {k: float(rng.random()) for k in PC_STRESS}
        rt = float(1e-3 + 1e-4 * abs(cfg["bx"] - 8) + 1e-4 * rng.random())
        counters.append(CounterSet(ops=ops, stress=stress, runtime=rt))
        runtimes[i] = rt
    return RecordedSpace(space=sp, runtimes=runtimes, counters=counters,
                         hw=SPECS["tpu_v5e"], input_tag="golden_synth")


def _models(recorded):
    sp = recorded.space
    ops = recorded.ops_list()
    return {
        "exact": ExactCounterModel(sp, ops),
        "tree": DecisionTreeModel(sp, list(sp), ops,
                                  rng=np.random.default_rng(0)),
        "quadratic": QuadraticRegressionModel(sp, list(sp), ops),
    }


# =============================================================================
# predict_matrix == predict, per config per counter
# =============================================================================
@pytest.mark.parametrize("kind", ["exact", "tree", "quadratic"])
def test_predict_matrix_matches_predict(recorded, kind):
    model = _models(recorded)[kind]
    sp = recorded.space
    names, M = prediction_matrix(model, sp)
    assert M.shape == (len(sp), len(names))
    for i, cfg in enumerate(sp):
        d = model.predict(cfg)
        for j, name in enumerate(names):
            assert M[i, j] == pytest.approx(d.get(name, 0.0),
                                            rel=1e-12, abs=1e-12), \
                (kind, i, name)
    # tree and exact models are replay-exact, not just close
    if kind in ("exact", "tree"):
        for i, cfg in enumerate(sp):
            d = model.predict(cfg)
            for j, name in enumerate(names):
                assert M[i, j] == d.get(name, 0.0)


def test_prediction_matrix_is_cached_and_readonly(recorded):
    model = _models(recorded)["exact"]
    names1, m1 = prediction_matrix(model, recorded.space)
    names2, m2 = prediction_matrix(model, recorded.space)
    assert m1 is m2 and names1 == names2
    with pytest.raises(ValueError):
        m1[0, 0] = 1.0


def test_minimal_tppc_subclass_still_searches(recorded):
    """A TPPCModel subclass implementing only predict() (the documented
    minimal interface) must keep working with the matrix-backed searchers."""
    from repro.core.model import TPPCModel

    class Minimal(TPPCModel):
        def __init__(self, inner):
            self.inner = inner

        def predict(self, cfg):
            return self.inner.predict(cfg)

    inner = _models(recorded)["exact"]
    model = Minimal(inner)
    names, M = prediction_matrix(model, recorded.space)
    ref_names, ref = prediction_matrix(inner, recorded.space)
    for name in names:
        assert np.array_equal(M[:, names.index(name)],
                              ref[:, ref_names.index(name)])
    ev = ReplayEvaluator(recorded)
    run_search(ProfileBasedSearcher(recorded.space, model=model,
                                    cores=CORES, seed=0), ev, 20)
    assert ev.steps == 20


def test_deliberate_sample_mixed_type_parameter():
    """Feature codes alias 'b' and 1 — the sample must match raw values."""
    sp = TuningSpace([TuningParameter("x", ("a", "b", 1, 2, 3)),
                      TuningParameter("y", (0, 1))])
    got = deliberate_training_sample(sp, values_per_param=2,
                                     rng=np.random.default_rng(0))
    keep = {"a", 3}  # endpoints of the declared list
    expect = [i for i, cfg in enumerate(sp) if cfg["x"] in keep]
    assert got == expect


def test_prediction_matrix_duck_typed_model(recorded):
    class Wrapped:  # only .predict — e.g. a third-party surrogate
        def __init__(self, inner):
            self.inner = inner

        def predict(self, cfg):
            return self.inner.predict(cfg)

    model = _models(recorded)["exact"]
    names, M = prediction_matrix(Wrapped(model), recorded.space)
    ref_names, ref = prediction_matrix(model, recorded.space)
    for name in names:
        j, rj = names.index(name), ref_names.index(name)
        assert np.array_equal(M[:, j], ref[:, rj])


# =============================================================================
# score_space == score_configuration loop (bitwise)
# =============================================================================
@pytest.mark.parametrize("kind", ["exact", "tree", "quadratic"])
def test_score_space_matches_scalar_loop_bitwise(recorded, kind):
    model = _models(recorded)[kind]
    sp = recorded.space
    names, M = prediction_matrix(model, sp)
    cols = {n: j for j, n in enumerate(names)}
    rng = np.random.default_rng(3)
    for trial in range(5):
        delta = {k: float(rng.uniform(-1, 1)) for k in PC_OPS}
        for k in list(delta)[:: 3]:
            delta[k] = 0.0  # exercise the dpc == 0 skip
        prof = int(rng.integers(len(sp)))
        vec = scoring.score_space(delta, M[prof], M, cols)
        prof_pred = model.predict(sp[prof])
        for i in range(len(sp)):
            ref = scoring.score_configuration(delta, prof_pred,
                                              model.predict(sp[i]))
            if kind == "quadratic":  # dgemm vs dot: equal to fp round-off
                assert vec[i] == pytest.approx(ref, rel=1e-12, abs=1e-12)
            else:
                assert vec[i] == ref, (trial, i)


def test_weighted_choice_replicates_generator_choice():
    """The inlined cdf draw must stay bit-compatible with rng.choice —
    identical picks from identical streams (guards numpy-version drift)."""
    n = 517
    base = np.random.default_rng(11)
    weights = base.random(n) * 256.0
    mask = base.random(n) > 0.2
    r_ours, r_np = np.random.default_rng(5), np.random.default_rng(5)
    w = np.where(mask, weights, 0.0)
    p = w / w.sum()
    for _ in range(500):
        ours = scoring.weighted_choice(weights, r_ours, mask)
        ref = int(r_np.choice(n, p=p))
        assert ours == ref


# =============================================================================
# searcher traces: vectorized == frozen scalar implementation
# =============================================================================
@pytest.mark.parametrize("kind", ["exact", "tree"])
@pytest.mark.parametrize("budget", [13, 60, 10**9])
def test_profile_searcher_trace_identical(recorded, kind, budget):
    model = _models(recorded)[kind]
    budget = min(budget, len(recorded.space))
    for seed in range(6):
        ev_s = ReplayEvaluator(recorded)
        run_search(ScalarProfileBasedSearcher(
            recorded.space, model=model, cores=CORES, seed=seed),
            ev_s, budget)
        ev_v = ReplayEvaluator(recorded)
        run_search(ProfileBasedSearcher(
            recorded.space, model=model, cores=CORES, seed=seed),
            ev_v, budget)
        assert ev_s.trace == ev_v.trace, (kind, seed, budget)


@pytest.mark.parametrize("kind", ["exact", "tree"])
def test_profile_local_searcher_trace_identical(recorded, kind):
    model = _models(recorded)[kind]
    for seed in range(6):
        ev_s = ReplayEvaluator(recorded)
        run_search(ScalarProfileLocalSearcher(
            recorded.space, model=model, cores=CORES, seed=seed),
            ev_s, 60)
        ev_v = ReplayEvaluator(recorded)
        run_search(ProfileLocalSearcher(
            recorded.space, model=model, cores=CORES, seed=seed),
            ev_v, 60)
        assert ev_s.trace == ev_v.trace, (kind, seed)


def test_quadratic_model_steers_both_engines(recorded):
    """Quadratic predictions differ from the scalar path only at fp
    round-off (dgemm vs dot) — both engines must still search sanely."""
    model = _models(recorded)["quadratic"]
    for seed in range(3):
        ev_v = ReplayEvaluator(recorded)
        run_search(ProfileBasedSearcher(
            recorded.space, model=model, cores=CORES, seed=seed), ev_v, 40)
        assert ev_v.steps == 40
        assert ev_v.best_runtime < np.inf


# =============================================================================
# array-backed space == original scans
# =============================================================================
def test_feature_matrix_matches_vectorize():
    sp = make_space()
    fm = sp.feature_matrix
    assert fm.shape == (len(sp), len(sp.parameters))
    for i, cfg in enumerate(sp):
        assert fm[i].tolist() == sp.vectorize(cfg)
    with pytest.raises(ValueError):
        fm[0, 0] = 99.0


def test_index_of_matches_linear_scan():
    sp = make_space()
    for i, cfg in enumerate(sp):
        assert sp.index_of(dict(cfg)) == i
    with pytest.raises(KeyError):
        sp.index_of({"bx": 3, "by": 1, "unroll": 1, "layout": "row",
                     "vec": 0})
    with pytest.raises(KeyError):
        sp.index_of({"bx": 1})  # wrong key set


def test_neighbours_match_full_scan():
    sp = make_space()
    for idx in range(len(sp)):
        assert sp.neighbours(idx) == scalar_neighbours(sp, idx)


def test_deliberate_sample_matches_scalar_scan():
    sp = make_space()

    def scalar_sample(space, values_per_param, seed):
        rng = np.random.default_rng(seed)
        keep = {}
        for p in space.nonbinary_parameters:
            vals = list(p.values)
            if len(vals) <= values_per_param:
                keep[p.name] = set(vals)
            else:
                picks = {vals[0], vals[-1]}
                if values_per_param >= 3:
                    picks.add(vals[len(vals) // 2])
                while len(picks) < values_per_param:
                    picks.add(vals[int(rng.integers(len(vals)))])
                keep[p.name] = picks
        return [i for i, cfg in enumerate(space)
                if all(cfg[n] in keep[n] for n in keep)]

    for vpp in (2, 3):
        got = deliberate_training_sample(
            sp, values_per_param=vpp, rng=np.random.default_rng(1))
        assert got == scalar_sample(sp, vpp, 1)


def test_exact_model_from_pairs_shuffled_order(recorded):
    """from_pairs must remap record order to space order exactly once."""
    sp = recorded.space
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(sp))
    configs = [sp[int(i)] for i in perm]
    counters = [recorded.counters[int(i)].ops for i in perm]
    model = ExactCounterModel.from_pairs(sp, configs, counters)
    for i in (0, 5, len(sp) - 1):
        assert model.predict(sp[i]) == dict(recorded.counters[i].ops)
        assert model.predict_index(i) == dict(recorded.counters[i].ops)
    names, M = prediction_matrix(model, sp)
    j = names.index(C.HBM_RD)
    expect = [recorded.counters[i].ops[C.HBM_RD] for i in range(len(sp))]
    assert np.array_equal(M[:, j], np.asarray(expect))
