"""repro: profile-counter-guided autotuning for a multi-pod JAX/TPU framework.

Reproduction of Filipovič et al. (2021), "Using hardware performance counters
to speed up autotuning convergence on GPUs", adapted to TPU and integrated as
a first-class feature of a JAX training/serving framework.  See README.md.
"""

__version__ = "1.0.0"
