"""Distributed train step: loss → grads (with microbatch accumulation and
remat) → clip → (optional int8 cross-pod compression) → AdamW update.

The step is a pure function over ``TrainState``; distribution comes entirely
from shardings (FSDP over data, TP over model, gradients reduced by GSPMD;
cross-pod traffic optionally compressed via distributed/compression.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim.adamw import (AdamW, AdamWState, apply_updates,
                               clip_by_global_norm)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class StepConfig:
    remat: str = "nothing_saveable"
    microbatches: int = 1
    loss_chunks: int = 1
    kv_chunk: int = 1024
    clip_norm: float = 1.0
    compress_cross_pod: bool = False


def init_train_state(model: Model, optimizer: AdamW,
                     rng: jax.Array) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(model: Model, optimizer: AdamW) -> TrainState:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    return jax.eval_shape(
        lambda k: init_train_state(model, optimizer, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def make_train_step(
    model: Model, optimizer: AdamW, step_cfg: StepConfig = StepConfig(),
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    def loss_fn(params, mb):
        kw = dict(remat=step_cfg.remat, loss_chunks=step_cfg.loss_chunks)
        if not (model.cfg.xlstm or model.cfg.mamba_per_attn
                or model.cfg.enc_layers):
            kw["kv_chunk"] = step_cfg.kv_chunk
        return model.loss(params, mb, **kw)

    def train_step(state: TrainState, batch: Dict):
        mbs = step_cfg.microbatches
        if mbs == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((mbs, x.shape[0] // mbs) + x.shape[1:]),
                batch)

            def mb_body(carry, mb):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_l + l, acc_g), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(
                mb_body, (jnp.float32(0.0), zero_g), split)
            loss = loss / mbs
            grads = jax.tree.map(lambda g: g / mbs, grads)

        if step_cfg.compress_cross_pod:
            from repro.distributed.compression import quantize_dequantize_tree
            grads = quantize_dequantize_tree(grads)

        grads, gnorm = clip_by_global_norm(grads, step_cfg.clip_norm)
        updates, opt = optimizer.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": optimizer.lr(opt.count)}
        return new_state, metrics

    return train_step
