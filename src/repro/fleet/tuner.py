"""``FleetTuner`` — many tuning jobs, one worker pool, one shared store.

The paper's value proposition is amortization: sample counters once,
converge fast on *other* inputs and *other* GPUs.  The fleet orchestrator
operationalizes that at deployment scale:

* every ``TuningJob`` (kernel × input bucket × hardware) gets its own
  ask-tell searcher and its own completion-ordered ``EvalAccount``;
* one worker pool evaluates candidates from ALL jobs concurrently — when a
  job's searcher is between batches, its workers serve other jobs, so the
  fleet's wall-clock approaches ``total busy work / workers``;
* one concurrency-safe ``ConfigStore`` collects tuned configs and trained
  TP→PC_ops model artifacts under ``(space, bucket, hardware)`` keys;
* a job with no explicit searcher warm-starts from the NEAREST stored
  artifact (exact key → same bucket on other hardware → same hardware on
  another bucket → same space), walking the model's predicted-runtime
  ranking on its own hardware — so adding a device or a shape to the fleet
  costs a handful of trials instead of a fresh search; with no artifact it
  falls back to its ``cold_searcher`` and, on completion, trains and
  publishes the missing model for the next arrival.

Scheduling is round-robin over jobs with unfilled budgets, keeping up to
``in_flight`` tests outstanding pool-wide; completions are drained one at a
time and fed back to the owning searcher, so the loop is event-driven end
to end (no barrier between jobs or between batches of one job).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import costmodel, hwspec
from repro.core.account import EvalAccount, Observation
from repro.core.hwspec import HardwareSpec
from repro.core.model import TPPCModel
from repro.core.searcher import WarmStartSearcher, make_searcher
from repro.core.tuner import predicted_runtimes
from repro.core.tuning_space import TuningSpace
from repro.fleet.job import JobResult, TuningJob
from repro.fleet.pool import WorkItem
from repro.tuning.session import TuningSession
from repro.tuning.store import ConfigStore


def predicted_runtime_order(model: TPPCModel, space: TuningSpace,
                            hw: HardwareSpec) -> List[int]:
    """Config indices best-predicted-first: the portable model's PC_ops
    predictions priced through the cost model on the target hardware — the
    ranking a warm-started job walks."""
    return [int(i) for i in
            np.argsort(predicted_runtimes(model, space, hw), kind="stable")]


@dataclasses.dataclass
class FleetReport:
    """What one ``FleetTuner.run()`` did, across all jobs."""

    results: List[JobResult]
    elapsed: float       # pool wall-clock consumed by this run (makespan)
    busy: float          # worker-seconds across all jobs
    in_flight: int
    workers: int

    def by_job(self) -> Dict[str, JobResult]:
        return {r.job: r for r in self.results}


class _JobState:
    """Orchestrator-side bookkeeping for one job."""

    def __init__(self, job: TuningJob):
        self.job = job
        self.account = EvalAccount()
        self.searcher = None
        self.searcher_name = ""
        self.warm_started = False
        self.submitted = 0
        self.pending = 0
        self.done = False
        self.result: Optional[JobResult] = None
        self.hw = job.hw_spec()
        self.hw_key = job.hardware_key

    def payload_for(self, index: int, profile: bool) -> Optional[dict]:
        if self.job.kernel is None:
            return None
        p = {"kernel": self.job.kernel, "input": self.job.input_key,
             "index": int(index), "profile": bool(profile)}
        if self.hw_key in hwspec.SPECS:
            p["hw"] = self.hw_key
        else:
            # fingerprint keys aren't resolvable by name on the worker
            # side — ship the spec's declared numbers instead
            p["hw_spec"] = dataclasses.asdict(self.hw)
        return p


class FleetTuner:
    """Schedule many ``TuningJob``s over one pool and one shared store.

    ``in_flight`` defaults to the pool's worker count — more keeps lanes
    busy across searcher latencies, fewer throttles.  ``publish_models``
    makes cold jobs train and store the portable TP→PC_ops model for their
    key on completion (the artifact later arrivals warm-start from).
    """

    def __init__(self, jobs: Sequence[TuningJob], pool,
                 store: Optional[ConfigStore] = None,
                 in_flight: Optional[int] = None,
                 publish_models: bool = True,
                 model_kind: str = "tree",
                 verbose: bool = False):
        if not jobs:
            raise ValueError("FleetTuner needs at least one job")
        names = [j.name for j in jobs]
        if len(set(names)) != len(names):
            raise ValueError(f"job names must be unique, got {names}")
        self.jobs = list(jobs)
        self.pool = pool
        self.store = store
        self.in_flight = int(in_flight if in_flight is not None
                             else pool.workers)
        self.publish_models = publish_models
        self.model_kind = model_kind
        self.verbose = verbose
        self._uid = 0

    # -- per-job setup ---------------------------------------------------------
    def _start(self, js: _JobState) -> None:
        """Bind a searcher on first schedule: explicit name, or warm-start
        from the nearest stored artifact, or the cold fallback."""
        if js.searcher is not None:
            return
        job = js.job
        model = None
        if self.store is not None:
            model, key = self.store.load_nearest_model(
                job.space.name, job.bucket, js.hw_key, bind_space=job.space)
            if model is not None and self.verbose:
                print(f"[fleet] {job.name}: warm start from {key}")
        if job.searcher is not None:
            js.searcher_name = job.searcher
            js.searcher = make_searcher(
                job.searcher, job.space, seed=job.seed,
                model=model, cores=js.hw.cores)
        elif model is not None:
            js.warm_started = True
            js.searcher_name = "warm_start"
            js.searcher = WarmStartSearcher(
                job.space,
                order=predicted_runtime_order(model, job.space, js.hw),
                seed=job.seed)
        else:
            js.searcher_name = job.cold_searcher
            js.searcher = make_searcher(job.cold_searcher, job.space,
                                        seed=job.seed)

    def _eval_fn(self, js: _JobState, index: int, profile: bool):
        """Pure measurement closure for in-process pools: the job's
        portable workload priced through the cost model on its hardware,
        with the replay cost structure (profiled tests pay the multi-pass
        slowdown)."""
        from repro.core.evaluate import (PROFILE_FIXED, PROFILE_SLOWDOWN,
                                         TEST_OVERHEAD)

        if js.job.eval_fn is not None:
            custom = js.job.eval_fn
            return lambda: custom(index, profile)

        space, wl, hw = js.job.space, js.job.workload_fn, js.hw

        def fn():
            cs = costmodel.execute(wl(space[index]), hw)
            rt = float(cs.runtime)
            if profile:
                return rt, cs, rt * PROFILE_SLOWDOWN + TEST_OVERHEAD \
                    + PROFILE_FIXED
            return rt, None, rt + TEST_OVERHEAD

        return fn

    # -- the event loop --------------------------------------------------------
    def run(self) -> FleetReport:
        states = [_JobState(j) for j in self.jobs]
        by_name = {js.job.name: js for js in states}
        n = len(states)
        t_start = self.pool.elapsed()
        rr = 0
        while True:
            # saturate the pool: a rotating cursor over jobs, advanced one
            # position per visit (a submit resumes scanning at the NEXT
            # job, so lanes spread fairly); stop once a full lap produced
            # nothing — no job can offer work right now
            fruitless = 0
            while self.pool.outstanding() < self.in_flight and fruitless < n:
                js = states[rr]
                rr = (rr + 1) % n
                if js.done or js.submitted >= js.job.budget:
                    fruitless += 1
                    continue
                self._start(js)
                cands = js.searcher.propose(1)
                if not cands:
                    # waiting on its batch (pending > 0) or exhausted
                    if js.pending == 0 and js.searcher.done:
                        self._finalize(js)
                    fruitless += 1
                    continue
                c = cands[0]
                self.pool.submit(WorkItem(
                    uid=self._uid, job=js.job.name, index=c.index,
                    profile=c.profile,
                    fn=self._eval_fn(js, c.index, c.profile),
                    payload=js.payload_for(c.index, c.profile)))
                self._uid += 1
                js.submitted += 1
                js.pending += 1
                fruitless = 0
            if self.pool.outstanding() == 0:
                break       # nothing running and nothing schedulable
            res = self.pool.collect()
            js = by_name[res.job]
            js.pending -= 1
            # job accounts run on THIS run's clock (the pool may have
            # served earlier runs), so per-job elapsed stays comparable to
            # the report's makespan
            js.account.record_completion(res.index, res.runtime, res.cost,
                                         res.finished_at - t_start)
            js.searcher.observe([Observation(
                index=res.index, runtime=res.runtime, counters=res.counters,
                step=js.account.steps, elapsed=js.account.elapsed)])
            if js.pending == 0 and js.submitted >= js.job.budget:
                self._finalize(js)
        for js in states:   # jobs whose searcher dried up mid-fill
            if not js.done:
                self._finalize(js)
        results = [js.result for js in states]
        return FleetReport(
            results=results,
            elapsed=self.pool.elapsed() - t_start,
            busy=float(sum(r.busy for r in results)),
            in_flight=self.in_flight,
            workers=self.pool.workers)

    # -- completion ------------------------------------------------------------
    def _finalize(self, js: _JobState) -> None:
        job, acct = js.job, js.account
        if acct.best_index is None:
            raise RuntimeError(f"job {job.name} made no empirical tests "
                               "(budget <= 0 or empty space?)")
        js.done = True
        js.result = JobResult(
            job=job.name, bucket=job.bucket, hardware=js.hw_key,
            searcher=js.searcher_name, warm_started=js.warm_started,
            best_index=acct.best_index,
            best_config=dict(job.space[acct.best_index]),
            best_runtime=acct.best_runtime, trials=acct.steps,
            elapsed=acct.elapsed, busy=acct.busy,
            trace=list(acct.trace), history=list(acct.history))
        if self.store is None:
            return
        # batch the entry + model artifact into ONE locked read-merge-write
        # (each autosave re-parses the whole file — at fleet scale two per
        # completion is measurable lock/IO churn on the event loop)
        was_autosave, self.store.autosave = self.store.autosave, False
        try:
            self.store.put(
                job.space.name, job.bucket, js.hw_key,
                config=js.result.best_config, runtime=acct.best_runtime,
                trials=acct.steps,
                meta={"job": job.name, "searcher": js.searcher_name,
                      "warm_started": js.warm_started})
            if self.publish_models and self.store.get_model_dict(
                    job.space.name, job.bucket, js.hw_key) is None:
                # train the portable TP→PC_ops model this job was missing
                # and publish it — the next (input, hardware) arrival
                # warm-starts from it
                session = TuningSession(job.space, job.workload_fn,
                                        hw=js.hw, seed=job.seed)
                session.train(kind=self.model_kind, sample="deliberate")
                session.save_model_to_store(self.store, job.bucket,
                                            js.hw_key)
        finally:
            self.store.autosave = was_autosave
        if was_autosave and self.store.path is not None:
            self.store.save()
        if self.verbose:
            print(f"[fleet] {job.name}: best {acct.best_runtime*1e3:.3f}ms "
                  f"in {acct.steps} trials "
                  f"({'warm' if js.warm_started else 'cold'})")
