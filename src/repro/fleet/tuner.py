"""``FleetTuner`` — many tuning jobs, one worker pool, one shared store.

The paper's value proposition is amortization: sample counters once,
converge fast on *other* inputs and *other* GPUs.  The fleet orchestrator
operationalizes that at deployment scale:

* every ``TuningJob`` (kernel × input bucket × hardware) gets its own
  ask-tell searcher and its own completion-ordered ``EvalAccount``;
* one worker pool evaluates candidates from ALL jobs concurrently — when a
  job's searcher is between batches, its workers serve other jobs, so the
  fleet's wall-clock approaches ``total busy work / workers``;
* one concurrency-safe ``ConfigStore`` collects tuned configs and trained
  TP→PC_ops model artifacts under ``(space, bucket, hardware)`` keys;
* a job with no explicit searcher warm-starts from the NEAREST stored
  artifact (exact key → same bucket on other hardware → same hardware on
  another bucket → same space → compatible spaces, each rebound through
  the shared-counter intersection and blended as a similarity-weighted
  committee), walking the prior's predicted-runtime ranking on its own
  hardware — so adding a device or a shape to the fleet costs a handful
  of trials instead of a fresh search; a CROSS-SPACE prior additionally
  runs a distrust-and-verify first wave (``TransferredWarmStart``) so a
  misleading transfer costs at most one wave.  With no artifact at all
  the job falls back to its ``cold_searcher`` and, on completion, trains
  and publishes the missing model for the next arrival.

Scheduling is PRIORITY dispatch by predicted remaining gain: a job backed
by a stored TP→PC artifact knows its model-predicted best runtime on its
own hardware, so ``current best − predicted best`` estimates how much
latency further convergence is still buying; the scheduler spends lanes on
the job with the most left to gain (cold jobs with no artifact rank
highest — their gain is unknown).  Ties (and the all-cold fleet) break
least-recently-scheduled, which degenerates to the fair round-robin of
the pre-priority scheduler.  With ``park_factor`` set, a model-backed job
whose measured best is already within that factor of its predicted best is
PARKED — it stops consuming budget, freeing lanes for jobs still
converging — and is unparked if a model published later in the run shows
there was more gain to be had than its stale artifact predicted.

Failure handling (the fleet no longer dies on its first crashed config):
worker pools surface failed tests as ``FailedResult`` data, and the
orchestrator retries each failed test up to ``retries`` times on another
lane (exclude-and-resubmit).  A config whose measurement itself fails
``known_bad_after`` times is marked KNOWN-BAD: it resolves as an
``inf``-runtime row in the job's trace/history (so budgets terminate and
convergence curves stay honest) and is reported in ``JobResult.known_bad``.
With ``straggler_factor`` set, a test outstanding longer than that factor
times the job's rolling per-kind completion-latency estimate (submit →
finish on the pool clock, so IPC/queueing overhead is in the baseline) is
timed out: resubmitted elsewhere, and the eventual late result dropped.  Every discarded attempt's
worker-seconds are charged through ``EvalAccount.record_abandoned`` — they
appear in ``busy`` (and ``abandoned_s``) because the lanes really were
burned.

``in_flight`` may be ELASTIC: with ``in_flight_max`` set, an
``ElasticInFlight`` controller grows/shrinks the outstanding-work target
between the bounds from pool backpressure (live lane count) and the
variance of observed measurement costs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel, hwspec
from repro.core.account import EvalAccount, Observation
from repro.core.evaluate import ElasticInFlight
from repro.core.hwspec import HardwareSpec
from repro.core.model import TPPCModel, TransferEnsemble
from repro.core.searcher import (TransferredWarmStart, WarmStartSearcher,
                                 make_searcher)
from repro.core.tuner import ensemble_runtime_scores, predicted_runtimes
from repro.core.tuning_space import TuningSpace
from repro.fleet.job import JobResult, TuningJob
from repro.fleet.pool import FAIL_TEST, WorkItem

_INF = float("inf")

# Absolute floor on straggler deadlines: on real pools a sub-millisecond
# test can be delayed tens of milliseconds by OS scheduling/IPC jitter
# alone, which is noise, not straggling — never time out below this.
# Virtual clocks have no jitter and their test costs sit above the floor.
STRAGGLER_MIN_TIMEOUT = 0.05


def predicted_runtime_order(model: TPPCModel, space: TuningSpace,
                            hw: HardwareSpec) -> List[int]:
    """Config indices best-predicted-first: the portable model's PC_ops
    predictions priced through the cost model on the target hardware — the
    ranking a warm-started job walks."""
    return [int(i) for i in
            np.argsort(predicted_runtimes(model, space, hw), kind="stable")]


def _whole_space_scores(model, space: TuningSpace,
                        hw: HardwareSpec) -> np.ndarray:
    """Warm-start ranking scores for either prior shape: absolute
    predicted runtimes for a native/exact model, the committee's relative
    scores for a cross-space ``TransferEnsemble`` (only the argsort of
    the latter is meaningful — see ``ensemble_runtime_scores``)."""
    if isinstance(model, TransferEnsemble):
        return ensemble_runtime_scores(model, space, hw)
    return predicted_runtimes(model, space, hw)


@dataclasses.dataclass
class FleetReport:
    """What one ``FleetTuner.run()`` did, across all jobs."""

    results: List[JobResult]
    elapsed: float       # pool wall-clock consumed by this run (makespan)
    busy: float          # worker-seconds across all jobs (incl. abandoned)
    in_flight: int
    workers: int
    abandoned: float = 0.0       # worker-seconds of discarded attempts
    failures: int = 0            # failed attempts across all jobs
    timeouts: int = 0            # stragglers timed out and resubmitted
    known_bad: int = 0           # configs marked known-bad fleet-wide
    parked: int = 0              # jobs parked by the gain scheduler
    max_retries_used: int = 0    # highest attempt number any test needed
    in_flight_max: Optional[int] = None   # elastic upper bound (None: fixed)

    def by_job(self) -> Dict[str, JobResult]:
        return {r.job: r for r in self.results}


@dataclasses.dataclass
class _InFlight:
    """One logical empirical test currently on the pool."""

    js: "_JobState"
    index: int
    profile: bool
    attempt: int
    exclude: Tuple[int, ...]
    submitted_at: float      # absolute pool clock at submission


class _TrainerThread:
    """Background executor for the fleet's model work (ISSUE 9).

    Two task kinds ride the same bounded queue: **prep** (warm-start
    whole-space prediction for a job about to bind its searcher) and
    **train** (the TP→PC model a finished cold job publishes).  Both are
    pure compute over read-only inputs — every store read/write stays on
    the event-loop thread, which applies completions via ``get``.  A
    task that raises is delivered as an error, never as a dead thread:
    the loop contains the failure to that one job/publish and keeps
    dispatching (trainer-crash containment).
    """

    def __init__(self, maxsize: int = 8):
        self._in: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self._out: "queue.Queue" = queue.Queue()
        # submitted-not-yet-applied; touched only by the loop thread
        self.pending = 0
        self._thread = threading.Thread(target=self._loop,
                                        name="fleet-trainer", daemon=True)
        self._thread.start()

    def submit(self, tag: str, js: "_JobState",
               fn: Callable[[], Any]) -> None:
        """Enqueue one task (blocks when the bounded queue is full —
        backpressure on a loop outrunning the trainer)."""
        self.pending += 1
        self._in.put((tag, js, fn))

    def _loop(self) -> None:
        while True:
            task = self._in.get()
            if task is None:
                return
            tag, js, fn = task
            try:
                self._out.put((tag, js, fn(), None))
            except BaseException as exc:
                self._out.put((tag, js, None, exc))

    def get(self, block: bool = False, timeout: Optional[float] = None):
        """One ``(tag, js, result, error)`` completion, or None when
        nothing is ready within the wait."""
        try:
            if block:
                item = self._out.get(timeout=timeout)
            else:
                item = self._out.get_nowait()
        except queue.Empty:
            return None
        self.pending -= 1
        return item

    def close(self, timeout: float = 5.0) -> None:
        self._in.put(None)
        if self._thread is not threading.current_thread():
            self._thread.join(timeout=timeout)


class _JobState:
    """Orchestrator-side bookkeeping for one job."""

    def __init__(self, job: TuningJob):
        self.job = job
        self.account = EvalAccount()
        self.searcher = None
        self.searcher_name = ""
        self.warm_started = False
        # warm-start prep pipeline: None (not started) -> "pending"
        # (whole-space prediction on the trainer thread) -> "done"
        self.prep_state: Optional[str] = None
        self.prep_model = None
        self.prep_key: Optional[str] = None
        self.pred = None
        # cross-space transfer provenance (set when the warm start came
        # from the store's compatible-space tier)
        self.transfer_key: Optional[str] = None
        self.transfer_similarity: Optional[float] = None
        self.submitted = 0
        self.pending = 0
        self.done = False
        self.result: Optional[JobResult] = None
        self.hw = job.hw_spec()
        self.hw_key = job.hardware_key
        # fault-tolerance / scheduling state
        self.retry_queue: List[Tuple[int, bool, int, Tuple[int, ...]]] = []
        self.fail_counts: Dict[int, int] = {}
        self.known_bad: List[int] = []
        self.failures = 0
        self.timeouts = 0
        # rolling per-kind completion-LATENCY window (submit→finish on
        # the pool clock, so IPC/queueing overhead is part of the
        # baseline; profiled tests are ~5x plain, so one shared window
        # would false-flag every profile as a straggler)
        self.lat_window: Dict[bool, List[float]] = {False: [], True: []}
        self.predicted_best: Optional[float] = None
        self.parked = False
        self.was_parked = False
        self.last_pick = 0

    def payload_for(self, index: int, profile: bool) -> Optional[dict]:
        if self.job.kernel is None:
            return None
        p = {"kernel": self.job.kernel, "input": self.job.input_key,
             "index": int(index), "profile": bool(profile)}
        if self.hw_key in hwspec.SPECS:
            p["hw"] = self.hw_key
        else:
            # fingerprint keys aren't resolvable by name on the worker
            # side — ship the spec's declared numbers instead
            p["hw_spec"] = dataclasses.asdict(self.hw)
        return p

    def note_latency(self, profile: bool, latency: float) -> None:
        w = self.lat_window[profile]
        w.append(latency)
        if len(w) > 8:
            w.pop(0)

    def latency_estimate(self, profile: bool) -> Optional[float]:
        """Straggler baseline: the MAX over the recent latency window —
        real pools see scheduling/IPC hiccups far above the median, and a
        mean-style estimate false-flags them; armed only after 3
        completions of the kind so one early sample can't set a hair
        trigger.  ``None`` disarms the timeout for this (job, kind)."""
        w = self.lat_window[profile]
        if len(w) < 3:
            return None
        return max(w)


class FleetTuner:
    """Schedule many ``TuningJob``s over one pool and one shared store.

    ``in_flight`` defaults to the pool's worker count — more keeps lanes
    busy across searcher latencies, fewer throttles; ``in_flight_max``
    makes it elastic between the two bounds.  ``publish_models`` makes cold
    jobs train and store the portable TP→PC_ops model for their key on
    completion (the artifact later arrivals warm-start from).

    Fault policy: ``retries`` bounds resubmissions per logical test;
    ``known_bad_after`` measurement failures of one config mark it
    known-bad; ``straggler_factor`` (None: disabled) times out tests
    outstanding longer than ``factor ×`` the job's rolling cost estimate.
    ``park_factor`` (None: disabled) parks model-backed jobs whose best is
    already within that factor of their predicted best runtime.
    """

    def __init__(self, jobs: Sequence[TuningJob], pool,
                 store=None,
                 in_flight: Optional[int] = None,
                 publish_models: bool = True,
                 model_kind: str = "tree",
                 verbose: bool = False,
                 retries: int = 2,
                 known_bad_after: int = 2,
                 straggler_factor: Optional[float] = None,
                 park_factor: Optional[float] = None,
                 in_flight_max: Optional[int] = None,
                 allow_empty: bool = False,
                 on_job_done=None,
                 on_trial=None,
                 train_async: bool = True,
                 train_queue: int = 8,
                 transfer: bool = True,
                 transfer_threshold: Optional[float] = None):
        if not jobs and not allow_empty:
            raise ValueError("FleetTuner needs at least one job "
                             "(allow_empty=True for a service fleet that "
                             "injects jobs while running)")
        names = [j.name for j in jobs]
        if len(set(names)) != len(names):
            raise ValueError(f"job names must be unique, got {names}")
        self.jobs = list(jobs)
        self.pool = pool
        self.store = store
        self.in_flight = int(in_flight if in_flight is not None
                             else pool.workers)
        if in_flight_max is not None and in_flight_max < self.in_flight:
            raise ValueError(
                f"in_flight_max must be >= in_flight, got "
                f"{in_flight_max} < {self.in_flight}")
        self.in_flight_max = in_flight_max
        self.publish_models = publish_models
        self.model_kind = model_kind
        self.verbose = verbose
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        self.retries = int(retries)
        self.known_bad_after = int(known_bad_after)
        self.straggler_factor = straggler_factor
        self.park_factor = park_factor
        self.on_job_done = on_job_done
        # fires after EVERY resolved empirical test with
        # (job_name, trials_so_far, best_runtime) — the service journals
        # these as progress checkpoints so a crashed daemon resumes an
        # interrupted job with only its REMAINING budget
        self.on_trial = on_trial
        self._uid = 0
        self._states: List[_JobState] = []
        self._by_name: Dict[str, _JobState] = {}
        self._inflight: Dict[int, _InFlight] = {}
        self._abandoned: Dict[int, _JobState] = {}
        self._pick_seq = 0
        self._max_attempt = 0
        self._began = False
        self._stopping = False
        self._t_start = 0.0
        self._elastic: Optional[ElasticInFlight] = None
        self._limit = self.in_flight
        # off-loop model training (ISSUE 9): warm-start prediction and
        # publish-time training run on a trainer thread; the loop keeps
        # dispatching and applies completions between ticks
        self.train_async = bool(train_async)
        self.train_queue = int(train_queue)
        self._trainer: Optional[_TrainerThread] = None
        self.train_errors: List[Tuple[str, str]] = []
        # cross-space model transfer: when ALL exact-space warm-start
        # tiers miss, try the store's signature-indexed compatible-space
        # tier before going cold (transfer=False pins the legacy ladder)
        self.transfer = bool(transfer)
        if transfer_threshold is None:
            from repro.tuning.signature import DEFAULT_TRANSFER_THRESHOLD
            transfer_threshold = DEFAULT_TRANSFER_THRESHOLD
        self.transfer_threshold = float(transfer_threshold)
        # (space, kind) -> publishes still training: jobs of that space
        # defer binding until the model they would have seen is out
        self._publish_keys: Dict[Tuple[str, str], int] = {}

    # -- per-job setup ---------------------------------------------------------
    def _start(self, js: _JobState) -> bool:
        """Bind a searcher on first schedule: explicit name, or warm-start
        from the nearest stored artifact, or the cold fallback.  A loaded
        model also prices the job's predicted best runtime — the gain
        estimate the priority scheduler and parking policy run on.

        Returns False while the job is NOT yet schedulable: its
        whole-space warm-start prediction is still on the trainer
        thread, or a model publish for its (space, kind) is still
        training (binding now would miss the artifact the synchronous
        path would have seen).  The caller skips the job this tick and
        the fleet keeps dispatching other work meanwhile."""
        if js.searcher is not None:
            return True
        t0 = self.pool.elapsed()
        job = js.job
        if js.prep_state == "pending":
            return False
        if js.prep_state is None:
            if self._trainer is not None and self._publish_keys.get(
                    (job.space.name, job.kind), 0) > 0:
                return False
            model, key = (None, None)
            if self.store is not None:
                model, key = self.store.load_nearest_model(
                    job.space.name, job.bucket, js.hw_key,
                    bind_space=job.space, kind=job.kind)
            if model is None and job.searcher is None:
                # all four exact-space tiers missed: try the store's
                # signature-indexed compatible-space tier (a model from
                # a structurally similar space, rebound through the
                # shared-counter intersection)
                model, key = self._load_transfer(js)
            js.prep_model, js.prep_key = model, key
            if model is not None and self._trainer is not None:
                space, hw = job.space, js.hw
                js.prep_state = "pending"
                self._trainer.submit(
                    "prep", js,
                    lambda: _whole_space_scores(model, space, hw))
                return False
            if model is not None:
                js.pred = _whole_space_scores(model, job.space, js.hw)
            js.prep_state = "done"
        model, pred = js.prep_model, js.pred
        if model is not None and pred is not None:
            if js.transfer_key is None:
                # a borrowed model's ABSOLUTE scale is not trustworthy on
                # a space it was never fit on: transferred jobs keep gain
                # unknown (rank like cold, never park on the prior)
                js.predicted_best = float(np.min(pred))
            if self.verbose:
                print(f"[fleet] {job.name}: warm start from {js.prep_key}")
        if job.searcher is not None:
            js.searcher_name = job.searcher
            js.searcher = make_searcher(
                job.searcher, job.space, seed=job.seed,
                model=model, cores=js.hw.cores)
        elif model is not None and pred is not None:
            js.warm_started = True
            order = [int(i) for i in np.argsort(pred, kind="stable")]
            if js.transfer_key is not None:
                # transferred prior: distrust-and-verify first wave, so
                # a misleading cross-space ranking costs at most one wave
                js.searcher_name = "transfer_warm_start"
                js.searcher = TransferredWarmStart(
                    job.space, order=order, seed=job.seed)
            else:
                js.searcher_name = "warm_start"
                js.searcher = WarmStartSearcher(
                    job.space, order=order, seed=job.seed)
        else:
            # going cold: any transfer candidacy died in prep (failed
            # whole-space prediction) — drop the provenance with it
            js.transfer_key = None
            js.transfer_similarity = None
            js.searcher_name = job.cold_searcher
            js.searcher = make_searcher(job.cold_searcher, job.space,
                                        seed=job.seed)
        js.prep_model = None          # the searcher owns it from here
        js.pred = None
        self._absorb_stall(t0)
        return True

    def _load_transfer(self, js: _JobState):
        """Compatible-space prior for a job every exact tier missed:
        sign the job's space (counters sampled from one pure workload
        evaluation) and ask the store for the similarity-weighted
        committee over EVERY same-kind artifact above the threshold
        (``load_transfer_ensemble``; a store exposing only the single
        best via ``load_transfer_model`` still works).  Provenance
        reports the top member.  Failures are contained to this job
        (recorded in ``train_errors``) — it just goes cold, exactly as
        if the tier had missed."""
        if not self.transfer or self.store is None \
                or not (hasattr(self.store, "load_transfer_ensemble")
                        or hasattr(self.store, "load_transfer_model")):
            return None, None
        job = js.job
        try:
            from repro.tuning.signature import SpaceSignature

            counters = ()
            if job.workload_fn is not None and len(job.space):
                counters = sorted(job.workload_fn(job.space[0]))
            sig = SpaceSignature.from_space(job.space, kind=job.kind,
                                            counters=counters)
            loader = getattr(self.store, "load_transfer_ensemble",
                             self.store.load_transfer_model)
            model, key, sim = loader(
                sig, job.bucket, js.hw_key, bind_space=job.space,
                threshold=self.transfer_threshold)
        except Exception as exc:
            self.train_errors.append((job.name, f"transfer: {exc!r}"))
            if self.verbose:
                print(f"[fleet] {job.name}: transfer lookup failed "
                      f"({exc!r}); going cold")
            return None, None
        if model is None:
            return None, None
        js.transfer_key = key
        js.transfer_similarity = float(sim)
        if self.verbose:
            n = len(model) if isinstance(model, TransferEnsemble) else 1
            print(f"[fleet] {job.name}: cross-space transfer from {key} "
                  f"(similarity {sim:.3f}, committee of {n})")
        return model, key

    def _apply_prep(self, js: _JobState, pred, error) -> None:
        """Trainer completion for a warm-start prediction (loop thread).
        A failed prediction falls back to the cold searcher — contained
        to this job, recorded, never fatal to the loop."""
        if error is not None:
            self.train_errors.append((js.job.name, f"prep: {error!r}"))
            if self.verbose:
                print(f"[fleet] {js.job.name}: warm-start prep failed "
                      f"({error!r}); going cold")
            js.prep_model = None
            pred = None
        js.pred = pred
        js.prep_state = "done"

    def _eval_fn(self, js: _JobState, index: int, profile: bool):
        """Pure measurement closure for in-process pools: the job's
        portable workload priced through the cost model on its hardware,
        with the replay cost structure (profiled tests pay the multi-pass
        slowdown)."""
        from repro.core.evaluate import (PROFILE_FIXED, PROFILE_SLOWDOWN,
                                         TEST_OVERHEAD)

        if js.job.eval_fn is not None:
            custom = js.job.eval_fn
            return lambda: custom(index, profile)

        space, wl, hw = js.job.space, js.job.workload_fn, js.hw

        def fn():
            cs = costmodel.execute(wl(space[index]), hw)
            rt = float(cs.runtime)
            if profile:
                return rt, cs, rt * PROFILE_SLOWDOWN + TEST_OVERHEAD \
                    + PROFILE_FIXED
            return rt, None, rt + TEST_OVERHEAD

        return fn

    def _absorb_stall(self, t0: float) -> None:
        """True orchestrator work (store put/save at finalize, searcher
        binding) stalls the event loop while in-flight tests keep aging
        on the real pool clock — their results may already sit
        uncollected in the queue.  Shift their submission stamps by the
        stall so the straggler timeout only measures time the POOL
        spent, not time we did.  The former big offenders — model
        training at finalize, whole-space prediction at warm start —
        now run on the trainer thread and no longer stall the loop at
        all (``train_async=False`` restores the inline behavior, still
        covered here).  (Virtual pools don't advance during orchestrator
        work, so this is a no-op there.)
        """
        stall = self.pool.elapsed() - t0
        if stall > 0.0:
            for info in self._inflight.values():
                info.submitted_at += stall

    # -- scheduling ------------------------------------------------------------
    def _alive(self) -> int:
        alive = getattr(self.pool, "alive_workers", None)
        return int(alive()) if alive is not None else int(self.pool.workers)

    def _priority(self, js: _JobState) -> float:
        """Predicted remaining gain: how much latency convergence is still
        buying this job.  Cold jobs (no artifact) rank highest — their gain
        is unknown, and exploring them also produces the artifacts that
        sharpen everyone else's estimate."""
        if js.predicted_best is None:
            return _INF
        return max(0.0, js.account.best_runtime - js.predicted_best)

    def _pick(self, skip: set) -> Optional[_JobState]:
        """Highest-gain schedulable job; ties break least-recently-picked
        (which reduces to fair round-robin for an all-cold fleet)."""
        best, best_key = None, None
        for js in self._states:
            if js.done or js.parked or js in skip:
                continue
            if not js.retry_queue and js.submitted >= js.job.budget:
                continue
            key = (self._priority(js), -js.last_pick)
            if best is None or key > best_key:
                best, best_key = js, key
        return best

    def _submit(self, js: _JobState, index: int, profile: bool,
                attempt: int, exclude: Tuple[int, ...]) -> None:
        uid = self._uid
        self._uid += 1
        self._max_attempt = max(self._max_attempt, attempt)
        self._inflight[uid] = _InFlight(
            js=js, index=index, profile=profile, attempt=attempt,
            exclude=exclude, submitted_at=self.pool.elapsed())
        self.pool.submit(WorkItem(
            uid=uid, job=js.job.name, index=index, profile=profile,
            fn=self._eval_fn(js, index, profile),
            payload=js.payload_for(index, profile),
            attempt=attempt, exclude=exclude))

    def _fill(self, limit: int) -> None:
        """Saturate the pool up to ``limit`` logical tests, highest
        predicted gain first; retries of failed tests go out before new
        candidates of the same job."""
        skip: set = set()
        while len(self._inflight) < limit:
            js = self._pick(skip)
            if js is None:
                return
            if js.retry_queue:
                index, profile, attempt, exclude = js.retry_queue.pop(0)
                self._submit(js, index, profile, attempt, exclude)
                js.last_pick = self._next_pick()
                continue
            if not self._start(js):
                # warm-start prep (or a blocking publish) still on the
                # trainer thread: other jobs get the lanes meanwhile
                skip.add(js)
                continue
            cands = js.searcher.propose(1)
            if not cands:
                # waiting on its batch (pending > 0) or exhausted
                if js.pending == 0 and js.searcher.done:
                    self._finalize(js)
                skip.add(js)
                continue
            c = cands[0]
            self._submit(js, c.index, c.profile, 0, ())
            js.submitted += 1
            js.pending += 1
            js.last_pick = self._next_pick()

    def _next_pick(self) -> int:
        self._pick_seq += 1
        return self._pick_seq

    # -- completion handling ---------------------------------------------------
    def _resolve(self, js: _JobState, index: int, runtime: float,
                 counters, cost: float, finished_rel: float) -> None:
        """One logical test reached its final outcome (measured result or
        known-bad ``inf``): account it, feed the searcher, re-evaluate
        parking, finalize on budget exhaustion."""
        js.pending -= 1
        # job accounts run on THIS run's clock (the pool may have served
        # earlier runs), so per-job elapsed stays comparable to the
        # report's makespan
        js.account.record_completion(index, runtime, cost, finished_rel)
        js.searcher.observe([Observation(
            index=index, runtime=runtime, counters=counters,
            step=js.account.steps, elapsed=js.account.elapsed)])
        if self.on_trial is not None:
            self.on_trial(js.job.name, js.account.steps,
                          js.account.best_runtime)
        self._maybe_park(js)
        if js.pending == 0 and js.submitted >= js.job.budget:
            self._finalize(js)

    def _handle(self, res, t_start: float) -> None:
        info = self._inflight.pop(res.uid, None)
        if info is None:
            # a timed-out straggler finally came back: its measurement is
            # discarded, but the lane-seconds it burned are real
            js = self._abandoned.pop(res.uid, None)
            if js is not None:
                js.account.record_abandoned(res.cost)
            return
        js = info.js
        finished_rel = res.finished_at - t_start
        if res.error is None:
            # latency, not in-worker cost: for subprocess/thread pools the
            # submit→finish time includes IPC and queueing, and THAT is
            # what a straggler deadline must be calibrated against
            latency = res.finished_at - info.submitted_at
            js.note_latency(info.profile,
                            latency if latency > 0.0 else res.cost)
            self._resolve(js, res.index, res.runtime, res.counters,
                          res.cost, finished_rel)
            return
        # -- failure: the attempt burned a lane but produced nothing
        js.failures += 1
        js.account.record_abandoned(res.cost)
        kind = res.kind or FAIL_TEST
        give_up = False
        if kind == FAIL_TEST:
            js.fail_counts[info.index] = \
                js.fail_counts.get(info.index, 0) + 1
            if js.fail_counts[info.index] >= self.known_bad_after:
                give_up = True        # the config itself is the problem
        if not give_up and info.attempt < self.retries \
                and self._alive() > 0:
            exclude = info.exclude
            if res.lane >= 0 and res.lane not in exclude:
                exclude = exclude + (res.lane,)
            js.retry_queue.append(
                (info.index, info.profile, info.attempt + 1, exclude))
            if self.verbose:
                print(f"[fleet] {js.job.name}[{info.index}] failed "
                      f"({kind}): retry {info.attempt + 1}")
            return
        # give up: resolve the test as an inf row so the budget terminates
        # and the searcher is unblocked; the known-bad label is reserved
        # for configs whose OWN measurement failed known_bad_after times —
        # a retry budget exhausted on lane faults (or on a smaller fail
        # count) doesn't condemn the config
        if kind == FAIL_TEST \
                and js.fail_counts.get(info.index, 0) \
                >= self.known_bad_after \
                and info.index not in js.known_bad:
            js.known_bad.append(info.index)
        if self.verbose:
            print(f"[fleet] {js.job.name}[{info.index}] failed "
                  f"({kind}): giving up ({res.error})")
        self._resolve(js, info.index, _INF, None, 0.0, finished_rel)

    def _check_stragglers(self, t_start: float) -> None:
        """Time out tests outstanding longer than ``straggler_factor ×``
        their job's rolling latency estimate: resubmit elsewhere and drop
        the eventual late result (its cost is charged on arrival).  The
        retry carries no lane exclusion (the straggler's lane is unknown
        until its result arrives), but both addressable pools steer it
        away anyway: the wedged lane still holds the hung test in its
        busy/next-free accounting, so least-loaded selection avoids it."""
        if self.straggler_factor is None:
            return
        now = self.pool.elapsed()
        for uid, info in list(self._inflight.items()):
            est = info.js.latency_estimate(info.profile)
            if est is None:
                continue
            allowed = max(self.straggler_factor * est,
                          STRAGGLER_MIN_TIMEOUT)
            if now - info.submitted_at <= allowed:
                continue
            del self._inflight[uid]
            self._abandoned[uid] = info.js
            js = info.js
            js.timeouts += 1
            if self.verbose:
                print(f"[fleet] {js.job.name}[{info.index}] straggling "
                      f"(> {self.straggler_factor:.1f}x est): resubmit")
            if info.attempt < self.retries:
                js.retry_queue.append(
                    (info.index, info.profile, info.attempt + 1,
                     info.exclude))
            else:   # out of retries: resolve without a measurement
                self._resolve(js, info.index, _INF, None, 0.0,
                              now - t_start)

    def _collect_tick(self) -> Optional[float]:
        """Block-until for ``collect``: the nearest straggler deadline
        (None blocks indefinitely — no timeout policy or no estimate yet).
        Virtual pools ignore it; real pools wake up to run the scan."""
        if self.straggler_factor is None:
            return None
        deadlines = []
        for info in self._inflight.values():
            est = info.js.latency_estimate(info.profile)
            if est is not None:
                deadlines.append(
                    info.submitted_at + max(self.straggler_factor * est,
                                            STRAGGLER_MIN_TIMEOUT))
        if not deadlines:
            return None
        return max(0.01, min(deadlines) - self.pool.elapsed() + 0.01)

    # -- the event loop --------------------------------------------------------
    # ``run()`` is the one-shot form; a long-lived service instead drives
    # ``begin()`` / ``step()`` / ``finish()`` itself so it can inject
    # (``add_job``) and cancel (``cancel_job``) jobs between ticks.  The
    # decomposition is behavior-preserving: ``run()`` is exactly
    # begin + step-until-idle + finish.
    def begin(self) -> None:
        """Initialize a (possibly empty) scheduling session."""
        self._states = [_JobState(j) for j in self.jobs]
        self._by_name = {js.job.name: js for js in self._states}
        for i, js in enumerate(self._states):
            js.last_pick = i      # initial tie-break: declaration order
        self._pick_seq = len(self._states)
        self._inflight = {}
        self._abandoned = {}
        self._t_start = self.pool.elapsed()
        self._elastic = None
        if self.in_flight_max is not None:
            self._elastic = ElasticInFlight(lo=self.in_flight,
                                            hi=self.in_flight_max)
        self._limit = self.in_flight
        self._stopping = False
        if self.train_async and self._trainer is None:
            self._trainer = _TrainerThread(maxsize=self.train_queue)
        self._publish_keys = {}
        self._began = True

    def add_job(self, job: TuningJob) -> None:
        """Inject a job — before ``begin()`` or into a RUNNING fleet.

        Mid-run injection is the service path: the new job competes for
        lanes under the same gain-priority scheduler from the next
        ``step()`` (cold jobs rank highest, so a fresh tenant is served
        promptly without preempting in-flight work).
        """
        if any(j.name == job.name for j in self.jobs):
            raise ValueError(f"duplicate job name {job.name!r}")
        self.jobs.append(job)
        if self._began:
            js = _JobState(job)
            js.last_pick = self._next_pick()
            self._states.append(js)
            self._by_name[job.name] = js

    def cancel_job(self, name: str) -> bool:
        """Cancel a job mid-run: queued retries are dropped, in-flight
        tests are reclassified as abandoned (their lane-seconds are still
        charged when they come back), and the job resolves immediately to
        a partial ``JobResult`` with ``cancelled=True``.  Nothing is
        published to the store.  Returns False if unknown or already done.
        """
        js = self._by_name.get(name)
        if js is None or js.done:
            return False
        js.retry_queue.clear()
        for uid, info in list(self._inflight.items()):
            if info.js is js:
                del self._inflight[uid]
                self._abandoned[uid] = js
        js.pending = 0
        self._resolve_cancelled(js)
        return True

    def stop(self) -> None:
        """Graceful drain: stop scheduling NEW tests; in-flight tests keep
        running and are collected/accounted by the remaining ``step()``s
        (the shared shutdown path of the fleet CLI and the daemon)."""
        self._stopping = True

    @property
    def stopping(self) -> bool:
        return self._stopping

    def step(self, max_wait: Optional[float] = None) -> bool:
        """One scheduling tick: saturate the pool, collect one completion,
        process stragglers.  Returns False when the fleet is idle (nothing
        in flight and nothing schedulable) — the moment a service waits for
        new requests and ``run()`` finishes.  ``max_wait`` bounds how long
        the tick may block on the pool (None: until the next straggler
        deadline, or indefinitely), so a driving loop stays responsive to
        injected jobs and shutdown signals.
        """
        self._drain_trainer()
        if not self._stopping:
            self._fill(self._limit)
        if not self._inflight:
            if self._trainer is not None and self._trainer.pending > 0:
                # nothing on the pool, but searchers/models are still
                # training: wait for one completion so it can unblock
                # scheduling, and report the fleet as busy
                self._drain_trainer(block=True, max_wait=max_wait)
                return True
            return False
        tick = self._collect_tick()
        if max_wait is not None:
            tick = max_wait if tick is None else min(tick, max_wait)
        try:
            res = self.pool.collect(timeout=tick)
        except queue.Empty:
            self._check_stragglers(self._t_start)
            return True
        self._handle(res, self._t_start)
        if self._elastic is not None:
            if res.error is None:
                self._elastic.observe(res.cost)
            self._limit = self._elastic.target(self._alive())
        self._check_stragglers(self._t_start)
        return True

    def _drain_trainer(self, block: bool = False,
                       max_wait: Optional[float] = None) -> None:
        """Apply ready trainer completions on the loop thread (binds
        searchers, publishes models).  ``block=True`` waits up to
        ``max_wait`` for the first one; the rest drain opportunistically.
        """
        if self._trainer is None:
            return
        while self._trainer.pending > 0:
            item = self._trainer.get(block=block, timeout=max_wait)
            if item is None:
                return
            block = False
            tag, js, out, err = item
            if tag == "prep":
                self._apply_prep(js, out, err)
            else:
                self._apply_publish(js, out, err)

    def _drain_trainer_all(self) -> None:
        """Block until every outstanding trainer task has been applied
        (finish-time barrier: published models must be in the store
        before the report returns, so a later run warm-starts)."""
        if self._trainer is None:
            return
        while self._trainer.pending > 0:
            item = self._trainer.get(block=True, timeout=30.0)
            if item is None:          # wedged trainer: don't hang finish
                break
            tag, js, out, err = item
            if tag == "prep":
                self._apply_prep(js, out, err)
            else:
                self._apply_publish(js, out, err)

    def finish(self) -> FleetReport:
        """Drain straggler debts, finalize every remaining job, and build
        the report for everything since ``begin()``."""
        self._drain_trainer_all()
        # drain abandoned stragglers still on the pool so their burned
        # lane-seconds are charged (and a reused pool starts clean);
        # a straggler that never returns (hung thread) is skipped
        while self._abandoned and self.pool.outstanding() > 0:
            try:
                res = self.pool.collect(timeout=0.05)
            except queue.Empty:
                break
            js = self._abandoned.pop(res.uid, None)
            if js is not None:
                js.account.record_abandoned(res.cost)
        for js in self._states:   # parked jobs + searchers that dried up
            if not js.done:
                self._finalize(js)
        # finalizing above may have queued publish trainings; they must
        # land before the report so the next run's warm starts see them
        self._drain_trainer_all()
        # the trainer thread ends with the run — ``begin()`` starts a
        # fresh one, so a finished tuner never leaks a parked thread
        # into the embedding process (one daemon per process is the
        # norm, but benchmarks and tests cycle many)
        if self._trainer is not None:
            self._trainer.close()
            self._trainer = None
        for js in self._states:
            # a straggler drained above may have charged abandoned cost
            # AFTER its job finalized — refresh the snapshot's accounting
            js.result.busy = js.account.busy
            js.result.abandoned_s = js.account.abandoned
        results = [js.result for js in self._states]
        return FleetReport(
            results=results,
            elapsed=self.pool.elapsed() - self._t_start,
            busy=float(sum(r.busy for r in results)),
            in_flight=self.in_flight,
            workers=self.pool.workers,
            abandoned=float(sum(r.abandoned_s for r in results)),
            failures=int(sum(r.failures for r in results)),
            timeouts=int(sum(js.timeouts for js in self._states)),
            known_bad=int(sum(len(r.known_bad) for r in results)),
            parked=int(sum(1 for r in results if r.parked)),
            max_retries_used=self._max_attempt,
            in_flight_max=self.in_flight_max)

    def run(self) -> FleetReport:
        self.begin()
        while self.step():
            pass
        return self.finish()

    # -- introspection (the service's metering hooks) --------------------------
    def job_account(self, name: str) -> Optional[EvalAccount]:
        """The LIVE account of one job (None: unknown) — what a tenant
        manager snapshots/diffs to meter per-request worker-seconds."""
        js = self._by_name.get(name)
        return js.account if js is not None else None

    def progress(self) -> Dict[str, float]:
        """Fleet-wide meters since ``begin()`` (cheap, callable mid-run)."""
        busy = float(sum(js.account.busy for js in self._states))
        elapsed = self.pool.elapsed() - self._t_start
        return {
            "jobs": len(self._states),
            "jobs_done": sum(1 for js in self._states if js.done),
            "in_flight": len(self._inflight),
            "busy_s": busy,
            "elapsed_s": elapsed,
            "utilization": busy / max(elapsed * self.pool.workers, 1e-12),
        }

    # -- parking ---------------------------------------------------------------
    def _maybe_park(self, js: _JobState) -> None:
        """Park a model-backed job whose measured best already sits within
        ``park_factor`` of its predicted best: convergence has stopped
        buying latency, so its budget goes to jobs still gaining."""
        if (self.park_factor is None or js.parked
                or js.predicted_best is None):
            return
        if js.account.best_runtime <= self.park_factor * js.predicted_best:
            js.parked = True
            js.was_parked = True
            if self.verbose:
                print(f"[fleet] {js.job.name}: parked at "
                      f"{js.account.best_runtime * 1e3:.3f}ms "
                      f"(predicted best "
                      f"{js.predicted_best * 1e3:.3f}ms)")

    def _unpark_check(self, space_name: str,
                      kind: str = "kernel") -> None:
        """A model was just published for ``space_name``: parked jobs of
        that space (same problem kind) re-price their predicted best
        against the now-nearest artifact, and unpark if it shows more
        remaining gain than the stale artifact they parked on."""
        if self.park_factor is None or self.store is None:
            return
        for js in self._states:
            if js.done or not js.parked \
                    or js.job.space.name != space_name \
                    or js.job.kind != kind:
                continue
            model, _ = self.store.load_nearest_model(
                space_name, js.job.bucket, js.hw_key,
                bind_space=js.job.space, kind=kind)
            if model is None:
                continue
            js.predicted_best = float(np.min(
                predicted_runtimes(model, js.job.space, js.hw)))
            if js.account.best_runtime \
                    > self.park_factor * js.predicted_best:
                js.parked = False
                if self.verbose:
                    print(f"[fleet] {js.job.name}: unparked (new model "
                          f"predicts {js.predicted_best * 1e3:.3f}ms)")

    # -- completion ------------------------------------------------------------
    def _resolve_cancelled(self, js: _JobState) -> None:
        """Resolve a job to a partial, store-untouched ``cancelled`` result
        (explicit ``cancel_job`` or a graceful drain that caught it before
        it could run)."""
        acct = js.account
        js.done = True
        js.result = JobResult(
            job=js.job.name, bucket=js.job.bucket, hardware=js.hw_key,
            searcher=js.searcher_name, warm_started=js.warm_started,
            best_index=acct.best_index,
            best_config=dict(js.job.space[acct.best_index])
            if acct.best_index is not None else {},
            best_runtime=acct.best_runtime, trials=acct.steps,
            elapsed=acct.elapsed, busy=acct.busy,
            trace=list(acct.trace), history=list(acct.history),
            failures=js.failures, abandoned_s=acct.abandoned,
            known_bad=list(js.known_bad), parked=js.was_parked,
            cancelled=True,
            transfer_from=js.transfer_key,
            transfer_similarity=js.transfer_similarity)
        if self.verbose:
            print(f"[fleet] {js.job.name}: cancelled after "
                  f"{acct.steps} trials")
        if self.on_job_done is not None:
            self.on_job_done(js.result)

    def _finalize(self, js: _JobState) -> None:
        t0 = self.pool.elapsed()
        job, acct = js.job, js.account
        if acct.best_index is None and js.failures == 0 \
                and acct.steps == 0:
            if self._stopping:
                # graceful drain caught the job before its first test
                self._resolve_cancelled(js)
                return
            raise RuntimeError(f"job {job.name} made no empirical tests "
                               "(budget <= 0 or empty space?)")
        js.done = True
        js.result = JobResult(
            job=job.name, bucket=job.bucket, hardware=js.hw_key,
            searcher=js.searcher_name, warm_started=js.warm_started,
            best_index=acct.best_index,
            best_config=dict(job.space[acct.best_index])
            if acct.best_index is not None else {},
            best_runtime=acct.best_runtime, trials=acct.steps,
            elapsed=acct.elapsed, busy=acct.busy,
            trace=list(acct.trace), history=list(acct.history),
            failures=js.failures, abandoned_s=acct.abandoned,
            known_bad=list(js.known_bad), parked=js.was_parked,
            transfer_from=js.transfer_key,
            transfer_similarity=js.transfer_similarity)
        if self._stopping and not js.was_parked \
                and js.submitted < job.budget \
                and not (js.searcher is not None and js.searcher.done):
            # drained mid-search: partial result, flagged as such
            js.result.cancelled = True
        if self.store is None or acct.best_index is None:
            if self.on_job_done is not None:
                self.on_job_done(js.result)
            return
        # batch the entry + model artifact into ONE locked read-merge-write
        # (each autosave re-parses the whole file — at fleet scale two per
        # completion is measurable lock/IO churn on the event loop)
        was_autosave, self.store.autosave = self.store.autosave, False
        published = False
        train_fn = None
        try:
            self.store.put(
                job.space.name, job.bucket, js.hw_key,
                config=js.result.best_config, runtime=acct.best_runtime,
                trials=acct.steps,
                meta={"job": job.name, "searcher": js.searcher_name,
                      "warm_started": js.warm_started,
                      **({"transfer_from": js.transfer_key,
                          "transfer_similarity": js.transfer_similarity}
                         if js.transfer_key is not None else {})},
                kind=job.kind)
            if self.publish_models and self.store.get_model_dict(
                    job.space.name, job.bucket, js.hw_key,
                    kind=job.kind) is None:
                # train the portable TP→PC_ops model this job was missing
                # and publish it — the next (input, hardware) arrival
                # warm-starts from it
                from repro.tuning.session import TuningSession

                space, wl, hw = job.space, job.workload_fn, js.hw
                seed, mk = job.seed, self.model_kind

                def train_fn():
                    session = TuningSession(space, wl, hw=hw, seed=seed)
                    session.train(kind=mk, sample="deliberate")
                    return session

                if self._trainer is None:
                    # synchronous fallback: train + publish inline
                    session = train_fn()
                    session.save_model_to_store(self.store, job.bucket,
                                                js.hw_key, kind=job.kind)
                    published = True
                    train_fn = None
        finally:
            self.store.autosave = was_autosave
        if was_autosave and self.store.path is not None:
            self.store.save()
        if published:
            self._unpark_check(job.space.name, kind=job.kind)
        if train_fn is not None:
            # off-loop: the fleet keeps dispatching while the model
            # trains; same-space jobs defer binding until it publishes
            pk = (job.space.name, job.kind)
            self._publish_keys[pk] = self._publish_keys.get(pk, 0) + 1
            self._trainer.submit("train", js, train_fn)
        self._absorb_stall(t0)
        if self.verbose:
            print(f"[fleet] {job.name}: best {acct.best_runtime*1e3:.3f}ms "
                  f"in {acct.steps} trials "
                  f"({'warm' if js.warm_started else 'cold'})")
        if self.on_job_done is not None:
            self.on_job_done(js.result)

    def _apply_publish(self, js: _JobState, session, error) -> None:
        """Trainer completion for a publish training (loop thread): store
        the artifact and re-check parked jobs, exactly as the synchronous
        path did — or, on a training exception, record the failure
        against this job and move on (the tuned entry already landed;
        only the portable model is lost).  The daemon never dies to a
        training crash."""
        job = js.job
        pk = (job.space.name, job.kind)
        n = self._publish_keys.get(pk, 0)
        if n <= 1:
            self._publish_keys.pop(pk, None)
        else:
            self._publish_keys[pk] = n - 1
        if error is not None:
            self.train_errors.append((job.name, f"train: {error!r}"))
            if self.verbose:
                print(f"[fleet] {job.name}: model training failed "
                      f"({error!r}); publish skipped")
            return
        was_autosave, self.store.autosave = self.store.autosave, False
        try:
            session.save_model_to_store(self.store, job.bucket,
                                        js.hw_key, kind=job.kind)
        finally:
            self.store.autosave = was_autosave
        if was_autosave and self.store.path is not None:
            self.store.save()
        self._unpark_check(job.space.name, kind=job.kind)
