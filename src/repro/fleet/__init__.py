"""``repro.fleet`` — asynchronous fleet orchestration for autotuning.

Keeps N empirical tests in flight across many (kernel × input bucket ×
hardware) tuning jobs: ``TuningJob``s are scheduled by a ``FleetTuner``
over a worker pool (deterministic virtual clock, in-process threads, or
per-lane subprocesses), share one concurrency-safe ``ConfigStore``, and
warm-start from the nearest stored TP→PC model artifact.

    from repro.fleet import (FleetTuner, VirtualWorkerPool,
                             job_from_registry)
    from repro.tuning import ConfigStore

    jobs = [job_from_registry("matmul", "2048", hw, budget=24)
            for hw in ("tpu_v4", "tpu_v5e")]
    report = FleetTuner(jobs, VirtualWorkerPool(workers=4),
                        store=ConfigStore("fleet_store.json")).run()

CLI: ``python -m repro.launch.fleet``; benchmark:
``python -m benchmarks.bench_fleet`` (writes ``BENCH_fleet.json``).
"""
from repro.fleet.job import (JobResult, TuningJob, job_from_problem,
                             job_from_registry)
from repro.fleet.pool import (FAIL_LANE, FAIL_POOL, FAIL_TEST, FailedResult,
                              SubprocessWorkerPool, ThreadWorkerPool,
                              VirtualWorkerPool, WorkItem, WorkResult)
from repro.fleet.tuner import (FleetReport, FleetTuner,
                               predicted_runtime_order)

__all__ = [
    "FAIL_LANE", "FAIL_POOL", "FAIL_TEST", "FailedResult", "FleetReport",
    "FleetTuner", "JobResult", "SubprocessWorkerPool", "ThreadWorkerPool",
    "TuningJob", "VirtualWorkerPool", "WorkItem", "WorkResult",
    "job_from_problem", "job_from_registry", "predicted_runtime_order",
]
