"""``TuningJob`` / ``JobResult`` — the unit of fleet work.

A job is one (kernel × input bucket × hardware) autotuning task: a tuning
space, the portable workload model for that input, the hardware target, and
a trial budget.  The fleet schedules many of them over one worker pool and
records each through its own ``EvalAccount`` (completion-ordered trace), so
per-job convergence stays comparable to single-job tuning while the pool's
wall-clock amortizes across the whole fleet.

Jobs built from the kernel registry (``job_from_registry``) also carry
their ``(kernel, input_key)`` provenance, which is what subprocess worker
backends ship across the process boundary instead of the (unpicklable)
workload closure.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core import hwspec
from repro.core.hwspec import HardwareSpec
from repro.core.tuning_space import Config, TuningSpace


@dataclasses.dataclass
class TuningJob:
    """One (kernel × input bucket × hardware) autotuning task."""

    name: str
    space: TuningSpace
    workload_fn: Callable[[Config], Dict[str, float]]
    hardware: Union[str, HardwareSpec]
    bucket: str = "default"          # input-shape bucket / input tag
    budget: int = 25                 # empirical-test budget
    seed: int = 0
    searcher: Optional[str] = None   # None = auto: warm_start on a stored
    #                                  artifact hit, else ``cold_searcher``
    cold_searcher: str = "random"
    kernel: Optional[str] = None     # registry provenance (subprocess pools)
    input_key: Optional[str] = None
    # override measurement: (index, profile) -> (runtime, counters, cost).
    # Default None = price workload_fn through the cost model on `hardware`
    # with the replay cost structure.  Thread pools time fn() wall-clock, so
    # a blocking eval_fn here is how real timed measurements plug in.
    eval_fn: Optional[Callable] = None

    def hw_spec(self) -> HardwareSpec:
        if isinstance(self.hardware, HardwareSpec):
            return self.hardware
        return hwspec.get(self.hardware)

    @property
    def hardware_key(self) -> str:
        """Normalized store key for this job's hardware target."""
        return hwspec.hardware_key(self.hardware)


def job_from_registry(kernel: str, input_key: str,
                      hardware: Union[str, HardwareSpec],
                      budget: int = 25, seed: int = 0,
                      searcher: Optional[str] = None,
                      cold_searcher: str = "random") -> TuningJob:
    """Build a job from a registered kernel benchmark + named input."""
    from repro.kernels.registry import BENCHMARKS

    bm = BENCHMARKS[kernel]
    if input_key not in bm.inputs:
        raise KeyError(f"kernel {kernel!r} has no input {input_key!r}; "
                       f"available: {sorted(bm.inputs)}")
    inp = bm.inputs[input_key]
    hw_key = hwspec.hardware_key(hardware)
    return TuningJob(
        name=f"{kernel}/{input_key}@{hw_key}",
        space=bm.make_space(),
        workload_fn=lambda cfg: bm.workload_fn(cfg, inp),
        hardware=hardware,
        bucket=input_key,
        budget=budget,
        seed=seed,
        searcher=searcher,
        cold_searcher=cold_searcher,
        kernel=kernel,
        input_key=input_key,
    )


@dataclasses.dataclass
class JobResult:
    """Outcome of one fleet job, read off its completion-ordered account.

    ``best_index`` is ``None`` only in the degenerate fault case where
    every empirical test of the job failed (its ``known_bad`` then lists
    the crashed configs and ``best_runtime`` is ``inf``) — the fleet still
    completes and reports it instead of dying.  Known-bad configs appear
    in the trace/history as ``inf``-runtime rows, so ``trials`` counts
    every *resolved* test, successful or not; ``failures`` counts failed
    attempts (including retried ones) and ``abandoned_s`` the
    worker-seconds those burned — already included in ``busy``.
    """

    job: str
    bucket: str
    hardware: str
    searcher: str
    warm_started: bool
    best_index: Optional[int]
    best_config: Config
    best_runtime: float
    trials: int                  # empirical tests resolved (incl. known-bad)
    elapsed: float               # job's completion frontier on the pool clock
    busy: float                  # worker-seconds spent on this job
    trace: List[Tuple[int, float, float]]
    history: List[Tuple[int, float]]
    failures: int = 0            # failed attempts observed (pre-retry)
    abandoned_s: float = 0.0     # worker-seconds of discarded attempts
    known_bad: List[int] = dataclasses.field(default_factory=list)
    parked: bool = False         # scheduler parked it inside the well band
    cancelled: bool = False      # cancelled mid-run (service/drain path):
    #                              partial results, nothing published

    def trials_to_threshold(self, threshold: float) -> Optional[int]:
        """Completed trials until runtime <= threshold (None: never)."""
        for steps, _, rt in self.trace:
            if rt <= threshold:
                return steps
        return None
