"""``TuningJob`` / ``JobResult`` — the unit of fleet work.

A job is one (problem × input bucket × hardware) autotuning task: a tuning
space, the portable workload model for that input, the hardware target, and
a trial budget.  The fleet schedules many of them over one worker pool and
records each through its own ``EvalAccount`` (completion-ordered trace), so
per-job convergence stays comparable to single-job tuning while the pool's
wall-clock amortizes across the whole fleet.

``job_from_problem`` is the generic entry: any ``TuningProblem`` (kernel
tiles, train-step sharding, serve geometry, ...) becomes a fleet job, with
the problem's ``kind`` namespacing its store artifacts and its
``make_evaluator`` (when non-None) plugging in as the measurement closure.
``job_from_registry`` remains as the kernel-specific shim — jobs built from
the kernel registry also carry their ``(kernel, input_key)`` provenance,
which is what subprocess worker backends ship across the process boundary
instead of the (unpicklable) workload closure.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core import hwspec
from repro.core.hwspec import HardwareSpec
from repro.core.tuning_space import Config, TuningSpace


@dataclasses.dataclass
class TuningJob:
    """One (problem × input bucket × hardware) autotuning task."""

    name: str
    space: TuningSpace
    workload_fn: Callable[[Config], Dict[str, float]]
    hardware: Union[str, HardwareSpec]
    bucket: str = "default"          # input-shape bucket / input tag
    budget: int = 25                 # empirical-test budget
    seed: int = 0
    searcher: Optional[str] = None   # None = auto: warm_start on a stored
    #                                  artifact hit, else ``cold_searcher``
    cold_searcher: str = "random"
    kernel: Optional[str] = None     # registry provenance (subprocess pools)
    input_key: Optional[str] = None
    # override measurement: (index, profile) -> (runtime, counters, cost).
    # Default None = price workload_fn through the cost model on `hardware`
    # with the replay cost structure.  Thread pools time fn() wall-clock, so
    # a blocking eval_fn here is how real timed measurements plug in.
    eval_fn: Optional[Callable] = None
    # problem-kind namespace for the job's store artifacts ("kernel",
    # "serve", "sharding", ...).  None infers the legacy kind from the
    # space name, so hand-built serve-space jobs keep hitting the store
    # entries their pre-problem ancestors wrote.
    kind: Optional[str] = None

    def __post_init__(self) -> None:
        if self.kind is None:
            from repro.tuning.store import legacy_kind
            self.kind = legacy_kind(self.space.name)

    def hw_spec(self) -> HardwareSpec:
        if isinstance(self.hardware, HardwareSpec):
            return self.hardware
        return hwspec.get(self.hardware)

    @property
    def hardware_key(self) -> str:
        """Normalized store key for this job's hardware target."""
        return hwspec.hardware_key(self.hardware)


def job_from_problem(problem, hardware: Union[str, HardwareSpec],
                     budget: int = 25, seed: int = 0,
                     searcher: Optional[str] = None,
                     cold_searcher: str = "random",
                     name: Optional[str] = None) -> TuningJob:
    """Build a fleet job from any ``TuningProblem``.

    The problem's ``make_evaluator(hw)`` — when it returns a closure —
    becomes the job's measurement substrate; ``None`` keeps the fleet's
    cost-model replay path, which is what keeps kernel-adapter jobs
    bit-identical to the legacy ``job_from_registry`` traces.
    """
    hw_key = hwspec.hardware_key(hardware)
    job = TuningJob(
        name=name if name is not None
        else f"{problem.kind}:{problem.name}@{hw_key}",
        space=problem.space(),
        workload_fn=problem.workload_fn(),
        hardware=hardware,
        bucket=problem.bucket,
        budget=budget,
        seed=seed,
        searcher=searcher,
        cold_searcher=cold_searcher,
        kernel=problem.kernel,
        input_key=problem.input_key,
        kind=problem.kind,
    )
    job.eval_fn = problem.make_evaluator(job.hw_spec())
    return job


def job_from_registry(kernel: str, input_key: str,
                      hardware: Union[str, HardwareSpec],
                      budget: int = 25, seed: int = 0,
                      searcher: Optional[str] = None,
                      cold_searcher: str = "random") -> TuningJob:
    """Kernel-registry shim: ``job_from_problem`` over a
    ``KernelProblem``, keeping the legacy ``kernel/input@hw`` job name."""
    from repro.tuning.problem import KernelProblem

    problem = KernelProblem(kernel, input_key)
    hw_key = hwspec.hardware_key(hardware)
    return job_from_problem(
        problem, hardware, budget=budget, seed=seed, searcher=searcher,
        cold_searcher=cold_searcher,
        name=f"{kernel}/{input_key}@{hw_key}")


@dataclasses.dataclass
class JobResult:
    """Outcome of one fleet job, read off its completion-ordered account.

    ``best_index`` is ``None`` only in the degenerate fault case where
    every empirical test of the job failed (its ``known_bad`` then lists
    the crashed configs and ``best_runtime`` is ``inf``) — the fleet still
    completes and reports it instead of dying.  Known-bad configs appear
    in the trace/history as ``inf``-runtime rows, so ``trials`` counts
    every *resolved* test, successful or not; ``failures`` counts failed
    attempts (including retried ones) and ``abandoned_s`` the
    worker-seconds those burned — already included in ``busy``.
    """

    job: str
    bucket: str
    hardware: str
    searcher: str
    warm_started: bool
    best_index: Optional[int]
    best_config: Config
    best_runtime: float
    trials: int                  # empirical tests resolved (incl. known-bad)
    elapsed: float               # job's completion frontier on the pool clock
    busy: float                  # worker-seconds spent on this job
    trace: List[Tuple[int, float, float]]
    history: List[Tuple[int, float]]
    failures: int = 0            # failed attempts observed (pre-retry)
    abandoned_s: float = 0.0     # worker-seconds of discarded attempts
    known_bad: List[int] = dataclasses.field(default_factory=list)
    parked: bool = False         # scheduler parked it inside the well band
    cancelled: bool = False      # cancelled mid-run (service/drain path):
    #                              partial results, nothing published
    # cross-space transfer provenance: set only when the warm start came
    # from the store's compatible-space tier (all four exact-space tiers
    # missed) — the source artifact's store key and the structural
    # similarity that justified using it
    transfer_from: Optional[str] = None
    transfer_similarity: Optional[float] = None

    def trials_to_threshold(self, threshold: float) -> Optional[int]:
        """Completed trials until runtime <= threshold (None: never)."""
        for steps, _, rt in self.trace:
            if rt <= threshold:
                return steps
        return None
