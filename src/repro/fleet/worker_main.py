"""Fleet worker subprocess: a JSON-lines evaluation server.

One of these runs per ``SubprocessWorkerPool`` lane.  Requests name a
registered kernel workload (``{"kernel", "input", "hw", "index", "uid",
"profile"}``); the worker rebuilds the workload model from the registry,
prices it through the cost model on the named hardware, and replies with
``{"uid", "runtime", "cost"}`` (plus ``ops``/``stress`` when profiled).

With ``--devices N`` the worker brings up its own N-device jax host runtime
(``--xla_force_host_platform_device_count``) and builds a mesh through the
``launch/mesh.py`` machinery — the same per-process multi-device shape the
8-device dry-run integration uses, so a real device-backed ``run()``
payload drops in without changing the pool protocol.

Protocol extras: ``{"op": "ping"}`` → ``{"op": "pong", "devices": n}``
(startup handshake), ``{"op": "shutdown"}`` or EOF → exit.  Errors are
reported per-request (``{"uid", "error", ...}``), never by crashing the
worker.  ``attempt`` is echoed back verbatim so the pool can correlate
retries.

Fault-injection hooks (tests/benchmarks for the fleet's failure policies):
a payload with ``"sim_fail": true`` replies with an injected error instead
of evaluating; ``"sim_crash": true`` makes the worker process exit
immediately WITHOUT replying — the deterministic stand-in for a lane dying
with a test in flight (the pool's reader sees EOF and fails the item as
kind ``"lane"``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="bring up a jax host runtime with this many "
                    "devices (0: pure-numpy cost-model evaluation)")
    args = ap.parse_args(argv)

    mesh = None
    n_devices = 0
    if args.devices > 0:
        import os
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.devices}")
        import jax

        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(data=args.devices)
        n_devices = len(jax.devices())

    from repro.core import costmodel, hwspec
    from repro.kernels.registry import BENCHMARKS

    spaces = {}     # kernel -> TuningSpace (configs resolved by index)

    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        req = json.loads(line)
        op = req.get("op")
        if op == "shutdown":
            break
        if op == "ping":
            print(json.dumps({"op": "pong", "devices": n_devices,
                              "mesh": bool(mesh)}), flush=True)
            continue
        out = {"uid": req.get("uid"), "attempt": int(req.get("attempt", 0))}
        if req.get("sim_crash"):
            # simulate a lane dying mid-test: no reply, immediate exit
            sys.exit(1)
        if req.get("sim_fail"):
            out["error"] = "InjectedFailure: sim_fail requested"
            print(json.dumps(out), flush=True)
            continue
        try:
            bm = BENCHMARKS[req["kernel"]]
            if req["kernel"] not in spaces:
                spaces[req["kernel"]] = bm.make_space()
            space = spaces[req["kernel"]]
            cfg = space[int(req["index"])]
            inp = bm.inputs[req["input"]]
            if "hw_spec" in req:        # unregistered hardware: by numbers
                hw = hwspec.HardwareSpec(**req["hw_spec"])
            else:
                hw = hwspec.get(req["hw"])
            t0 = time.perf_counter()
            cs = costmodel.execute(bm.workload_fn(cfg, inp), hw)
            out["runtime"] = float(cs.runtime)
            out["cost"] = time.perf_counter() - t0
            if req.get("profile"):
                out["ops"] = {k: float(v) for k, v in cs.ops.items()}
                out["stress"] = {k: float(v) for k, v in cs.stress.items()}
        except Exception as e:      # report per-request, keep serving
            out["error"] = f"{type(e).__name__}: {e}"
        print(json.dumps(out), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
