"""Worker pools: the fleet's asynchronous measurement substrate.

All pools speak the same submit/collect protocol as the async evaluator
layer, but at fleet scope — one pool serves empirical tests from MANY jobs,
so a job whose searcher is waiting on its current batch never idles a
worker that another job could use:

* ``VirtualWorkerPool``    — deterministic simulated concurrency: work is
  evaluated eagerly (the cost-model workloads are pure) and completion
  times are scheduled on a virtual clock with ``workers`` parallel lanes.
  The benchmark/test backend: bit-reproducible, no threads — including its
  FAULT-INJECTION hooks (seeded random test failures, lane kills at a
  virtual time, cost-scaled stragglers), so every retry/timeout/park
  policy in the orchestrator is deterministically testable.
* ``ThreadWorkerPool``     — real in-process concurrency over a
  ``ThreadPoolExecutor``; costs and completion times are measured
  wall-clock.  For measurement callables that genuinely block (timed
  kernels, RPCs to devices).
* ``SubprocessWorkerPool`` — one persistent worker *process* per lane,
  speaking JSON-lines over stdin/stdout (``repro.fleet.worker_main``).
  Workers can bring up their own multi-device jax runtime (the
  ``launch/mesh.py`` host-mesh machinery via
  ``--xla_force_host_platform_device_count``), which is the shape of a real
  per-device fleet backend; work items must carry a serializable
  ``payload`` (registry kernel + input + hardware + config index) instead
  of a closure.

Failure contract: a failed empirical test is DATA, not an exception.
``collect()`` never raises on a lane failure — it returns a
``FailedResult`` carrying the error text, an ``kind`` classifying it
(``"test"``: the measurement itself failed — crashing/invalid config;
``"lane"``: the worker died with the test in flight; ``"pool"``: no lane
was available to run it at all), the lane it ran on, and which ``attempt``
this was — so the orchestrator can retry on another lane
(``WorkItem.exclude``), give up after a budget, or mark the config
known-bad, instead of the whole fleet dying on its first crashed config.

``WorkItem.fn`` is a zero-arg callable returning ``(runtime, counters,
cost)`` — the same triple as ``Evaluator._evaluate`` — used by the
in-process pools; ``WorkItem.payload`` is the serializable description used
by subprocess pools.  ``WorkResult.finished_at`` is on the pool's clock
(virtual seconds or wall seconds since pool start).
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import os
import queue
import subprocess
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.counters import CounterSet

EvalFn = Callable[[], Tuple[float, Optional[CounterSet], float]]

# Failure kinds carried by FailedResult.kind
FAIL_TEST = "test"   # the measurement itself errored (crashing config)
FAIL_LANE = "lane"   # the worker lane died with the test in flight
FAIL_POOL = "pool"   # no lane was available to run the test at all


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One empirical test, addressed back to its job by name.

    ``attempt`` counts resubmissions of the same logical test (0 = first
    try) and is echoed on the result; ``exclude`` names lanes the pool
    should avoid (the orchestrator's exclude-and-resubmit retry: don't
    hand a retry back to the lane that just failed it) — advisory: if
    every non-excluded lane is dead, any live lane is used.
    """

    uid: int
    job: str
    index: int
    profile: bool = False
    fn: Optional[EvalFn] = None
    payload: Optional[Dict[str, Any]] = None
    attempt: int = 0
    exclude: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class WorkResult:
    uid: int
    job: str
    index: int
    runtime: float
    counters: Optional[CounterSet]
    cost: float          # worker-seconds this test occupied a lane
    finished_at: float   # completion time on the pool clock
    error: Optional[str] = None
    kind: Optional[str] = None   # FAIL_TEST / FAIL_LANE / FAIL_POOL
    lane: int = -1               # lane the test ran on (-1: unknown)
    attempt: int = 0             # echoed from the WorkItem


@dataclasses.dataclass(frozen=True)
class FailedResult(WorkResult):
    """A failed empirical test surfaced as data instead of an exception.

    ``error`` is the human-readable cause, ``kind`` classifies it
    (``"test"`` / ``"lane"`` / ``"pool"``), ``lane`` is where it ran and
    ``attempt`` which retry this was.  ``runtime`` is ``inf`` and
    ``counters`` is ``None``; ``cost`` is the worker-seconds the failed
    attempt still burned (honest accounting feeds it to
    ``EvalAccount.record_abandoned``).
    """


def _failed(item: WorkItem, error: str, kind: str, lane: int, cost: float,
            finished_at: float) -> FailedResult:
    return FailedResult(
        uid=item.uid, job=item.job, index=item.index, runtime=float("inf"),
        counters=None, cost=cost, finished_at=finished_at, error=error,
        kind=kind, lane=lane, attempt=item.attempt)


class VirtualWorkerPool:
    """Deterministic ``workers``-lane scheduling on a virtual clock.

    ``submit`` evaluates the item's pure ``fn`` immediately, assigns the
    test to the earliest-free lane (started no earlier than the last
    collection — the moment the orchestrator could have decided to submit),
    and schedules its completion; ``collect`` pops the earliest-finishing
    outstanding test and advances the clock to it.  ``elapsed()`` is the
    makespan so far — the fleet's simulated wall-clock.

    Fault injection (all deterministic, for tests/benchmarks):

    * ``fail_rate`` / ``fail_seed`` — each submitted attempt fails with
      this probability (kind ``"test"``), drawn from a dedicated seeded
      rng in submission order; the failed attempt still burns its cost.
    * ``fail_fn`` — ``fn(item) -> Optional[str]``: targeted injection —
      return an error string to fail exactly that attempt (kind
      ``"test"``; e.g. fail config 7 on its first attempt only).
    * ``kill_lane_at`` — ``{lane: virtual_time}``: the lane dies at that
      time.  A test in flight on it fails at the kill time (kind
      ``"lane"``, cost = the lane-seconds burned before the kill); the
      lane takes no further work.
    * ``cost_scale`` — ``fn(item) -> factor`` multiplying the item's cost
      (straggler injection: make one uid run 50x long).
    """

    def __init__(self, workers: int = 4, fail_rate: float = 0.0,
                 fail_seed: int = 0,
                 fail_fn: Optional[Callable[[WorkItem],
                                            Optional[str]]] = None,
                 kill_lane_at: Optional[Dict[int, float]] = None,
                 cost_scale: Optional[Callable[[WorkItem], float]] = None):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.workers = int(workers)
        self._free = [0.0] * self.workers
        self._now = 0.0
        self._heap: List[Tuple[float, int, WorkResult]] = []
        self._seq = 0
        self.fail_rate = float(fail_rate)
        self._fail_rng = np.random.default_rng(fail_seed)
        self._fail_fn = fail_fn
        self._kill = dict(kill_lane_at or {})
        self._cost_scale = cost_scale

    def _lane_dead_at(self, lane: int, t: float) -> bool:
        k = self._kill.get(lane)
        return k is not None and t >= k

    def _push(self, finish: float, res: WorkResult) -> None:
        heapq.heappush(self._heap, (finish, self._seq, res))
        self._seq += 1

    def submit(self, item: WorkItem) -> None:
        # choose the earliest-free lane among the alive ones, honouring the
        # item's exclusion list when any other alive lane exists
        alive = [i for i in range(self.workers)
                 if not self._lane_dead_at(i, max(self._now, self._free[i]))]
        if not alive:
            self._push(self._now, _failed(
                item, "all virtual lanes are dead", FAIL_POOL, -1, 0.0,
                self._now))
            return
        preferred = [i for i in alive if i not in item.exclude] or alive
        lane = min(preferred, key=lambda i: self._free[i])
        start = max(self._now, self._free[lane])
        rt, cs, cost = item.fn()
        if self._cost_scale is not None:
            cost *= float(self._cost_scale(item))
        kill = self._kill.get(lane)
        if kill is not None and start + cost > kill:
            # the lane dies mid-test: the attempt burned (kill - start)
            # lane-seconds and its result is lost
            self._free[lane] = kill
            self._push(kill, _failed(
                item, f"virtual lane {lane} killed at t={kill:.6f} with "
                "this test in flight", FAIL_LANE, lane,
                max(0.0, kill - start), kill))
            return
        finish = start + cost
        self._free[lane] = finish
        err = self._fail_fn(item) if self._fail_fn is not None else None
        if err is None and self.fail_rate > 0.0 \
                and self._fail_rng.random() < self.fail_rate:
            err = "injected test failure"
        if err is not None:
            self._push(finish, _failed(item, err, FAIL_TEST, lane, cost,
                                       finish))
            return
        self._push(finish, WorkResult(
            uid=item.uid, job=item.job, index=item.index, runtime=rt,
            counters=cs, cost=cost, finished_at=finish, lane=lane,
            attempt=item.attempt))

    def collect(self, timeout: Optional[float] = None) -> WorkResult:
        if not self._heap:
            raise RuntimeError("collect() with no outstanding work")
        finish, _, res = heapq.heappop(self._heap)
        self._now = max(self._now, finish)
        return res

    def outstanding(self) -> int:
        return len(self._heap)

    def alive_workers(self) -> int:
        """Lanes currently able to take new work."""
        return sum(1 for i in range(self.workers)
                   if not self._lane_dead_at(
                       i, max(self._now, self._free[i])))

    def elapsed(self) -> float:
        return self._now

    def close(self) -> None:
        pass


class ThreadWorkerPool:
    """Real in-process concurrency: ``workers`` threads, wall-clock costs.

    Suited to measurement callables that release the GIL or block (device
    RPCs, subprocess compiles, sleeps); a pure-Python compute-bound ``fn``
    will serialize on the GIL and show no speedup.  Threads are not
    addressable lanes, so ``WorkItem.exclude`` is a no-op here; a raising
    ``fn`` comes back as a ``FailedResult`` (kind ``"test"``).
    """

    def __init__(self, workers: int = 4):
        from concurrent.futures import ThreadPoolExecutor

        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.workers = int(workers)
        self._ex = ThreadPoolExecutor(max_workers=self.workers,
                                      thread_name_prefix="fleet-worker")
        self._t0 = time.perf_counter()
        self._done: "queue.Queue[WorkResult]" = queue.Queue()
        self._outstanding = 0

    def _run(self, item: WorkItem) -> None:
        start = time.perf_counter()
        try:
            rt, cs, _ = item.fn()
            err = None
        except Exception as e:                      # surfaced at collect()
            rt, cs, err = float("inf"), None, f"{type(e).__name__}: {e}"
        end = time.perf_counter()
        self._done.put(WorkResult(
            uid=item.uid, job=item.job, index=item.index, runtime=rt,
            counters=cs, cost=end - start, finished_at=end - self._t0,
            error=err, kind=FAIL_TEST if err is not None else None,
            attempt=item.attempt))

    def submit(self, item: WorkItem) -> None:
        self._outstanding += 1
        self._ex.submit(self._run, item)

    def collect(self, timeout: Optional[float] = None) -> WorkResult:
        res = self._done.get(timeout=timeout)
        self._outstanding -= 1
        return res

    def outstanding(self) -> int:
        return self._outstanding

    def alive_workers(self) -> int:
        return self.workers

    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    def close(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


class SubprocessWorkerPool:
    """``workers`` persistent evaluation processes over JSON-lines pipes.

    Each worker runs ``python -m repro.fleet.worker_main`` with its own
    interpreter (and, with ``devices_per_worker > 0``, its own jax host
    runtime of that many devices brought up through the ``launch/mesh.py``
    host-mesh machinery).  Work items must carry a ``payload`` naming a
    registered kernel workload; results stream back on a reader thread per
    worker, so ``collect`` sees completions in real finish order across the
    whole pool.

    Failure handling: a worker process that exits mid-run fails its
    in-flight tests with ``FailedResult``\\ s (kind ``"lane"``) — but only
    AFTER its reader thread has drained every completed result still
    buffered in the pipe, so a lane that wrote a result and then died never
    loses it.  ``submit`` with no live lanes enqueues a ``"pool"``-kind
    failure for the item (behind any already-buffered completions in the
    FIFO) instead of raising, so the orchestrator drains survivors before
    seeing the fleet-dead condition.
    """

    def __init__(self, workers: int = 2, devices_per_worker: int = 0,
                 startup_timeout: float = 120.0):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.workers = int(workers)
        self._t0 = time.perf_counter()
        self._done: "queue.Queue[WorkResult]" = queue.Queue()
        self._outstanding = 0
        self._items: Dict[int, WorkItem] = {}
        self._owner: Dict[int, int] = {}   # uid -> worker lane
        self._lock = threading.Lock()
        self._procs: List[subprocess.Popen] = []
        self._busy = [0] * self.workers    # in-flight per worker (least-loaded)
        self._dead = [False] * self.workers
        self._readers: List[threading.Thread] = []

        import repro

        src_dir = os.path.dirname(os.path.dirname(
            os.path.abspath(repro.__file__)))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_dir + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        cmd = [sys.executable, "-m", "repro.fleet.worker_main",
               "--devices", str(int(devices_per_worker))]
        for w in range(self.workers):
            p = subprocess.Popen(cmd, stdin=subprocess.PIPE,
                                 stdout=subprocess.PIPE, env=env, text=True,
                                 bufsize=1)
            self._procs.append(p)
            t = threading.Thread(target=self._reader, args=(w, p),
                                 daemon=True)
            t.start()
            self._readers.append(t)
        # handshake: a ping per worker proves imports/devices came up
        try:
            for p in self._procs:
                p.stdin.write(json.dumps({"op": "ping"}) + "\n")
                p.stdin.flush()
            deadline = time.perf_counter() + startup_timeout
            for _ in range(self.workers):
                remaining = max(0.1, deadline - time.perf_counter())
                try:
                    res = self._done.get(timeout=remaining)
                except queue.Empty:
                    raise RuntimeError(
                        f"fleet worker produced no handshake within "
                        f"{startup_timeout:.0f}s (its stderr goes to this "
                        "process's stderr — check for import/device "
                        "errors)") from None
                if res.error is not None:
                    raise RuntimeError(f"fleet worker failed to start: "
                                       f"{res.error}")
        except BaseException:
            self.close()           # don't leak the surviving workers
            raise

    def _reader(self, worker: int, p: subprocess.Popen) -> None:
        for line in p.stdout:
            try:
                msg = json.loads(line)
            except json.JSONDecodeError:
                continue
            if msg.get("op") == "pong":
                self._done.put(WorkResult(uid=-1, job="", index=-1,
                                          runtime=0.0, counters=None,
                                          cost=0.0, finished_at=0.0,
                                          error=msg.get("error")))
                continue
            with self._lock:
                item = self._items.pop(msg["uid"], None)
                self._owner.pop(msg["uid"], None)
                self._busy[worker] -= 1
            if item is None:
                continue
            if msg.get("error") is not None:
                self._done.put(_failed(
                    item, msg["error"], FAIL_TEST, worker,
                    float(msg.get("cost", 0.0)),
                    time.perf_counter() - self._t0))
                continue
            cs = None
            if "ops" in msg:
                cs = CounterSet(ops=msg["ops"], stress=msg["stress"],
                                runtime=float(msg["runtime"]))
            self._done.put(WorkResult(
                uid=item.uid, job=item.job, index=item.index,
                runtime=float(msg.get("runtime", float("inf"))),
                counters=cs, cost=float(msg.get("cost", 0.0)),
                finished_at=time.perf_counter() - self._t0,
                lane=worker, attempt=item.attempt))
        # stdout EOF: the worker exited.  Everything it had written before
        # dying was already drained by the loop above (the pipe stays
        # readable to EOF after process death), so no completed result is
        # lost; only the genuinely in-flight items fail — as data, kind
        # "lane", so the orchestrator can resubmit them elsewhere.
        with self._lock:
            self._dead[worker] = True
            lost = [uid for uid, w in self._owner.items() if w == worker]
            items = [self._items.pop(uid) for uid in lost]
            for uid in lost:
                del self._owner[uid]
            self._busy[worker] = 0
        now = time.perf_counter() - self._t0
        for item in items:
            self._done.put(_failed(
                item, f"worker process {worker} exited (rc={p.poll()}) "
                "with this test in flight", FAIL_LANE, worker, 0.0, now))

    def submit(self, item: WorkItem) -> None:
        if item.payload is None:
            raise ValueError(
                "SubprocessWorkerPool needs serializable payloads "
                "(build jobs with fleet.job_from_registry)")
        self._outstanding += 1
        while True:
            with self._lock:
                alive = [i for i in range(self.workers) if not self._dead[i]]
                if not alive:
                    # fleet-dead is a per-item failure, queued BEHIND any
                    # results the reader threads already drained — the
                    # caller sees every completed test before the death
                    self._done.put(_failed(
                        item, "all fleet worker processes have died",
                        FAIL_POOL, -1, 0.0,
                        time.perf_counter() - self._t0))
                    return
                preferred = [i for i in alive if i not in item.exclude] \
                    or alive
                worker = min(preferred, key=lambda i: self._busy[i])
                self._busy[worker] += 1
                self._items[item.uid] = item
                self._owner[item.uid] = worker
            req = dict(item.payload)
            req.update(uid=item.uid, index=int(item.index),
                       profile=bool(item.profile), attempt=int(item.attempt))
            p = self._procs[worker]
            try:
                p.stdin.write(json.dumps(req) + "\n")
                p.stdin.flush()
                return
            except (BrokenPipeError, OSError):
                # the lane died between the reader noticing and us writing:
                # un-book the item and try the next live lane — UNLESS the
                # reader's EOF handler already claimed it (it saw our
                # booking and enqueued a lane-kind failure); resubmitting
                # then would produce a second result for the same uid and
                # drive the outstanding count negative
                with self._lock:
                    self._dead[worker] = True
                    if self._items.pop(item.uid, None) is None:
                        return
                    self._owner.pop(item.uid, None)

    def collect(self, timeout: Optional[float] = None) -> WorkResult:
        res = self._done.get(timeout=timeout)
        self._outstanding -= 1
        return res

    def outstanding(self) -> int:
        return self._outstanding

    def alive_workers(self) -> int:
        with self._lock:
            return sum(1 for d in self._dead if not d)

    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    def close(self) -> None:
        for p in self._procs:
            try:
                if p.stdin and not p.stdin.closed:
                    p.stdin.write(json.dumps({"op": "shutdown"}) + "\n")
                    p.stdin.flush()
                    p.stdin.close()
            except (BrokenPipeError, OSError):
                pass
        for p in self._procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()
