"""Worker pools: the fleet's asynchronous measurement substrate.

All pools speak the same submit/collect protocol as the async evaluator
layer, but at fleet scope — one pool serves empirical tests from MANY jobs,
so a job whose searcher is waiting on its current batch never idles a
worker that another job could use:

* ``VirtualWorkerPool``    — deterministic simulated concurrency: work is
  evaluated eagerly (the cost-model workloads are pure) and completion
  times are scheduled on a virtual clock with ``workers`` parallel lanes.
  The benchmark/test backend: bit-reproducible, no threads.
* ``ThreadWorkerPool``     — real in-process concurrency over a
  ``ThreadPoolExecutor``; costs and completion times are measured
  wall-clock.  For measurement callables that genuinely block (timed
  kernels, RPCs to devices).
* ``SubprocessWorkerPool`` — one persistent worker *process* per lane,
  speaking JSON-lines over stdin/stdout (``repro.fleet.worker_main``).
  Workers can bring up their own multi-device jax runtime (the
  ``launch/mesh.py`` host-mesh machinery via
  ``--xla_force_host_platform_device_count``), which is the shape of a real
  per-device fleet backend; work items must carry a serializable
  ``payload`` (registry kernel + input + hardware + config index) instead
  of a closure.

``WorkItem.fn`` is a zero-arg callable returning ``(runtime, counters,
cost)`` — the same triple as ``Evaluator._evaluate`` — used by the
in-process pools; ``WorkItem.payload`` is the serializable description used
by subprocess pools.  ``WorkResult.finished_at`` is on the pool's clock
(virtual seconds or wall seconds since pool start).
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import os
import queue
import subprocess
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.counters import CounterSet

EvalFn = Callable[[], Tuple[float, Optional[CounterSet], float]]


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One empirical test, addressed back to its job by name."""

    uid: int
    job: str
    index: int
    profile: bool = False
    fn: Optional[EvalFn] = None
    payload: Optional[Dict[str, Any]] = None


@dataclasses.dataclass(frozen=True)
class WorkResult:
    uid: int
    job: str
    index: int
    runtime: float
    counters: Optional[CounterSet]
    cost: float          # worker-seconds this test occupied a lane
    finished_at: float   # completion time on the pool clock
    error: Optional[str] = None


class VirtualWorkerPool:
    """Deterministic ``workers``-lane scheduling on a virtual clock.

    ``submit`` evaluates the item's pure ``fn`` immediately, assigns the
    test to the earliest-free lane (started no earlier than the last
    collection — the moment the orchestrator could have decided to submit),
    and schedules its completion; ``collect`` pops the earliest-finishing
    outstanding test and advances the clock to it.  ``elapsed()`` is the
    makespan so far — the fleet's simulated wall-clock.
    """

    def __init__(self, workers: int = 4):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.workers = int(workers)
        self._free = [0.0] * self.workers
        self._now = 0.0
        self._heap: List[Tuple[float, int, WorkItem, float,
                               Optional[CounterSet], float]] = []
        self._seq = 0

    def submit(self, item: WorkItem) -> None:
        rt, cs, cost = item.fn()
        lane = min(range(self.workers), key=lambda i: self._free[i])
        start = max(self._now, self._free[lane])
        finish = start + cost
        self._free[lane] = finish
        heapq.heappush(self._heap, (finish, self._seq, item, rt, cs, cost))
        self._seq += 1

    def collect(self, timeout: Optional[float] = None) -> WorkResult:
        if not self._heap:
            raise RuntimeError("collect() with no outstanding work")
        finish, _, item, rt, cs, cost = heapq.heappop(self._heap)
        self._now = max(self._now, finish)
        return WorkResult(uid=item.uid, job=item.job, index=item.index,
                          runtime=rt, counters=cs, cost=cost,
                          finished_at=finish)

    def outstanding(self) -> int:
        return len(self._heap)

    def elapsed(self) -> float:
        return self._now

    def close(self) -> None:
        pass


class ThreadWorkerPool:
    """Real in-process concurrency: ``workers`` threads, wall-clock costs.

    Suited to measurement callables that release the GIL or block (device
    RPCs, subprocess compiles, sleeps); a pure-Python compute-bound ``fn``
    will serialize on the GIL and show no speedup.
    """

    def __init__(self, workers: int = 4):
        from concurrent.futures import ThreadPoolExecutor

        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.workers = int(workers)
        self._ex = ThreadPoolExecutor(max_workers=self.workers,
                                      thread_name_prefix="fleet-worker")
        self._t0 = time.perf_counter()
        self._done: "queue.Queue[WorkResult]" = queue.Queue()
        self._outstanding = 0

    def _run(self, item: WorkItem) -> None:
        start = time.perf_counter()
        try:
            rt, cs, _ = item.fn()
            err = None
        except Exception as e:                      # surfaced at collect()
            rt, cs, err = float("inf"), None, f"{type(e).__name__}: {e}"
        end = time.perf_counter()
        self._done.put(WorkResult(
            uid=item.uid, job=item.job, index=item.index, runtime=rt,
            counters=cs, cost=end - start, finished_at=end - self._t0,
            error=err))

    def submit(self, item: WorkItem) -> None:
        self._outstanding += 1
        self._ex.submit(self._run, item)

    def collect(self, timeout: Optional[float] = None) -> WorkResult:
        res = self._done.get(timeout=timeout)
        self._outstanding -= 1
        if res.error is not None:
            raise RuntimeError(
                f"worker failed on {res.job}[{res.index}]: {res.error}")
        return res

    def outstanding(self) -> int:
        return self._outstanding

    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    def close(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


class SubprocessWorkerPool:
    """``workers`` persistent evaluation processes over JSON-lines pipes.

    Each worker runs ``python -m repro.fleet.worker_main`` with its own
    interpreter (and, with ``devices_per_worker > 0``, its own jax host
    runtime of that many devices brought up through the ``launch/mesh.py``
    host-mesh machinery).  Work items must carry a ``payload`` naming a
    registered kernel workload; results stream back on a reader thread per
    worker, so ``collect`` sees completions in real finish order across the
    whole pool.
    """

    def __init__(self, workers: int = 2, devices_per_worker: int = 0,
                 startup_timeout: float = 120.0):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.workers = int(workers)
        self._t0 = time.perf_counter()
        self._done: "queue.Queue[WorkResult]" = queue.Queue()
        self._outstanding = 0
        self._items: Dict[int, WorkItem] = {}
        self._owner: Dict[int, int] = {}   # uid -> worker lane
        self._lock = threading.Lock()
        self._procs: List[subprocess.Popen] = []
        self._busy = [0] * self.workers    # in-flight per worker (least-loaded)
        self._dead = [False] * self.workers
        self._readers: List[threading.Thread] = []

        import repro

        src_dir = os.path.dirname(os.path.dirname(
            os.path.abspath(repro.__file__)))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_dir + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        cmd = [sys.executable, "-m", "repro.fleet.worker_main",
               "--devices", str(int(devices_per_worker))]
        for w in range(self.workers):
            p = subprocess.Popen(cmd, stdin=subprocess.PIPE,
                                 stdout=subprocess.PIPE, env=env, text=True,
                                 bufsize=1)
            self._procs.append(p)
            t = threading.Thread(target=self._reader, args=(w, p),
                                 daemon=True)
            t.start()
            self._readers.append(t)
        # handshake: a ping per worker proves imports/devices came up
        try:
            for p in self._procs:
                p.stdin.write(json.dumps({"op": "ping"}) + "\n")
                p.stdin.flush()
            deadline = time.perf_counter() + startup_timeout
            for _ in range(self.workers):
                remaining = max(0.1, deadline - time.perf_counter())
                try:
                    res = self._done.get(timeout=remaining)
                except queue.Empty:
                    raise RuntimeError(
                        f"fleet worker produced no handshake within "
                        f"{startup_timeout:.0f}s (its stderr goes to this "
                        "process's stderr — check for import/device "
                        "errors)") from None
                if res.error is not None:
                    raise RuntimeError(f"fleet worker failed to start: "
                                       f"{res.error}")
        except BaseException:
            self.close()           # don't leak the surviving workers
            raise

    def _reader(self, worker: int, p: subprocess.Popen) -> None:
        for line in p.stdout:
            try:
                msg = json.loads(line)
            except json.JSONDecodeError:
                continue
            if msg.get("op") == "pong":
                self._done.put(WorkResult(uid=-1, job="", index=-1,
                                          runtime=0.0, counters=None,
                                          cost=0.0, finished_at=0.0,
                                          error=msg.get("error")))
                continue
            with self._lock:
                item = self._items.pop(msg["uid"], None)
                self._owner.pop(msg["uid"], None)
                self._busy[worker] -= 1
            if item is None:
                continue
            cs = None
            if "ops" in msg:
                cs = CounterSet(ops=msg["ops"], stress=msg["stress"],
                                runtime=float(msg["runtime"]))
            self._done.put(WorkResult(
                uid=item.uid, job=item.job, index=item.index,
                runtime=float(msg.get("runtime", float("inf"))),
                counters=cs, cost=float(msg.get("cost", 0.0)),
                finished_at=time.perf_counter() - self._t0,
                error=msg.get("error")))
        # stdout EOF: the worker exited.  During close() nothing is in
        # flight on it; otherwise it died mid-run — fail its lost items so
        # collect() raises instead of blocking forever, and stop routing
        # new work to the lane.
        with self._lock:
            self._dead[worker] = True
            lost = [uid for uid, w in self._owner.items() if w == worker]
            items = [self._items.pop(uid) for uid in lost]
            for uid in lost:
                del self._owner[uid]
        now = time.perf_counter() - self._t0
        for item in items:
            self._done.put(WorkResult(
                uid=item.uid, job=item.job, index=item.index,
                runtime=float("inf"), counters=None, cost=0.0,
                finished_at=now,
                error=f"worker process {worker} exited "
                      f"(rc={p.poll()}) with this test in flight"))

    def submit(self, item: WorkItem) -> None:
        if item.payload is None:
            raise ValueError(
                "SubprocessWorkerPool needs serializable payloads "
                "(build jobs with fleet.job_from_registry)")
        with self._lock:
            alive = [i for i in range(self.workers) if not self._dead[i]]
            if not alive:
                raise RuntimeError("all fleet worker processes have died")
            worker = min(alive, key=lambda i: self._busy[i])
            self._busy[worker] += 1
            self._items[item.uid] = item
            self._owner[item.uid] = worker
        req = dict(item.payload)
        req.update(uid=item.uid, index=int(item.index),
                   profile=bool(item.profile))
        p = self._procs[worker]
        p.stdin.write(json.dumps(req) + "\n")
        p.stdin.flush()
        self._outstanding += 1

    def collect(self, timeout: Optional[float] = None) -> WorkResult:
        res = self._done.get(timeout=timeout)
        self._outstanding -= 1
        if res.error is not None:
            raise RuntimeError(
                f"worker failed on {res.job}[{res.index}]: {res.error}")
        return res

    def outstanding(self) -> int:
        return self._outstanding

    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    def close(self) -> None:
        for p in self._procs:
            try:
                if p.stdin and not p.stdin.closed:
                    p.stdin.write(json.dumps({"op": "shutdown"}) + "\n")
                    p.stdin.flush()
                    p.stdin.close()
            except (BrokenPipeError, OSError):
                pass
        for p in self._procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()
