"""Deterministic, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step) — no pipeline state to
checkpoint, trivially elastic (a restored job at step k regenerates exactly
the batch it would have seen), and host-shardable: each process materializes
only its addressable shard of the global batch and forms the global array
via ``jax.make_array_from_process_local_data`` when running multi-host.

The token stream mimics Zipf-distributed language tokens with
document-boundary structure and next-token labels (teacher forcing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 512
    frontend: str = ""            # "vision" | "audio" | ""
    frontend_len: int = 0
    frontend_dim: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step]))


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The batch for ``step`` — identical regardless of host layout."""
    rng = _batch_rng(cfg, step)
    b, s = cfg.global_batch, cfg.seq_len
    # Zipf tokens clipped to vocab; 0 reserved as document separator
    toks = rng.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
    toks = np.clip(toks, 1, cfg.vocab_size - 1).astype(np.int32)
    # document boundaries
    n_docs = max(1, s // cfg.mean_doc_len)
    for i in range(b):
        cuts = rng.integers(0, s + 1, size=n_docs)
        toks[i, cuts] = 0
    batch = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = rng.standard_normal(
            (b, cfg.frontend_len, cfg.frontend_dim)).astype(np.float32)
    if cfg.frontend == "audio":
        batch["frames"] = rng.standard_normal(
            (b, cfg.frontend_len, cfg.frontend_dim)).astype(np.float32)
    return batch


def batch_iterator(cfg: DataConfig, start_step: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, step)
        step += 1


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict:
    """Place a host batch onto the mesh with the given shardings."""
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings
        else jax.device_put(v)
        for k, v in batch.items()
    }
