"""AdamW + schedules, from scratch (no optax), pytree-native.

Optimizer state mirrors the param tree leaf-for-leaf, so the FSDP sharding of
params transfers 1:1 to m/v (the dominant memory term at scale).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                          count=jnp.zeros((), jnp.int32))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        count = state.count + 1
        b1, b2 = self.b1, self.b2
        lr = self.lr(count)

        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state.m, grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2)
            * jnp.square(g.astype(jnp.float32)),
            state.v, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, m_, v_):
            step = (m_ / c1) / (jnp.sqrt(v_ / c2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        return updates, AdamWState(m=m, v=v, count=count)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return schedule


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.float32(lr)
