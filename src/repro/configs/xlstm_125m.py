"""xLSTM-125M: alternating sLSTM + mLSTM blocks [arXiv:2405.04517;
unverified]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, xlstm=True, sub_quadratic=True,
)

SMOKE = ARCH.scaled(
    name="xlstm-smoke", n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    vocab_size=512, dtype="float32",
)
