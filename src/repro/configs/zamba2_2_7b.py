"""Zamba2-2.7B: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, ssm_state=64, mamba_per_attn=6,
    sub_quadratic=True,
)

SMOKE = ARCH.scaled(
    name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, ssm_state=16, mamba_per_attn=2,
    dtype="float32",
)
