"""Command R+ 104B: dense GQA, no bias [hf:CohereForAI; unverified]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab_size=256000,
)

SMOKE = ARCH.scaled(
    name="command-r-plus-smoke", n_layers=2, d_model=96, n_heads=6,
    n_kv_heads=2, d_ff=192, vocab_size=512, dtype="float32",
)
