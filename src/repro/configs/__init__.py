"""Assigned-architecture configs: one module per arch, plus the catalog."""
from repro.configs import (command_r_plus_104b, deepseek_v2_236b, gemma_2b,
                           internvl2_76b, llama4_scout_17b, qwen1_5_0_5b,
                           qwen2_5_3b, seamless_m4t_large_v2, xlstm_125m,
                           zamba2_2_7b)

ARCHS = {
    m.ARCH.name: m.ARCH for m in (
        deepseek_v2_236b, llama4_scout_17b, qwen2_5_3b, command_r_plus_104b,
        qwen1_5_0_5b, gemma_2b, zamba2_2_7b, xlstm_125m, internvl2_76b,
        seamless_m4t_large_v2,
    )
}
SMOKES = {
    m.ARCH.name: m.SMOKE for m in (
        deepseek_v2_236b, llama4_scout_17b, qwen2_5_3b, command_r_plus_104b,
        qwen1_5_0_5b, gemma_2b, zamba2_2_7b, xlstm_125m, internvl2_76b,
        seamless_m4t_large_v2,
    )
}


def get_arch(name: str):
    return ARCHS[name]
