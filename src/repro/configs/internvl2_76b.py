"""InternVL2-76B backbone (InternLM2-like LLM; InternViT frontend STUBBED to
precomputed patch embeddings per the assignment) [arXiv:2404.16821;
unverified]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    frontend="vision", frontend_dim=3200, frontend_len=256,
)

SMOKE = ARCH.scaled(
    name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, frontend_dim=48, frontend_len=4,
    dtype="float32",
)
