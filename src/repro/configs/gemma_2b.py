"""Gemma 2B: GeGLU, head_dim=256, MQA [arXiv:2403.08295]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=256000, head_dim=256, activation="gelu",
    tie_embeddings=True,
)

SMOKE = ARCH.scaled(
    name="gemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    head_dim=32, d_ff=128, vocab_size=512, dtype="float32",
)
