"""DeepSeek-V2 236B: MLA + 2-shared/160-routed top-6 MoE [arXiv:2405.04434]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,                 # dense-equivalent hidden (shared path)
    moe_d_ff=1536, n_experts=160, top_k=6, n_shared_experts=2,
    vocab_size=102400, head_dim=128,
    kv_lora_rank=512, qk_rope_dim=64, v_head_dim=128,
)

SMOKE = ARCH.scaled(
    name="deepseek-v2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, moe_d_ff=32, n_experts=8, top_k=2,
    n_shared_experts=1, vocab_size=512, kv_lora_rank=32, qk_rope_dim=8,
    v_head_dim=16, dtype="float32",
)
