"""Llama-4-Scout 17B-A 16E: top-1 MoE + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, moe_d_ff=8192, n_experts=16, top_k=1, n_shared_experts=1,
    vocab_size=202048, rope_theta=500000.0,
)

SMOKE = ARCH.scaled(
    name="llama4-scout-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, moe_d_ff=128, n_experts=4, top_k=1,
    n_shared_experts=1, vocab_size=512, dtype="float32",
)
