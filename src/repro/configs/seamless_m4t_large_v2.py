"""SeamlessM4T-large-v2 backbone: 24+24 enc-dec transformer; speech frontend
STUBBED to precomputed frame embeddings per the assignment
[arXiv:2308.11596]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    enc_layers=24, dec_layers=24,
    frontend="audio", frontend_dim=1024, frontend_len=4096,
)

SMOKE = ARCH.scaled(
    name="seamless-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, enc_layers=2, dec_layers=2,
    frontend_dim=48, frontend_len=8, dtype="float32",
)
