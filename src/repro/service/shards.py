"""``ShardedConfigStore`` — one tuned-config corpus, hash-partitioned.

A single JSON store file is fine for one fleet process, but the service
multiplexes many tenants and may run next to other daemons sharing the
same corpus: every ``save()`` is a locked read-merge-write of the WHOLE
file, so unrelated keys contend on one lock and every publish re-parses
every artifact.  Sharding fixes both: keys are partitioned by
``crc32(key) % n_shards`` across ``n_shards`` ordinary ``ConfigStore``
files in one directory, so writers touching different shards never
contend and a publish only rewrites the (small) shard it lands in.

Layout::

    <root>/
      shards.json      # {"format": "repro.sharded_store", "shards": N}
      shard-00.json    # plain repro.config_store files — each individually
      shard-01.json    #   merge-safe (file lock + read-merge-write), so
      ...              #   concurrent daemons resolve conflicts per shard

``crc32`` (not Python's ``hash``) keeps the partition deterministic
across processes regardless of ``PYTHONHASHSEED`` — two daemons MUST
route the same key to the same shard file or merge safety is lost.  The
shard count is fixed at corpus creation and recorded in ``shards.json``
(written under a file lock so concurrent first-creators agree); later
openers adopt the recorded count, ignoring a conflicting request.

The facade mirrors the ``ConfigStore`` API (including the settable
``autosave`` used by ``FleetTuner``'s publish batching), tracking dirty
shards so ``save()`` only rewrites the files actually touched.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core.model import TPPCModel, TransferredModel
from repro.core.tuning_space import Config, TuningSpace
from repro.tuning.serialize import rebind_model_dict
from repro.tuning.signature import (DEFAULT_TRANSFER_THRESHOLD,
                                    SpaceSignature, similarity,
                                    transfer_compatible)
from repro.tuning.store import (ConfigStore, StoreEntry, _FileLock,
                                quarantine_file, split_key, store_key)

META_FORMAT = "repro.sharded_store"
META_VERSION = 1
META_FILE = "shards.json"
DEFAULT_SHARDS = 4


def shard_of(key: str, n_shards: int) -> int:
    """Deterministic cross-process shard index for a store key."""
    return zlib.crc32(key.encode("utf-8")) % n_shards


class ShardedConfigStore:
    """``ConfigStore``-compatible facade over ``n_shards`` store files.

    Point it at a directory; the shard files and metafile are created on
    first use.  ``autosave=True`` (default) persists the touched shard on
    every mutation, exactly like a path-bound ``ConfigStore``; setting
    ``autosave = False`` batches mutations until ``save()``, which
    flushes only dirty shards (each through the underlying store's
    locked read-merge-write, so other processes' writes merge in).
    """

    def __init__(self, root: str, n_shards: int = DEFAULT_SHARDS,
                 autosave: bool = True):
        if n_shards <= 0:
            raise ValueError("n_shards must be positive")
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.n_shards = self._bind_meta(n_shards)
        self._autosave = autosave
        self._shards: List[ConfigStore] = []
        self._dirty: set = set()
        for i in range(self.n_shards):
            # the facade owns persistence: shards never autosave themselves
            self._shards.append(
                ConfigStore(path=self._shard_path(i), autosave=False))
        self._rebalance()

    # -- wiring ----------------------------------------------------------------
    @property
    def path(self) -> str:
        """The corpus root directory (non-None: 'persistent' to callers)."""
        return self.root

    @property
    def quarantined(self) -> List[str]:
        """Damaged files moved aside across all shards (load/merge time).

        A quarantined shard comes up empty instead of crashing the load
        path; its keys are then rebuilt from peers' merges and/or the
        daemon's journal replay (``TuningDaemon`` re-puts journaled
        results that are missing from the store on ``--recover``)."""
        return [p for s in self._shards for p in s.quarantined]

    @property
    def autosave(self) -> bool:
        return self._autosave

    @autosave.setter
    def autosave(self, value: bool) -> None:
        self._autosave = bool(value)

    def _shard_path(self, i: int) -> str:
        return os.path.join(self.root, f"shard-{i:02d}.json")

    def _meta_path(self) -> str:
        return os.path.join(self.root, META_FILE)

    def _bind_meta(self, requested: int) -> int:
        """Create-or-adopt the corpus shard count, atomically.

        The metafile is the one piece of state every writer must agree
        on — a daemon partitioning by a different count would scatter a
        key across files and break per-shard merge safety.  First
        creator wins under the file lock; everyone else adopts.
        """
        meta = self._meta_path()
        with _FileLock(meta):
            if os.path.exists(meta):
                d = None
                try:
                    with open(meta) as f:
                        d = json.load(f)
                except (json.JSONDecodeError, UnicodeDecodeError, OSError):
                    d = None
                if isinstance(d, dict) and d.get("format") == META_FORMAT \
                        and isinstance(d.get("shards"), int):
                    return int(d["shards"])
                if isinstance(d, dict) \
                        and d.get("format") not in (None, META_FORMAT):
                    # a valid file of some OTHER format: caller error,
                    # not data damage — refuse loudly
                    raise ValueError(f"{meta} is not a {META_FORMAT} file")
                # torn/truncated metafile: quarantine it and re-derive
                # the count from the shard files already on disk.  Only
                # TOUCHED shards materialize, so the highest index is a
                # floor, not the count — the requested count fills in
                # (reopening with the same config is the common case).
                quarantine_file(meta, "unreadable shard metafile")
                highest = -1
                for f in os.listdir(self.root):
                    if f.startswith("shard-") and f.endswith(".json"):
                        try:
                            highest = max(highest, int(f[6:-5]))
                        except ValueError:
                            pass
                n = max(int(requested), highest + 1)
            else:
                n = int(requested)
            tmp = meta + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"format": META_FORMAT, "version": META_VERSION,
                           "shards": n}, f, indent=1)
            os.replace(tmp, meta)
            return n

    def _shard(self, key: str) -> Tuple[ConfigStore, int]:
        i = shard_of(key, self.n_shards)
        return self._shards[i], i

    def _rebalance(self) -> int:
        """Re-home keys stranded in the wrong shard by the v1→v2 key
        upgrade.

        Pre-refactor corpora partitioned by the 3-part key string;
        ``ConfigStore.load`` upgrades those keys to the 4-part
        ``kind|...`` form, whose crc32 generally lands in a DIFFERENT
        shard — lookups routing by the new hash would miss them.  Moves
        persist immediately (destination before source, so a crash can
        duplicate but never lose a key; the source shard drops its copy
        via the post-merge filter so the on-disk legacy key is not
        re-adopted).  Returns how many artifacts moved.
        """
        moved = 0
        for i, shard in enumerate(self._shards):
            bad_e = [k for k in shard._entries
                     if shard_of(k, self.n_shards) != i]
            bad_m = [k for k in shard._models
                     if shard_of(k, self.n_shards) != i]
            if not bad_e and not bad_m:
                continue

            def drop_bad(shard=shard, bad_e=tuple(bad_e),
                         bad_m=tuple(bad_m)):
                for k in bad_e:
                    shard._entries.pop(k, None)
                for k in bad_m:
                    if shard._models.pop(k, None) is not None:
                        shard._index_discard(k)

            touched = set()
            for k in bad_e:
                j = shard_of(k, self.n_shards)
                dest, other = self._shards[j], shard._entries[k]
                mine = dest._entries.get(k)
                if mine is None or other.runtime < mine.runtime:
                    dest._entries[k] = other
                    dest._dirty_entries.add(k)
                touched.add(j)
            for k in bad_m:
                j = shard_of(k, self.n_shards)
                dest, m = self._shards[j], shard._models[k]
                mine = dest._models.get(k)
                if mine is None or int(m.get("revision", 0)) \
                        > int(mine.get("revision", 0)):
                    dest._models[k] = m
                    dest._index_add(k)
                    dest._dirty_models.add(k)
                touched.add(j)
            for j in sorted(touched):
                self._shards[j].save()
            drop_bad()
            if os.path.exists(shard.path):
                shard.save(_post_merge=drop_bad)
            moved += len(bad_e) + len(bad_m)
        return moved

    def _touched(self, i: int) -> None:
        if self._autosave:
            self._shards[i].save()
        else:
            self._dirty.add(i)

    # -- tuned configs ---------------------------------------------------------
    def get(self, space: str, bucket: str, hardware: str,
            kind: Optional[str] = None) -> Optional[StoreEntry]:
        shard, _ = self._shard(store_key(space, bucket, hardware, kind=kind))
        return shard.get(space, bucket, hardware, kind=kind)

    def put(self, space: str, bucket: str, hardware: str, config: Config,
            runtime: float, trials: int,
            meta: Optional[Dict[str, Any]] = None,
            kind: Optional[str] = None) -> StoreEntry:
        shard, i = self._shard(store_key(space, bucket, hardware, kind=kind))
        entry = shard.put(space, bucket, hardware, config, runtime,
                          trials, meta, kind=kind)
        self._touched(i)
        return entry

    def entries(self) -> Iterator[StoreEntry]:
        for shard in self._shards:
            yield from shard.entries()

    def __len__(self) -> int:
        return sum(len(s) for s in self._shards)

    def __contains__(self, key: str) -> bool:
        shard, _ = self._shard(key)
        return key in shard

    # -- model artifacts -------------------------------------------------------
    def get_model_dict(self, space: str, bucket: str, hardware: str,
                       kind: Optional[str] = None) -> Optional[Dict]:
        shard, _ = self._shard(store_key(space, bucket, hardware, kind=kind))
        return shard.get_model_dict(space, bucket, hardware, kind=kind)

    def model_keys(self) -> Iterator[str]:
        for shard in self._shards:
            yield from shard.model_keys()

    def put_model_dict(self, space: str, bucket: str, hardware: str,
                       artifact: Dict, revision: Optional[int] = None,
                       n_obs: Optional[int] = None,
                       kind: Optional[str] = None) -> None:
        shard, i = self._shard(store_key(space, bucket, hardware, kind=kind))
        shard.put_model_dict(space, bucket, hardware, artifact,
                             revision=revision, n_obs=n_obs, kind=kind)
        self._touched(i)

    def load_model(self, space: str, bucket: str, hardware: str,
                   bind_space: Optional[TuningSpace] = None,
                   kind: Optional[str] = None) -> Optional[TPPCModel]:
        shard, _ = self._shard(store_key(space, bucket, hardware, kind=kind))
        return shard.load_model(space, bucket, hardware,
                                bind_space=bind_space, kind=kind)

    def save_model(self, space: str, bucket: str, hardware: str,
                   model: TPPCModel,
                   model_space: Optional[TuningSpace] = None,
                   revision: Optional[int] = None,
                   n_obs: Optional[int] = None,
                   kind: Optional[str] = None) -> None:
        shard, i = self._shard(store_key(space, bucket, hardware, kind=kind))
        shard.save_model(space, bucket, hardware, model,
                         model_space=model_space, revision=revision,
                         n_obs=n_obs, kind=kind)
        self._touched(i)

    def nearest_model_key(self, space: str, bucket: str, hardware: str,
                          kind: Optional[str] = None) -> Optional[str]:
        """Same portability tiering as ``ConfigStore``, over ALL shards.

        Exact hit short-circuits to the owning shard; otherwise the tier
        scan runs over the union of every shard's model keys (sorted, so
        ties break identically to the single-file store) — never
        crossing problem kinds.
        """
        exact = store_key(space, bucket, hardware, kind=kind)
        want_kind = split_key(exact)[0]
        shard, _ = self._shard(exact)
        if shard.get_model_dict(space, bucket, hardware,
                                kind=kind) is not None:
            return exact
        # union of the shards' (kind, space) index buckets — only keys
        # that can possibly match, sorted so ties break identically to
        # the single-file store
        first_bucket = first_hw = first_space = None
        for k in sorted(k for s_ in self._shards
                        for k in s_._model_index.get((want_kind, space), ())):
            _, _, b, h = split_key(k)
            if b == bucket:
                if first_bucket is None:
                    first_bucket = k
                    break
            elif h == hardware:
                if first_hw is None:
                    first_hw = k
            elif first_space is None:
                first_space = k
        for k in (first_bucket, first_hw, first_space):
            if k is not None:
                return k
        return None

    def transfer_candidates(self, signature: SpaceSignature,
                            bucket: str, hardware: str,
                            threshold: float = DEFAULT_TRANSFER_THRESHOLD
                            ) -> List[Tuple[str, float]]:
        """Every compatible-space model key over ALL shards, most
        preferred first — same contract as
        ``ConfigStore.transfer_candidates`` (similarity rank, ties
        toward same bucket, then same hardware, then sorted key order;
        shard layout never affects the ranking)."""
        found: List[Tuple[Tuple, str, float]] = []
        for shard in self._shards:
            for (kk, s), keys in sorted(shard._model_index.items()):
                if kk != signature.kind or s == signature.space:
                    continue
                for k in keys:
                    sig = shard.model_signature(k)
                    if sig is None \
                            or not transfer_compatible(sig, signature,
                                                       threshold=threshold):
                        continue
                    sim = similarity(sig, signature)
                    _, _, b, h = split_key(k)
                    rank = (-sim, 0 if b == bucket else 1,
                            0 if h == hardware else 1, k)
                    found.append((rank, k, sim))
        found.sort(key=lambda t: t[0])
        return [(k, sim) for _, k, sim in found]

    def nearest_transfer_key(self, signature: SpaceSignature,
                             bucket: str, hardware: str,
                             threshold: float = DEFAULT_TRANSFER_THRESHOLD
                             ) -> Optional[Tuple[str, float]]:
        """Fifth warm-start tier over ALL shards — same contract as
        ``ConfigStore.nearest_transfer_key``."""
        cands = self.transfer_candidates(signature, bucket, hardware,
                                         threshold=threshold)
        return cands[0] if cands else None

    def load_nearest_model(self, space: str, bucket: str, hardware: str,
                           bind_space: Optional[TuningSpace] = None,
                           kind: Optional[str] = None
                           ) -> Tuple[Optional[TPPCModel], Optional[str]]:
        key = self.nearest_model_key(space, bucket, hardware, kind=kind)
        if key is None:
            return None, None
        kk, s, b, h = split_key(key)
        shard, _ = self._shard(key)
        return shard.load_model(s, b, h, bind_space=bind_space,
                                kind=kk), key

    def load_transfer_model(self, signature: SpaceSignature,
                            bucket: str, hardware: str,
                            bind_space: TuningSpace,
                            threshold: float = DEFAULT_TRANSFER_THRESHOLD
                            ) -> Tuple[Optional[TransferredModel],
                                       Optional[str], float]:
        """``(model, key, similarity)`` — sharded twin of
        ``ConfigStore.load_transfer_model``."""
        found = self.nearest_transfer_key(signature, bucket, hardware,
                                          threshold=threshold)
        if found is None:
            return None, None, 0.0
        key, sim = found
        shard, _ = self._shard(key)
        try:
            model = rebind_model_dict(shard._models[key], bind_space,
                                      signature, source_key=key,
                                      similarity=sim)
        except (ValueError, KeyError, TypeError):
            return None, None, 0.0
        return model, key, sim

    def load_transfer_ensemble(self, signature: SpaceSignature,
                               bucket: str, hardware: str,
                               bind_space: TuningSpace,
                               threshold: float
                               = DEFAULT_TRANSFER_THRESHOLD,
                               limit: Optional[int] = None
                               ) -> Tuple[Optional["TransferEnsemble"],
                                          Optional[str], float]:
        """Similarity-weighted committee over every compatible artifact
        across ALL shards — sharded twin of
        ``ConfigStore.load_transfer_ensemble``."""
        from repro.core.model import TransferEnsemble

        members = []
        for key, sim in self.transfer_candidates(signature, bucket,
                                                 hardware,
                                                 threshold=threshold):
            shard, _ = self._shard(key)
            try:
                members.append((rebind_model_dict(
                    shard._models[key], bind_space, signature,
                    source_key=key, similarity=sim), sim))
            except (ValueError, KeyError, TypeError):
                continue
            if limit is not None and len(members) >= limit:
                break
        if not members:
            return None, None, 0.0
        return TransferEnsemble(members), members[0][0].source_key, \
            members[0][1]

    # -- persistence -----------------------------------------------------------
    def save(self, merge: bool = True, force: bool = False) -> str:
        """Flush dirty shards (locked read-merge-write each); return root.

        Each shard flush goes through ``ConfigStore.save``'s amortized
        path — clean shards no-op, single-writer shards skip the
        read-back, multi-writer shards delta-write only changed keys."""
        for i in sorted(self._dirty):
            self._shards[i].save(merge=merge, force=force)
        self._dirty.clear()
        return self.root

    @property
    def save_stats(self) -> Dict[str, Any]:
        """Save-path counters summed across shards (``last_s`` is the
        slowest single shard save, not a sum)."""
        totals: Dict[str, Any] = {"saves": 0, "noop": 0, "full": 0,
                                  "delta": 0, "merged_reads": 0,
                                  "last_s": 0.0, "total_s": 0.0}
        for s in self._shards:
            for k, v in s.save_stats.items():
                if k == "last_s":
                    totals[k] = max(totals[k], v)
                else:
                    totals[k] = totals.get(k, 0) + v
        totals["last_s"] = round(totals["last_s"], 9)
        totals["total_s"] = round(totals["total_s"], 9)
        return totals

    def refresh(self) -> None:
        """Merge other processes' on-disk writes into memory, all shards.

        Reads are safe without the lock — shard writes land via atomic
        ``os.replace`` — and merging (rather than reloading) preserves
        our own unflushed mutations under the usual conflict rules.
        """
        for shard in self._shards:
            if os.path.exists(shard.path):
                d = shard._read_checked(shard.path)
                if d is not None:     # damaged shard: quarantined, skipped
                    shard._merge_from(d)
        # a peer still writing v1 files may have stranded upgraded keys
        # in the wrong shard; re-home them
        self._rebalance()

    def prune(self, keep_hardware=None, keep_spaces=None,
              keep_buckets=None, keep_kinds=None,
              dry_run: bool = False) -> Dict[str, int]:
        """Per-shard ``ConfigStore.prune``, stats aggregated across shards.

        A real (non-dry) prune persists each affected shard immediately —
        inside the underlying store's locked post-merge re-filter — even
        when the facade is in batching mode, because a deferred merging
        save would re-adopt the pruned keys from disk.
        """
        totals = {"dropped_entries": 0, "kept_entries": 0,
                  "dropped_models": 0, "kept_models": 0, "dropped": 0}
        for shard in self._shards:
            was = shard.autosave
            shard.autosave = not dry_run
            try:
                stats = shard.prune(keep_hardware=keep_hardware,
                                    keep_spaces=keep_spaces,
                                    keep_buckets=keep_buckets,
                                    keep_kinds=keep_kinds,
                                    dry_run=dry_run)
            finally:
                shard.autosave = was
            for k in totals:
                totals[k] += stats[k]
        return totals
