"""Liveness + readiness probes for the tuning daemon's ``health`` op.

Kubernetes-style split: *live* means the process is making progress (the
fleet loop has ticked recently — a wedged loop with an open socket is
dead, not alive), *ready* means it can usefully accept work (not
draining, the store's directory is writable, the journal's unsynced tail
is bounded).  ``ServiceClient.health()`` reads this to decide whether to
keep a reconnecting request parked or fail it over; load balancers in
front of multiple daemons get the same answer for free.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional

# a fleet loop silent for this long is presumed wedged (its bounded
# step/wait cadence is ~0.25s, so 10s is ~40 missed ticks)
LOOP_STALL_S = 10.0

# an unsynced journal tail older than this flags the disk, not the load
JOURNAL_LAG_S = 5.0


@dataclasses.dataclass
class HealthReport:
    """One ``health`` op answer (flat, wire-friendly)."""

    live: bool
    ready: bool
    fleet_loop_alive: bool
    store_writable: bool
    draining: bool
    journal_enabled: bool
    journal_fsync_lag_s: float = 0.0
    journal_appends: int = 0
    journal_mode: Optional[str] = None
    journal_commits: int = 0       # fsync-bearing writes (group commits)
    journal_pending: int = 0       # enqueued records awaiting a commit
    loop_age_s: Optional[float] = None   # seconds since the last loop tick
    detail: str = ""

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["journal_fsync_lag_s"] = round(self.journal_fsync_lag_s, 6)
        if self.loop_age_s is not None:
            d["loop_age_s"] = round(self.loop_age_s, 6)
        return d


def store_writable(store) -> bool:
    """Can the store's backing location take a write right now?

    Probes by creating and removing a sidecar file next to the store
    (never touching the store files themselves).  An in-memory store
    (``path is None``) has nothing to fail and reports True.
    """
    path = getattr(store, "path", None)
    if path is None:
        return True
    root = path if os.path.isdir(path) \
        else (os.path.dirname(os.path.abspath(path)) or ".")
    probe = os.path.join(root, f".health_probe.{os.getpid()}")
    try:
        fd = os.open(probe, os.O_CREAT | os.O_WRONLY | os.O_TRUNC, 0o644)
        os.write(fd, b"ok")
        os.close(fd)
        os.unlink(probe)
        return True
    except OSError:
        return False


def assess(loop_age_s: Optional[float], loop_thread_alive: bool,
           draining: bool, store, journal=None) -> HealthReport:
    """Fold the daemon's raw signals into one report.

    ``loop_age_s`` is None when the daemon is driven in-process (tests)
    without its loop thread — liveness then falls back to the thread
    flag alone, which the caller sets True for in-process driving.
    """
    loop_ok = loop_thread_alive and (loop_age_s is None
                                     or loop_age_s < LOOP_STALL_S)
    writable = store_writable(store)
    lag = journal.fsync_lag_s if journal is not None else 0.0
    ready = loop_ok and writable and not draining \
        and lag < JOURNAL_LAG_S
    detail = []
    if not loop_thread_alive:
        detail.append("fleet loop not running")
    elif loop_age_s is not None and loop_age_s >= LOOP_STALL_S:
        detail.append(f"fleet loop silent {loop_age_s:.1f}s")
    if not writable:
        detail.append("store not writable")
    if draining:
        detail.append("draining")
    if lag >= JOURNAL_LAG_S:
        detail.append(f"journal fsync lag {lag:.1f}s")
    jstats: Dict[str, Any] = {}
    if journal is not None and hasattr(journal, "stats"):
        jstats = journal.stats()
    return HealthReport(
        live=loop_ok, ready=ready, fleet_loop_alive=loop_thread_alive,
        store_writable=writable, draining=draining,
        journal_enabled=journal is not None,
        journal_fsync_lag_s=lag,
        journal_appends=journal.appends if journal is not None else 0,
        journal_mode=jstats.get("mode",
                                getattr(journal, "mode", None)),
        journal_commits=int(jstats.get("commits", 0)),
        journal_pending=int(jstats.get("pending", 0)),
        loop_age_s=loop_age_s,
        detail="; ".join(detail))
