"""Tenant admission control and worker-seconds budget metering.

A *tenant* is whoever is paying for tuning — a serving engine instance, a
CI pipeline, a user.  The daemon multiplexes all of them onto one worker
pool, so two things need policing:

* **admission** — caps on how many tenants the daemon tracks and how much
  work each may have queued/active at once, so one chatty tenant cannot
  monopolize the fleet's submit queue;
* **budgets** — each tenant may carry a worker-seconds allowance
  (``budget_s``).  Spend is metered from the fleet's own ledgers:
  every loop tick the daemon diffs each running job's ``EvalAccount``
  against the snapshot taken at dispatch (``snapshot()``/``diff()``) and
  charges the delta of ``busy`` — which *includes* abandoned/retried
  attempts, so a tenant whose jobs crash lanes still pays for the burned
  worker time.  An exhausted tenant's queued work is parked and new
  submits are rejected; running jobs are allowed to finish (their cost
  was admitted when they started).

Fairness is least-spent-first: when fleet slots free up, queued requests
are admitted from the tenant with the smallest metered spend, so a cold
tenant's burst cannot starve everyone else (gain-priority inside the
fleet then orders the admitted jobs' individual trials).

Store hits bill nothing — answering from the corpus costs zero
worker-seconds, which is exactly the economics the service exists for.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.service.protocol import E_ADMISSION, E_BUDGET


class AdmissionError(Exception):
    """A submit the tenant policy refuses; ``code`` is the wire code."""

    def __init__(self, message: str, code: str = E_ADMISSION):
        super().__init__(message)
        self.code = code


@dataclasses.dataclass
class TenantState:
    """Ledger for one tenant."""

    name: str
    budget_s: Optional[float] = None   # worker-seconds allowance (None: ∞)
    spent_s: float = 0.0               # metered from EvalAccount diffs
    queued: int = 0                    # requests waiting for a fleet slot
    active: int = 0                    # requests running in the fleet
    submitted: int = 0                 # lifetime accepted submits
    store_hits: int = 0                # answered with zero trials
    rejected: int = 0                  # refused submits (any reason)
    parked: int = 0                    # queued work parked on exhaustion

    @property
    def exhausted(self) -> bool:
        return self.budget_s is not None and self.spent_s >= self.budget_s

    @property
    def remaining_s(self) -> Optional[float]:
        if self.budget_s is None:
            return None
        return max(0.0, self.budget_s - self.spent_s)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "budget_s": self.budget_s,
            "spent_s": round(self.spent_s, 6),
            "remaining_s": (None if self.remaining_s is None
                            else round(self.remaining_s, 6)),
            "exhausted": self.exhausted,
            "queued": self.queued, "active": self.active,
            "submitted": self.submitted, "store_hits": self.store_hits,
            "rejected": self.rejected, "parked": self.parked,
        }


class TenantManager:
    """Admission + budget policy for the daemon's tenant population."""

    def __init__(self, max_tenants: int = 64,
                 max_active_per_tenant: int = 4,
                 max_queued_per_tenant: int = 16,
                 default_budget_s: Optional[float] = None):
        self.max_tenants = max_tenants
        self.max_active_per_tenant = max_active_per_tenant
        self.max_queued_per_tenant = max_queued_per_tenant
        self.default_budget_s = default_budget_s
        self._tenants: Dict[str, TenantState] = {}

    def __contains__(self, name: str) -> bool:
        return name in self._tenants

    def get(self, name: str) -> Optional[TenantState]:
        return self._tenants.get(name)

    def admit(self, name: str,
              budget_s: Optional[float] = None) -> TenantState:
        """Get-or-create the tenant; raise ``AdmissionError`` when full.

        ``budget_s`` declares (or re-declares) the tenant's allowance —
        a tenant may top itself up mid-flight; ``None`` leaves whatever
        is already configured (or the daemon default for new tenants).
        """
        ts = self._tenants.get(name)
        if ts is None:
            if len(self._tenants) >= self.max_tenants:
                raise AdmissionError(
                    f"tenant table full ({self.max_tenants}); "
                    f"refusing new tenant {name!r}")
            ts = TenantState(name=name, budget_s=self.default_budget_s)
            self._tenants[name] = ts
        if budget_s is not None:
            ts.budget_s = float(budget_s)
        return ts

    def check_submit(self, ts: TenantState) -> None:
        """Police one more submit for an admitted tenant."""
        if ts.exhausted:
            ts.rejected += 1
            raise AdmissionError(
                f"tenant {ts.name!r} exhausted its worker-seconds budget "
                f"({ts.spent_s:.3f}s of {ts.budget_s:.3f}s)",
                code=E_BUDGET)
        if ts.queued >= self.max_queued_per_tenant:
            ts.rejected += 1
            raise AdmissionError(
                f"tenant {ts.name!r} has {ts.queued} queued requests "
                f"(limit {self.max_queued_per_tenant})")

    def can_start(self, ts: TenantState) -> bool:
        """May a queued request of this tenant enter the fleet now?"""
        return (not ts.exhausted
                and ts.active < self.max_active_per_tenant)

    def charge(self, ts: TenantState, worker_seconds: float) -> None:
        if worker_seconds > 0:
            ts.spent_s += worker_seconds

    def fairness_order(self, names: List[str]) -> List[str]:
        """Least-spent-first admission order (stable for ties)."""
        return sorted(names,
                      key=lambda n: (self._tenants[n].spent_s
                                     if n in self._tenants else 0.0))

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        return {name: ts.to_dict()
                for name, ts in sorted(self._tenants.items())}
