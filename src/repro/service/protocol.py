"""JSON-lines wire protocol for the tuning service.

One request per line, one response per line, strictly in order per
connection.  Every request is a JSON object with an ``"op"`` field; every
response carries ``"ok": true/false`` plus op-specific payload, and failed
ones add ``"error"`` (human-readable) and ``"code"`` (machine-checkable).

Ops
---
``ping``      liveness probe; echoes the protocol version.
``submit``    enqueue a tuning request for a tenant.  Three kinds:
              ``kind="kernel"`` names a registry benchmark
              (kernel / input / hardware), ``kind="serve"`` describes an
              online-serving space (batch_sizes × max_seqs + bucket shape)
              so drift retunes from ``OnlineAutotuner`` route through the
              shared fleet, and ``kind="problem"`` names any registered
              ``TuningProblem`` as a ``"kind:name"`` spec (e.g.
              ``"sharding:qwen2.5-3b/train_4k"``) plus optional ``params``,
              resolved through ``repro.tuning.problem``.  Responds with a
              request id immediately; a store hit resolves it inline with
              ``trials == 0``.
``status``    poll a request id: state + progress meters.
``result``    fetch the final entry for a *done* request.
``cancel``    abandon a queued or running request.
``stats``     daemon-wide snapshot: fleet progress, tenants, store size.
``health``    liveness + readiness: fleet loop alive, store writable,
              journal fsync lag, draining flag (heartbeat probe).
``shutdown``  stop accepting work; ``drain=true`` (default) finishes
              in-flight trials first.

Crash safety (protocol v2): a ``submit`` may carry a client-supplied
``idempotency_key`` (unique per logical request, per tenant).  A retried
submit after a timeout, socket drop, or daemon restart then DEDUPES onto
the original request instead of spawning a duplicate tuning run — the
response echoes the original request id with ``deduped: true``.  Without
a key, a retried submit is a new request (at-least-once semantics).

The protocol is deliberately version-tagged and flat (no nesting beyond
one level) so non-Python tenants can speak it with any JSON library.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

PROTOCOL = "repro.tuning-service"
PROTOCOL_VERSION = 2     # v2: idempotency_key on submit + health op

# Guard against a hostile/broken peer streaming an unbounded line.
MAX_LINE_BYTES = 1 << 20

OPS = ("ping", "submit", "status", "result", "cancel", "stats", "health",
       "shutdown")
SUBMIT_KINDS = ("kernel", "serve", "problem")

# Machine-checkable error codes (the ``code`` field of failed responses).
E_BAD_REQUEST = "bad_request"        # malformed JSON / failed validation
E_UNKNOWN_OP = "unknown_op"
E_UNKNOWN_REQUEST = "unknown_request"   # no such request id
E_UNKNOWN_KERNEL = "unknown_kernel"     # registry has no such kernel/input
E_UNKNOWN_PROBLEM = "unknown_problem"   # problem registry has no such spec
E_ADMISSION = "admission_denied"        # tenant/queue limits hit
E_BUDGET = "budget_exhausted"           # tenant worker-seconds budget spent
E_DRAINING = "draining"                 # daemon is shutting down
E_NOT_DONE = "not_done"                 # result requested before completion
E_INTERNAL = "internal"


class ProtocolError(ValueError):
    """A request that cannot be parsed or fails validation."""

    def __init__(self, message: str, code: str = E_BAD_REQUEST):
        super().__init__(message)
        self.code = code


def encode(obj: Dict[str, Any]) -> bytes:
    """Serialize one protocol message to a newline-terminated line."""
    return (json.dumps(obj, separators=(",", ":"), sort_keys=True)
            + "\n").encode("utf-8")


def decode(line: bytes) -> Dict[str, Any]:
    """Parse one wire line into a message dict (``ProtocolError`` if not)."""
    if len(line) > MAX_LINE_BYTES:
        raise ProtocolError(f"line exceeds {MAX_LINE_BYTES} bytes")
    try:
        obj = json.loads(line.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise ProtocolError(f"bad JSON: {exc}") from None
    if not isinstance(obj, dict):
        raise ProtocolError("message must be a JSON object")
    return obj


def ok(**payload: Any) -> Dict[str, Any]:
    resp: Dict[str, Any] = {"ok": True}
    resp.update(payload)
    return resp


def err(message: str, code: str = E_BAD_REQUEST, **payload: Any
        ) -> Dict[str, Any]:
    resp: Dict[str, Any] = {"ok": False, "error": message, "code": code}
    resp.update(payload)
    return resp


def _want(obj: Dict[str, Any], field: str, types: Tuple[type, ...],
          required: bool = True, default: Any = None) -> Any:
    if field not in obj or obj[field] is None:
        if required:
            raise ProtocolError(f"missing field {field!r}")
        return default
    val = obj[field]
    # bool is an int subclass; never accept it where a number is wanted.
    if isinstance(val, bool) and bool not in types:
        raise ProtocolError(f"field {field!r}: expected "
                            f"{'/'.join(t.__name__ for t in types)}, "
                            f"got bool")
    if not isinstance(val, types):
        raise ProtocolError(f"field {field!r}: expected "
                            f"{'/'.join(t.__name__ for t in types)}, "
                            f"got {type(val).__name__}")
    return val


def _want_num_list(obj: Dict[str, Any], field: str, required: bool = True,
                   default: Any = None) -> Optional[List[int]]:
    val = _want(obj, field, (list,), required=required, default=default)
    if val is default and not required:
        return default
    out = []
    for x in val:
        if isinstance(x, bool) or not isinstance(x, int) or x <= 0:
            raise ProtocolError(f"field {field!r}: expected a list of "
                                f"positive ints")
        out.append(x)
    if not out:
        raise ProtocolError(f"field {field!r}: must be non-empty")
    return out


def _validate_submit(obj: Dict[str, Any]) -> Dict[str, Any]:
    kind = _want(obj, "kind", (str,), required=False, default="kernel")
    if kind not in SUBMIT_KINDS:
        raise ProtocolError(f"unknown submit kind {kind!r}; "
                            f"expected one of {SUBMIT_KINDS}")
    req: Dict[str, Any] = {
        "op": "submit",
        "kind": kind,
        "tenant": _want(obj, "tenant", (str,)),
        "hardware": _want(obj, "hardware", (str,)),
        "budget": _want(obj, "budget", (int,), required=False),
        "seed": _want(obj, "seed", (int,), required=False, default=0),
        # Declares/updates the tenant's worker-seconds budget at first
        # sight; None leaves whatever the daemon already knows.
        "tenant_budget_s": _want(obj, "tenant_budget_s", (int, float),
                                 required=False),
        # Client-supplied dedupe token: a retried submit carrying the
        # same (tenant, key) resolves to the ORIGINAL request.
        "idempotency_key": _want(obj, "idempotency_key", (str,),
                                 required=False),
    }
    if not req["tenant"]:
        raise ProtocolError("field 'tenant': must be non-empty")
    if req["idempotency_key"] is not None and not req["idempotency_key"]:
        raise ProtocolError("field 'idempotency_key': must be non-empty")
    if req["budget"] is not None and req["budget"] <= 0:
        raise ProtocolError("field 'budget': must be positive")
    if kind == "kernel":
        req["kernel"] = _want(obj, "kernel", (str,))
        req["input"] = _want(obj, "input", (str,), required=False)
        req["searcher"] = _want(obj, "searcher", (str,), required=False)
    elif kind == "problem":
        # registry-resolved: "kind:name" spec + optional constructor params
        req["problem"] = _want(obj, "problem", (str,))
        req["params"] = _want(obj, "params", (dict,), required=False,
                              default={})
        req["searcher"] = _want(obj, "searcher", (str,), required=False)
        if not req["problem"]:
            raise ProtocolError("field 'problem': must be non-empty")
    else:  # serve
        req["bucket"] = _want(obj, "bucket", (str,))
        shape = _want_num_list(obj, "bucket_shape")
        if len(shape) != 2:
            raise ProtocolError("field 'bucket_shape': expected "
                                "[prompt_len, new_tokens]")
        req["bucket_shape"] = shape
        req["batch_sizes"] = _want_num_list(obj, "batch_sizes")
        req["max_seqs"] = _want_num_list(obj, "max_seqs")
        req["space"] = _want(obj, "space", (str,), required=False,
                             default="serve_online")
        req["calib_n"] = _want(obj, "calib_n", (int,), required=False,
                               default=16)
        req["stats"] = _want(obj, "stats", (dict,), required=False,
                             default={})
        # hardware outside the daemon's registry ships its spec numbers,
        # the same payload the fleet sends to subprocess lanes
        req["hardware_spec"] = _want(obj, "hardware_spec", (dict,),
                                     required=False)
        if req["calib_n"] <= 0:
            raise ProtocolError("field 'calib_n': must be positive")
    return req


def validate_request(obj: Dict[str, Any]) -> Dict[str, Any]:
    """Normalize a decoded request; raise ``ProtocolError`` if invalid.

    Returns a fresh dict holding only known fields with defaults applied,
    so daemon code never touches unvalidated client input.
    """
    op = obj.get("op")
    if not isinstance(op, str) or op not in OPS:
        raise ProtocolError(f"unknown op {op!r}; expected one of {OPS}",
                            code=E_UNKNOWN_OP)
    if op == "submit":
        return _validate_submit(obj)
    if op in ("status", "result", "cancel"):
        rid = _want(obj, "request_id", (str,))
        if not rid:
            raise ProtocolError("field 'request_id': must be non-empty")
        return {"op": op, "request_id": rid}
    if op == "shutdown":
        return {"op": op,
                "drain": _want(obj, "drain", (bool,), required=False,
                               default=True)}
    return {"op": op}  # ping / stats / health carry no payload


def read_line(sock_file, max_bytes: int = MAX_LINE_BYTES
              ) -> Optional[bytes]:
    """Read one protocol line from a file-like socket wrapper, bounded.

    Returns ``None`` on clean EOF.  Raises ``ProtocolError`` when the
    peer exceeds ``max_bytes`` before terminating the line — the bound
    caps how much a misbehaving client can make the reader buffer (the
    daemon answers ``E_BAD_REQUEST`` and closes the connection, leaving
    the rest of the oversize line undelivered on the dead socket).
    """
    line = sock_file.readline(max_bytes + 1)
    if not line:
        return None
    if len(line) > max_bytes:
        raise ProtocolError(f"line exceeds {max_bytes} bytes")
    return line
