"""``repro.service`` — tuning-as-a-service: the multi-tenant fleet daemon.

The paper's economics argument is amortization: counter-trained TP→PC
models pay for themselves when their cost is spread across hardware ports
and input changes.  A long-lived shared service is that argument at
deployment scale — every tenant's published model warm-starts the next
tenant, and a recurring (kernel, input bucket, hardware) key is answered
straight from the shared store with ZERO trials.

* ``protocol``  — the JSON-lines wire protocol (``submit`` / ``status`` /
  ``result`` / ``cancel`` / ``stats`` / ``shutdown``) with validation;
* ``daemon``    — ``TuningDaemon``: a localhost socket server multiplexing
  many tenants onto ONE elastic ``FleetTuner`` over one worker pool, with
  graceful drain on shutdown;
* ``tenants``   — admission control and per-tenant worker-seconds budget
  metering (``EvalAccount.snapshot()``/``diff()``), least-spent-first
  fairness so no tenant starves while a cold tenant burns budget;
* ``shards``    — ``ShardedConfigStore``: one corpus hash-partitioned
  across store files, so many daemons share it without lock convoys;
* ``client``    — ``ServiceClient`` (blocking, self-healing reconnect)
  and ``AsyncServiceClient`` (handle-based) speakers of the protocol;
* ``journal``   — ``RequestJournal``: the daemon's checksummed
  write-ahead request journal; replaying it under ``--recover``
  rebuilds the request table after a crash;
* ``health``    — liveness/readiness probes behind the ``health`` op.

CLI: ``python -m repro.launch.daemon`` (``--journal``/``--recover`` for
crash safety); the serve path joins with
``python -m repro.launch.serve --autotune --service HOST:PORT``.
"""
from repro.service.client import (AsyncServiceClient, PendingTuning,
                                  ServiceClient, ServiceError,
                                  ServiceUnavailable)
from repro.service.daemon import RequestRecord, TuningDaemon
from repro.service.health import HealthReport
from repro.service.journal import ReplayStats, RequestJournal
from repro.service.protocol import (PROTOCOL, PROTOCOL_VERSION,
                                    ProtocolError, validate_request)
from repro.service.shards import ShardedConfigStore
from repro.service.tenants import AdmissionError, TenantManager, TenantState

__all__ = [
    "AdmissionError", "AsyncServiceClient", "HealthReport", "PROTOCOL",
    "PROTOCOL_VERSION", "PendingTuning", "ProtocolError", "ReplayStats",
    "RequestJournal", "RequestRecord", "ServiceClient", "ServiceError",
    "ServiceUnavailable", "ShardedConfigStore", "TenantManager",
    "TenantState", "TuningDaemon", "validate_request",
]
