"""``TuningDaemon`` — the tuning-as-a-service process.

One long-lived daemon owns one worker pool, one elastic ``FleetTuner``
(started empty, jobs injected while it runs), and one shared config/model
corpus (usually a ``ShardedConfigStore``).  Tenants connect over a
localhost TCP socket and speak the JSON-lines protocol; every accepted
``submit`` becomes a ``TuningJob`` named after its request id, and the
fleet's gain-priority scheduler multiplexes all tenants' trials onto the
pool.  Three things make it a *service* rather than a batch fleet:

* **store-first answering** — a submit whose ``(space, bucket, hardware)``
  key is already in the corpus resolves immediately with ZERO trials;
  identical requests in flight are *coalesced* (followers ride the
  primary's tuning run and also pay zero);
* **tenant policy** — admission caps and per-tenant worker-seconds
  budgets, metered every loop tick from the fleet's own ``EvalAccount``
  ledgers (abandoned/retried attempts included); an exhausted tenant's
  queued work is parked and new submits rejected, without touching
  anyone else's jobs;
* **graceful drain** — ``shutdown`` (or SIGTERM via the CLI) stops
  admissions, lets in-flight empirical tests finish, resolves unfinished
  jobs as ``cancelled`` partials, and flushes the store.

Threading model: reader threads (one per connection) only touch daemon
state under ``self._lock``; the single loop thread holds the same lock
across ``admit → fleet.step → meter``, so fleet internals are never
entered concurrently.  ``step`` bounds its wait (``max_wait``) to keep
submit latency low while the pool is busy.

Crash safety: with a ``journal`` configured, every request state
transition (and every tenant budget charge) is appended to a
write-ahead ``RequestJournal`` BEFORE the acknowledging response is
sent.  A daemon restarted over the same journal with ``recover=True``
replays it: requests that finished are answered from the store,
interrupted ones are resubmitted with only their REMAINING trial
budget (progress checkpoints journal per completed trial), tenant
spend is restored so budgets survive the restart, and journaled
results missing from the store (e.g. a quarantined shard) are re-put.
Submits carrying an ``idempotency_key`` dedupe onto the original
request across retries and restarts.
"""
from __future__ import annotations

import dataclasses
import os
import selectors
import socket
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.account import AccountSnapshot
from repro.fleet import FleetTuner, JobResult, TuningJob
from repro.service import health as H
from repro.service import protocol as P
from repro.service.journal import (EV_CANCELLED, EV_CHARGE, EV_DAEMON_START,
                                   EV_DONE, EV_PROGRESS, EV_START, EV_SUBMIT,
                                   RequestJournal)
from repro.service.tenants import AdmissionError, TenantManager
from repro.tuning.store import split_key, store_key, upgrade_key

# request states (the wire-visible lifecycle)
QUEUED = "queued"
PARKED = "parked"        # queued, but its tenant's budget is exhausted
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"


@dataclasses.dataclass
class RequestRecord:
    """One accepted submit, from socket to resolution."""

    rid: str
    tenant: str
    kind: str                     # "kernel" | "serve" | "problem"
    key: str                      # kind|space|bucket|hardware store key
    state: str = QUEUED
    job: Optional[TuningJob] = None
    snap: Optional[AccountSnapshot] = None   # metering baseline
    spent_s: float = 0.0          # worker-seconds billed to this request
    trials: int = 0               # live trials this request paid for
    source: Optional[str] = None  # "store" | "tuned" | "transfer"
    #                               | "coalesced"
    primary: Optional[str] = None  # rid this request coalesced onto
    followers: List[str] = dataclasses.field(default_factory=list)
    result: Optional[Dict[str, Any]] = None
    error: Optional[str] = None
    idem: Optional[str] = None    # client-supplied idempotency key
    recovered: bool = False       # restored/resubmitted by journal replay
    resumed_trials: int = 0       # trials checkpointed before the crash

    def status_dict(self) -> Dict[str, Any]:
        return {
            "request_id": self.rid, "tenant": self.tenant,
            "kind": self.kind, "key": self.key, "state": self.state,
            "trials": self.trials, "spent_s": round(self.spent_s, 6),
            "source": self.source, "primary": self.primary,
            "error": self.error, "recovered": self.recovered,
        }


class _ConnState:
    """One client connection's buffers inside the daemon's IO loop.

    ``out`` holds ``(gate_seq, encoded_response)`` pairs in request
    order: a response may only be sent once the journal's durable
    watermark reaches its gate (0 = no durability dependency), so
    per-connection FIFO ordering and the write-ahead guarantee hold at
    the same time.
    """

    __slots__ = ("sock", "rbuf", "out", "wbuf", "closing", "interest")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.rbuf = b""
        self.out: deque = deque()
        self.wbuf = b""
        self.closing = False
        self.interest = selectors.EVENT_READ


class TuningDaemon:
    """Multi-tenant tuning service over one fleet and one store.

    ``port=0`` binds an ephemeral localhost port (read it back from
    ``daemon.port`` after ``start()``).  ``default_trial_budget`` caps
    jobs whose submit named no budget; ``gc_keep`` (a dict of ``prune``
    keep-filters) enables periodic store GC every ``gc_every_s`` of
    wall time, with the last stats kept in ``gc_stats``.
    """

    def __init__(self, pool, store,
                 host: str = "127.0.0.1", port: int = 0,
                 tenants: Optional[TenantManager] = None,
                 default_trial_budget: int = 16,
                 max_active_jobs: int = 32,
                 step_wait: float = 0.05,
                 gc_keep: Optional[Dict[str, Any]] = None,
                 gc_every_s: float = 60.0,
                 journal: Optional[Union[str, RequestJournal]] = None,
                 recover: bool = False,
                 verbose: bool = False,
                 **fleet_kwargs):
        self.pool = pool
        self.store = store
        self.host = host
        self.port = port
        self.tenants = tenants if tenants is not None else TenantManager()
        self.default_trial_budget = int(default_trial_budget)
        self.max_active_jobs = int(max_active_jobs)
        self.step_wait = float(step_wait)
        self.gc_keep = gc_keep
        self.gc_every_s = float(gc_every_s)
        self.gc_stats: Optional[Dict[str, int]] = None
        self.verbose = verbose
        self.tuner = FleetTuner([], pool, store=store, allow_empty=True,
                                on_job_done=self._on_job_done,
                                on_trial=self._on_trial,
                                **fleet_kwargs)
        self.final_report = None
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._stopped = threading.Event()
        self._draining = False
        self._seq = 0
        self._records: Dict[str, RequestRecord] = {}
        self._pending: deque = deque()          # rids waiting for the fleet
        self._by_key: Dict[str, str] = {}       # active primary per key
        self._idem: Dict[Tuple[str, str], str] = {}   # (tenant, key) -> rid
        self._server: Optional[socket.socket] = None
        self._threads: List[threading.Thread] = []
        self._loop_thread: Optional[threading.Thread] = None
        self._heartbeat: Optional[float] = None
        self._last_gc = 0.0
        self.journal: Optional[RequestJournal] = None
        if isinstance(journal, RequestJournal):
            self.journal = journal
        elif journal is not None:
            self.journal = RequestJournal(journal)
        self.recovery: Optional[Dict[str, Any]] = None
        if recover:
            if self.journal is None:
                raise ValueError("recover=True requires a journal")
            self._recover()
        if self.journal is not None:
            self.journal.append(EV_DAEMON_START, recovered=bool(recover))

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> Tuple[str, int]:
        """Bind the socket, start the IO + fleet-loop threads."""
        self._server = socket.create_server((self.host, self.port))
        self.port = self._server.getsockname()[1]
        self._wake_r, self._wake_w = os.pipe()
        os.set_blocking(self._wake_r, False)
        os.set_blocking(self._wake_w, False)
        if self.journal is not None:
            self.journal.add_commit_listener(self._notify_io)
        self.tuner.begin()
        for fn, name in ((self._io_loop, "service-io"),
                         (self._fleet_loop, "service-fleet")):
            t = threading.Thread(target=fn, name=name, daemon=True)
            t.start()
            self._threads.append(t)
            if name == "service-fleet":
                self._loop_thread = t
        if self.verbose:
            print(f"[service] listening on {self.host}:{self.port}")
        return self.host, self.port

    @property
    def address(self) -> Tuple[str, int]:
        return self.host, self.port

    def shutdown(self, drain: bool = True) -> None:
        """Stop accepting work and wind the fleet down.

        ``drain=True`` lets in-flight empirical tests finish (their
        results are collected and billed) before unfinished jobs resolve
        as ``cancelled`` partials; ``drain=False`` abandons in-flight
        work immediately (it is still billed when the lanes come back —
        the abandoned-cost policy).
        """
        with self._lock:
            if self._draining:
                return
            self._draining = True
            for rid in list(self._pending):
                self._resolve_cancelled_rid(rid, "daemon shutting down")
            self._pending.clear()
            if not drain:
                for rec in self._records.values():
                    if rec.state == RUNNING:
                        self.tuner.cancel_job(rec.rid)
            self.tuner.stop()
        self._wake.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the daemon has fully stopped."""
        return self._stopped.wait(timeout)

    def serve_forever(self) -> None:
        if self._server is None:
            self.start()
        self.wait()

    def __enter__(self) -> "TuningDaemon":
        if self._server is None:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=True)
        self.wait(timeout=60.0)

    # -- the fleet loop --------------------------------------------------------
    def _fleet_loop(self) -> None:
        while True:
            self._heartbeat = time.monotonic()
            with self._lock:
                if not self._draining:
                    self._admit_pending()
                    self._maybe_gc()
                progressed = self.tuner.step(max_wait=self.step_wait)
                self._meter()
                if self._draining and not progressed:
                    break
            if not progressed:
                self._wake.wait(0.2)
                self._wake.clear()
        with self._lock:
            self.final_report = self.tuner.finish()
            if getattr(self.store, "autosave", True) is False:
                self.store.save()
            if self.journal is not None:
                self.journal.sync()
        if self._server is not None:
            # close() alone does not wake a thread blocked in accept();
            # shutdown() forces it out with an error first
            try:
                self._server.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._server.close()
            except OSError:
                pass
        self._stopped.set()
        if self.verbose:
            print("[service] stopped")

    def _j(self, ev: str, **fields: Any) -> None:
        """Append one write-ahead journal record (no-op when disabled).

        Enqueues without waiting: ``handle`` waits for the journal tail
        to become durable AFTER releasing the request lock, so in
        ``batch`` mode one group commit covers every record the
        concurrent requests enqueued — the write-ahead guarantee (no
        ack before durability) is upheld at ~1 fsync per batch.
        """
        if self.journal is not None:
            self.journal.append(ev, wait=False, **fields)

    def _admit_pending(self) -> None:
        """Move queued requests into the fleet, least-spent tenant first."""
        active = sum(1 for r in self._records.values()
                     if r.state == RUNNING)
        if not self._pending or active >= self.max_active_jobs:
            return
        order = {n: i for i, n in enumerate(
            self.tenants.fairness_order(
                sorted({self._records[rid].tenant
                        for rid in self._pending})))}
        for rid in sorted(self._pending,
                          key=lambda r: (order[self._records[r].tenant], r)):
            if active >= self.max_active_jobs:
                break
            rec = self._records[rid]
            ts = self.tenants.get(rec.tenant)
            if ts is None:
                continue
            if ts.exhausted:
                if rec.state == QUEUED:
                    rec.state = PARKED
                    ts.parked += 1
                continue
            if rec.state == PARKED:      # budget topped back up: unpark
                rec.state = QUEUED
            if not self.tenants.can_start(ts):
                continue
            self._pending.remove(rid)
            self.tuner.add_job(rec.job)
            acct = self.tuner.job_account(rid)
            rec.snap = acct.snapshot() if acct is not None else None
            rec.state = RUNNING
            self._j(EV_START, rid=rid)
            ts.queued -= 1
            ts.active += 1
            active += 1
            if self.verbose:
                print(f"[service] {rid} -> fleet ({rec.key})")

    def _meter(self) -> None:
        """Bill each running request's worker-seconds since last tick."""
        for rec in self._records.values():
            if rec.state != RUNNING:
                continue
            acct = self.tuner.job_account(rec.rid)
            if acct is None or rec.snap is None:
                continue
            delta = acct.diff(rec.snap)
            if delta.busy > 0 or delta.steps > 0:
                ts = self.tenants.get(rec.tenant)
                if ts is not None:
                    self.tenants.charge(ts, delta.busy)
                if delta.busy > 0:
                    self._j(EV_CHARGE, tenant=rec.tenant, rid=rec.rid,
                            s=round(delta.busy, 9))
                rec.spent_s += delta.busy
                rec.snap = acct.snapshot()
                rec.trials = rec.snap.steps

    def _maybe_gc(self) -> None:
        if self.gc_keep is None:
            return
        now = self.pool.elapsed()
        if now - self._last_gc < self.gc_every_s:
            return
        self._last_gc = now
        self.gc_stats = self.store.prune(**self.gc_keep)
        if self.verbose and self.gc_stats.get("dropped"):
            print(f"[service] store GC: {self.gc_stats}")

    def _on_job_done(self, jr: JobResult) -> None:
        """Fleet callback (fires inside ``step`` under our lock)."""
        rec = self._records.get(jr.job)
        if rec is None:
            return
        self._meter_final(rec)
        ts = self.tenants.get(rec.tenant)
        if ts is not None and rec.state == RUNNING:
            ts.active -= 1
        self._by_key.pop(rec.key, None)
        if jr.cancelled or jr.best_index is None:
            rec.state = CANCELLED
            rec.error = "cancelled before completion" if jr.cancelled \
                else "every empirical test failed"
            self._j(EV_CANCELLED, rid=rec.rid, error=rec.error)
            for frid in rec.followers:
                self._resolve_cancelled_rid(
                    frid, f"primary {rec.rid} was cancelled")
        else:
            rec.state = DONE
            # a job warm-started from the cross-space transfer tier is
            # still live-tuned, but callers reading `source` learn the
            # prior came from ANOTHER space's model — with the source
            # key and similarity to judge it by
            rec.source = "transfer" if jr.transfer_from is not None \
                else "tuned"
            rec.trials = jr.trials + rec.resumed_trials
            rec.result = {
                "key": rec.key, "config": dict(jr.best_config),
                "runtime": jr.best_runtime, "trials": rec.trials,
                "searcher": jr.searcher, "warm_started": jr.warm_started,
                "source": rec.source,
            }
            if jr.transfer_from is not None:
                rec.result["transfer_from"] = jr.transfer_from
                rec.result["similarity"] = jr.transfer_similarity
            self._j(EV_DONE, rid=rec.rid, result=rec.result,
                    spent=round(rec.spent_s, 9))
            for frid in rec.followers:
                frec = self._records.get(frid)
                if frec is None or frec.state == CANCELLED:
                    continue
                fts = self.tenants.get(frec.tenant)
                if fts is not None:
                    fts.queued -= 1
                    fts.store_hits += 1
                frec.state = DONE
                frec.source = "coalesced"
                frec.result = dict(rec.result, source="coalesced",
                                   trials=0)
                self._j(EV_DONE, rid=frid, result=frec.result,
                        spent=round(frec.spent_s, 9))
        if self.verbose:
            print(f"[service] {rec.rid} {rec.state} "
                  f"(trials={rec.trials}, spent={rec.spent_s:.3f}s)")

    def _meter_final(self, rec: RequestRecord) -> None:
        acct = self.tuner.job_account(rec.rid)
        if acct is None or rec.snap is None:
            return
        delta = acct.diff(rec.snap)
        ts = self.tenants.get(rec.tenant)
        if ts is not None:
            self.tenants.charge(ts, delta.busy)
        if delta.busy > 0:
            self._j(EV_CHARGE, tenant=rec.tenant, rid=rec.rid,
                    s=round(delta.busy, 9))
        rec.spent_s += delta.busy
        rec.snap = acct.snapshot()
        rec.trials = rec.snap.steps

    def _on_trial(self, job_name: str, trials: int, best: float) -> None:
        """Fleet per-trial hook: journal a progress checkpoint so a
        crashed daemon resumes this request with its REMAINING budget
        (daemon jobs are named after their rid)."""
        rec = self._records.get(job_name)
        if rec is None:
            return
        self._j(EV_PROGRESS, rid=rec.rid,
                trials=int(trials) + rec.resumed_trials,
                best=(best if best != float("inf") else None))

    def _resolve_cancelled_rid(self, rid: str, why: str) -> None:
        rec = self._records.get(rid)
        if rec is None or rec.state in (DONE, CANCELLED):
            return
        if rec.state in (QUEUED, PARKED):
            ts = self.tenants.get(rec.tenant)
            if ts is not None:
                ts.queued -= 1
        rec.state = CANCELLED
        rec.error = why
        self._j(EV_CANCELLED, rid=rid, error=why)
        self._by_key.pop(rec.key, None)
        if rec.primary is not None:
            prec = self._records.get(rec.primary)
            if prec is not None and rid in prec.followers:
                prec.followers.remove(rid)

    # -- request handling ------------------------------------------------------
    def handle(self, req: Dict[str, Any]) -> Dict[str, Any]:
        """Dispatch one validated request (thread-safe; used directly by
        in-process tests and by the socket reader threads).

        Write-ahead discipline: ops run (and journal) under the request
        lock, but the durability wait happens AFTER the lock is
        released — concurrent requests each block only until the group
        commit covering their records lands, instead of serializing one
        fsync each inside the lock."""
        op = req["op"]
        with self._lock:
            resp = self._dispatch(op, req)
            ticket = self.journal.ticket() if self.journal is not None else 0
        if ticket:
            self.journal.wait_durable(ticket)
        return resp

    def _dispatch(self, op: str, req: Dict[str, Any]) -> Dict[str, Any]:
        if op == "ping":
            return P.ok(protocol=P.PROTOCOL, version=P.PROTOCOL_VERSION)
        if op == "submit":
            return self._op_submit(req)
        if op == "status":
            return self._op_status(req)
        if op == "result":
            return self._op_result(req)
        if op == "cancel":
            return self._op_cancel(req)
        if op == "stats":
            return self._op_stats()
        if op == "health":
            return self._op_health()
        if op == "shutdown":
            threading.Thread(target=self.shutdown,
                             kwargs={"drain": req["drain"]},
                             daemon=True).start()
            return P.ok(draining=True)
        return P.err(f"unhandled op {op!r}", code=P.E_INTERNAL)

    def _next_rid(self) -> str:
        self._seq += 1
        return f"r{self._seq:06d}"

    def _op_submit(self, req: Dict[str, Any]) -> Dict[str, Any]:
        # idempotent resubmit: a key we have seen resolves to the
        # ORIGINAL request, whatever state it is in — checked before
        # draining/admission so a crash-retry is never double-charged
        # or bounced by queue caps its first attempt already passed
        idem = req.get("idempotency_key")
        if idem is not None:
            prev = self._idem.get((req["tenant"], idem))
            if prev is not None and prev in self._records:
                return self._dedupe_response(self._records[prev])
        if self._draining:
            return P.err("daemon is draining", code=P.E_DRAINING)
        try:
            ts = self.tenants.admit(req["tenant"],
                                    budget_s=req.get("tenant_budget_s"))
            self.tenants.check_submit(ts)
        except AdmissionError as exc:
            return P.err(str(exc), code=exc.code)
        try:
            job, key = self._build_job(req)
        except P.ProtocolError as exc:
            ts.rejected += 1
            return P.err(str(exc), code=exc.code)
        rid = self._next_rid()
        rec = RequestRecord(rid=rid, tenant=req["tenant"],
                            kind=req["kind"], key=key, job=job, idem=idem)
        self._records[rid] = rec
        if idem is not None:
            self._idem[(req["tenant"], idem)] = rid
        ts.submitted += 1
        # write-ahead: the accepted submit (with its full validated
        # payload — enough to rebuild the job after a crash) is durable
        # BEFORE the client sees the request id
        self._j(EV_SUBMIT, rid=rid, key=key, idem=idem, req=req)
        # store-first: a known key is answered with zero trials
        kind, space, bucket, hw = split_key(key)
        entry = self.store.get(space, bucket, hw, kind=kind)
        if entry is not None:
            rec.state = DONE
            rec.source = "store"
            rec.result = {"key": key, "config": dict(entry.config),
                          "runtime": entry.runtime,
                          "trials": 0, "entry_trials": entry.trials,
                          "source": "store"}
            ts.store_hits += 1
            self._j(EV_DONE, rid=rid, result=rec.result, spent=0.0)
            return P.ok(request_id=rid, state=DONE, **rec.result)
        # coalesce onto an identical request already in flight
        primary = self._by_key.get(key)
        if primary is not None:
            prec = self._records[primary]
            prec.followers.append(rid)
            rec.primary = primary
            rec.source = "coalesced"
            ts.queued += 1
            return P.ok(request_id=rid, state=QUEUED, coalesced=primary)
        job.name = rid
        self._by_key[key] = rid
        self._pending.append(rid)
        ts.queued += 1
        self._wake.set()
        return P.ok(request_id=rid, state=QUEUED)

    def _dedupe_response(self, rec: RequestRecord) -> Dict[str, Any]:
        """Answer a retried submit from the original request's state."""
        if rec.state == DONE and rec.result is not None:
            return P.ok(request_id=rec.rid, state=DONE, deduped=True,
                        **rec.result)
        return P.ok(request_id=rec.rid, state=rec.state, deduped=True)

    def _build_job(self, req: Dict[str, Any]) -> Tuple[TuningJob, str]:
        budget = req["budget"] if req["budget"] is not None \
            else self.default_trial_budget
        if req["kind"] == "problem":
            from repro.fleet import job_from_problem
            from repro.tuning.problem import parse_problem
            try:
                problem = parse_problem(req["problem"], **req["params"])
            except (KeyError, ValueError, TypeError) as exc:
                raise P.ProtocolError(str(exc),
                                      code=P.E_UNKNOWN_PROBLEM) from None
            try:
                job = job_from_problem(
                    problem, req["hardware"], budget=budget,
                    seed=req["seed"], searcher=req["searcher"])
            except KeyError as exc:
                raise P.ProtocolError(f"unknown hardware: {exc}") from None
            return job, store_key(job.space.name, job.bucket,
                                  job.hardware_key, kind=job.kind)
        if req["kind"] == "kernel":
            from repro.fleet import job_from_registry
            from repro.kernels.registry import BENCHMARKS
            if req["kernel"] not in BENCHMARKS:
                raise P.ProtocolError(
                    f"unknown kernel {req['kernel']!r}; available: "
                    f"{sorted(BENCHMARKS)}", code=P.E_UNKNOWN_KERNEL)
            input_key = req["input"] if req["input"] is not None \
                else sorted(BENCHMARKS[req["kernel"]].inputs)[0]
            try:
                job = job_from_registry(
                    req["kernel"], input_key, req["hardware"],
                    budget=budget, seed=req["seed"],
                    searcher=req["searcher"])
            except KeyError as exc:
                raise P.ProtocolError(str(exc), code=P.E_UNKNOWN_KERNEL) \
                    from None
            return job, store_key(job.space.name, job.bucket,
                                  job.hardware_key, kind=job.kind)
        return self._build_serve_job(req, budget)

    def _build_serve_job(self, req: Dict[str, Any],
                         budget: int) -> Tuple[TuningJob, str]:
        """A serve-kind submit reconstructs the client's tuning problem as
        a ``ServeProblem``: the SAME space (so published model artifacts
        bind on the client side) and the portable serving workload at the
        client's explicit bucket shape, measured via the cost model with
        the client's feasibility rule."""
        from repro.core import hwspec
        from repro.core.hwspec import HardwareSpec
        from repro.fleet import job_from_problem
        from repro.serve.autotune import ServeProblem
        if req["hardware_spec"] is not None:
            # hardware outside this daemon's registry (a replica's "cpu"
            # label, a lab chip): price on the shipped spec numbers and
            # key the store by their fingerprint, like the fleet does
            try:
                hw = HardwareSpec(**req["hardware_spec"])
            except TypeError as exc:
                raise P.ProtocolError(f"bad hardware_spec: {exc}") \
                    from None
        else:
            try:
                hw = hwspec.get(req["hardware"])
            except KeyError as exc:
                raise P.ProtocolError(f"unknown hardware: {exc}") from None
        try:
            problem = ServeProblem(
                req["bucket"], batch_sizes=req["batch_sizes"],
                max_seqs=req["max_seqs"], space_name=req["space"],
                calib_n=req["calib_n"], stats=req["stats"],
                shape=tuple(req["bucket_shape"]))
        except ValueError as exc:
            raise P.ProtocolError(str(exc)) from None
        job = job_from_problem(
            problem,
            hw if req["hardware_spec"] is not None else req["hardware"],
            budget=budget, seed=req["seed"],
            name=f"serve:{req['bucket']}")   # renamed to the rid on accept
        return job, store_key(job.space.name, job.bucket,
                              job.hardware_key, kind=job.kind)

    def _op_status(self, req: Dict[str, Any]) -> Dict[str, Any]:
        rec = self._records.get(req["request_id"])
        if rec is None:
            return P.err(f"unknown request {req['request_id']!r}",
                         code=P.E_UNKNOWN_REQUEST)
        return P.ok(**rec.status_dict())

    def _op_result(self, req: Dict[str, Any]) -> Dict[str, Any]:
        rec = self._records.get(req["request_id"])
        if rec is None:
            return P.err(f"unknown request {req['request_id']!r}",
                         code=P.E_UNKNOWN_REQUEST)
        if rec.state == CANCELLED:
            return P.err(rec.error or "request was cancelled",
                         code=P.E_NOT_DONE, state=rec.state)
        if rec.state != DONE or rec.result is None:
            return P.err(f"request {rec.rid} is {rec.state}",
                         code=P.E_NOT_DONE, state=rec.state)
        return P.ok(request_id=rec.rid, state=DONE, **rec.result)

    def _op_cancel(self, req: Dict[str, Any]) -> Dict[str, Any]:
        rec = self._records.get(req["request_id"])
        if rec is None:
            return P.err(f"unknown request {req['request_id']!r}",
                         code=P.E_UNKNOWN_REQUEST)
        if rec.state in (DONE, CANCELLED):
            return P.ok(request_id=rec.rid, state=rec.state,
                        cancelled=False)
        if rec.state in (QUEUED, PARKED):
            if rec.primary is None and rec.rid in self._pending:
                self._pending.remove(rec.rid)
            self._resolve_cancelled_rid(rec.rid, "cancelled by client")
        else:  # RUNNING: the fleet abandons its in-flight tests
            self.tuner.cancel_job(rec.rid)
        return P.ok(request_id=rec.rid, state=rec.state, cancelled=True)

    def _op_stats(self) -> Dict[str, Any]:
        by_state: Dict[str, int] = {}
        by_source: Dict[str, int] = {}
        for rec in self._records.values():
            by_state[rec.state] = by_state.get(rec.state, 0) + 1
            if rec.source is not None:
                by_source[rec.source] = by_source.get(rec.source, 0) + 1
        return P.ok(
            protocol=P.PROTOCOL, version=P.PROTOCOL_VERSION,
            draining=self._draining,
            fleet=self.tuner.progress(),
            tenants=self.tenants.snapshot(),
            requests=by_state,
            sources=by_source,
            transfers=by_source.get("transfer", 0),
            store_entries=len(self.store),
            gc=self.gc_stats,
            journal=(None if self.journal is None
                     else dict({"path": self.journal.path,
                                "appends": self.journal.appends,
                                "fsync_lag_s": round(
                                    self.journal.fsync_lag_s, 6)},
                               **self.journal.stats())),
            store_saves=getattr(self.store, "save_stats", None),
            recovery=self.recovery,
        )

    def _op_health(self) -> Dict[str, Any]:
        """Liveness + readiness (the ``health``/heartbeat op).

        In-process driving (tests, recovery drills) has no loop thread;
        liveness then reports on the daemon state alone."""
        alive = self._loop_thread.is_alive() \
            if self._loop_thread is not None else not self._stopped.is_set()
        age = None if self._heartbeat is None \
            else time.monotonic() - self._heartbeat
        rep = H.assess(age, alive, self._draining, self.store,
                       self.journal)
        return P.ok(**rep.to_dict())

    # -- crash recovery --------------------------------------------------------
    def _recover(self) -> None:
        """Rebuild daemon state by replaying the write-ahead journal.

        Runs in the constructor, before any socket or loop exists:

        * resolved requests (``done``/``cancelled``) are restored so old
          request ids keep answering ``status``/``result``;
        * journaled results MISSING from the store are re-put (this is
          how a quarantined shard gets rebuilt from the journal);
        * unfinished requests are resubmitted through ``_build_job``
          with their remaining trial budget (journaled ``progress``
          checkpoints), re-coalescing identical keys; a request whose
          key reached the store before the crash is answered from it;
        * tenant budgets/spend are restored from ``submit`` payloads
          and ``charge`` records, so a restart cannot reset anyone's
          allowance.
        """
        events, jstats = self.journal.replay()
        seen: Dict[str, Dict[str, Any]] = {}
        order: List[str] = []
        spend: Dict[str, float] = {}
        for ev in events:
            kind = ev.get("ev")
            rid = ev.get("rid")
            if kind == EV_SUBMIT and rid is not None:
                seen[rid] = {"req": ev.get("req") or {},
                             "key": ev.get("key"),
                             "idem": ev.get("idem"),
                             "state": QUEUED, "trials": 0,
                             "spent": 0.0, "result": None, "error": None}
                if rid not in order:
                    order.append(rid)
                try:
                    self._seq = max(self._seq, int(rid.lstrip("r")))
                except ValueError:
                    pass
            elif kind == EV_CHARGE:
                t = ev.get("tenant")
                if t is not None:
                    spend[t] = spend.get(t, 0.0) + float(ev.get("s", 0.0))
                if rid in seen:
                    seen[rid]["spent"] += float(ev.get("s", 0.0))
            elif kind == EV_PROGRESS and rid in seen:
                seen[rid]["trials"] = int(ev.get("trials", 0))
            elif kind == EV_DONE and rid in seen:
                seen[rid]["state"] = DONE
                seen[rid]["result"] = ev.get("result")
            elif kind == EV_CANCELLED and rid in seen:
                seen[rid]["state"] = CANCELLED
                seen[rid]["error"] = ev.get("error")
        stats = {"requests": len(order), "restored_done": 0,
                 "restored_cancelled": 0, "answered_from_store": 0,
                 "resubmitted": 0, "rebuild_failed": 0,
                 "repaired_entries": 0, "journal": jstats.to_dict()}
        # tenants first: budgets + spend survive the restart
        for rid in order:
            req = seen[rid]["req"]
            if req.get("tenant"):
                try:
                    ts = self.tenants.admit(
                        req["tenant"], budget_s=req.get("tenant_budget_s"))
                    ts.submitted += 1
                except AdmissionError:
                    pass             # smaller table post-restart: best effort
        for tenant, s in spend.items():
            ts = self.tenants.get(tenant)
            if ts is not None:
                self.tenants.charge(ts, s)
        # repair the store from journaled results it is missing (e.g. a
        # shard quarantined by a checksum failure)
        for rid in order:
            res = seen[rid]["result"]
            if seen[rid]["state"] != DONE or not res \
                    or not res.get("config") or not seen[rid]["key"]:
                continue
            kind, space, bucket, hw = split_key(seen[rid]["key"])
            if self.store.get(space, bucket, hw, kind=kind) is None:
                self.store.put(space, bucket, hw,
                               config=dict(res["config"]),
                               runtime=float(res["runtime"]),
                               trials=int(res.get("trials", 0)),
                               meta={"recovered": True, "rid": rid},
                               kind=kind)
                stats["repaired_entries"] += 1
        # rebuild the request table
        for rid in order:
            s = seen[rid]
            req = s["req"]
            rec = RequestRecord(
                rid=rid, tenant=req.get("tenant", "?"),
                kind=req.get("kind", "kernel"),
                key=upgrade_key(s["key"]) if s["key"] else "?|?|?",
                idem=s["idem"], recovered=True)
            self._records[rid] = rec
            if s["idem"] is not None and req.get("tenant"):
                self._idem[(req["tenant"], s["idem"])] = rid
            ts = self.tenants.get(rec.tenant)
            if s["state"] == DONE:
                rec.state = DONE
                rec.result = s["result"]
                rec.source = (s["result"] or {}).get("source")
                rec.trials = int((s["result"] or {}).get("trials", 0))
                rec.spent_s = s["spent"]
                stats["restored_done"] += 1
                continue
            if s["state"] == CANCELLED:
                rec.state = CANCELLED
                rec.error = s["error"] or "cancelled before daemon crash"
                stats["restored_cancelled"] += 1
                continue
            # unfinished at crash time: answer from the store if its key
            # landed, else resubmit with the remaining budget
            rec.spent_s = s["spent"]
            rec.resumed_trials = s["trials"]
            kind, space, bucket, hw = split_key(rec.key)
            entry = self.store.get(space, bucket, hw, kind=kind)
            if entry is not None:
                rec.state = DONE
                rec.source = "store"
                rec.result = {"key": rec.key,
                              "config": dict(entry.config),
                              "runtime": entry.runtime, "trials": 0,
                              "entry_trials": entry.trials,
                              "source": "store"}
                if ts is not None:
                    ts.store_hits += 1
                self._j(EV_DONE, rid=rid, result=rec.result,
                        spent=round(rec.spent_s, 9))
                stats["answered_from_store"] += 1
                continue
            try:
                job, _ = self._build_job(req)
            except (P.ProtocolError, KeyError, TypeError) as exc:
                rec.state = CANCELLED
                rec.error = f"recovery could not rebuild job: {exc}"
                self._j(EV_CANCELLED, rid=rid, error=rec.error)
                stats["rebuild_failed"] += 1
                continue
            job.budget = max(1, job.budget - rec.resumed_trials)
            rec.job = job
            primary = self._by_key.get(rec.key)
            if primary is not None:
                self._records[primary].followers.append(rid)
                rec.primary = primary
                rec.source = "coalesced"
            else:
                job.name = rid
                self._by_key[rec.key] = rid
                self._pending.append(rid)
            if ts is not None:
                ts.queued += 1
            stats["resubmitted"] += 1
        self.recovery = stats
        if self.verbose:
            print(f"[service] recovery: {stats}")

    # -- socket plumbing -------------------------------------------------------
    #
    # One selector-driven IO thread serves every connection.  The old
    # thread-per-connection reader convoyed on the GIL under a
    # multi-tenant submit storm (8 readers × small CPU bursts); a single
    # event loop removes that contention AND lets acks be *deferred*
    # instead of blocked-on: a response whose journal records are not
    # yet group-committed is parked on the connection's output queue and
    # flushed when the committer's fsync lands (the journal commit
    # listener pokes the loop's self-pipe).  The write-ahead guarantee —
    # no ack before durability — is upheld without any reader ever
    # sleeping in ``wait_durable``.  In-process callers keep using
    # ``handle()``, which still blocks.

    def _notify_io(self) -> None:
        """Journal commit listener: wake the IO loop (never blocks)."""
        try:
            os.write(self._wake_w, b"\0")
        except (OSError, ValueError):
            pass

    def _io_loop(self) -> None:
        sel = selectors.DefaultSelector()
        self._server.setblocking(False)
        sel.register(self._server, selectors.EVENT_READ, "accept")
        sel.register(self._wake_r, selectors.EVENT_READ, "wake")
        conns: set = set()
        server_open = True
        while True:
            try:
                ready = sel.select(timeout=0.5)
            except OSError:
                break
            # drain everything available before deciding the burst is
            # over: a storm's submits land as several TCP segments a
            # few tens of microseconds apart, and kicking the journal
            # between them would split one coalescable burst across
            # fsyncs (bounded passes keep the stop check responsive)
            for _ in range(64):
                if not ready:
                    break
                server_open = self._io_handle(sel, conns, ready,
                                              server_open)
                try:
                    ready = sel.select(timeout=0)
                except OSError:
                    ready = []
            # event queue drained with acks still parked on the journal:
            # no more records are imminent, so end the committer's
            # quiesce window — the whole burst goes into one fsync NOW
            if self.journal is not None:
                for cs in conns:
                    if cs.out:
                        self.journal.kick()
                        break
            if self._stopped.is_set() and not conns and not server_open:
                break
        sel.close()

    def _io_handle(self, sel, conns, ready, server_open: bool) -> bool:
        for key, events in ready:
            if key.data == "accept":
                server_open = self._io_accept(sel, conns)
            elif key.data == "wake":
                try:
                    os.read(self._wake_r, 4096)
                except (OSError, BlockingIOError):
                    pass
                for cs in list(conns):
                    if cs.out or cs.wbuf:
                        self._io_flush(sel, conns, cs)
            else:
                cs = key.data
                if events & selectors.EVENT_READ:
                    self._io_read(sel, conns, cs)
                if cs in conns and events & selectors.EVENT_WRITE:
                    self._io_flush(sel, conns, cs)
        return server_open

    def _io_accept(self, sel, conns) -> bool:
        """Accept every pending connection; False once the listening
        socket is gone (daemon stopping — existing conns live on)."""
        while True:
            try:
                sock, _ = self._server.accept()
            except (BlockingIOError, InterruptedError):
                return True
            except OSError:
                try:
                    sel.unregister(self._server)
                except (KeyError, ValueError):
                    pass
                return False
            sock.setblocking(False)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            cs = _ConnState(sock)
            sel.register(sock, selectors.EVENT_READ, cs)
            conns.add(cs)

    def _io_read(self, sel, conns, cs) -> None:
        try:
            data = cs.sock.recv(1 << 16)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._io_drop(sel, conns, cs)
            return
        if not data:
            # peer EOF: answer what was fully received, then close
            cs.closing = True
            if cs.rbuf and not cs.rbuf.endswith(b"\n"):
                cs.rbuf = b""        # torn trailing line: nothing to answer
        else:
            cs.rbuf += data
        while True:
            nl = cs.rbuf.find(b"\n")
            if nl < 0:
                if len(cs.rbuf) > P.MAX_LINE_BYTES:
                    self._io_protocol_error(cs)
                break
            line, cs.rbuf = cs.rbuf[:nl + 1], cs.rbuf[nl + 1:]
            if len(line) > P.MAX_LINE_BYTES:
                self._io_protocol_error(cs)
                break
            if not line.strip():
                continue
            self._io_request(cs, line)
        self._io_flush(sel, conns, cs)

    def _io_protocol_error(self, cs) -> None:
        """Oversize line: bounded-buffer refusal, then hang up (the rest
        of the oversize line dies with the connection)."""
        cs.out.append((0, P.encode(P.err(
            f"line exceeds {P.MAX_LINE_BYTES} bytes"))))
        cs.closing = True
        cs.rbuf = b""

    def _io_request(self, cs, line: bytes) -> None:
        """Dispatch one request line; queue its response behind the
        journal ticket covering the records it appended."""
        gate = 0
        try:
            req = P.validate_request(P.decode(line))
            with self._lock:
                resp = self._dispatch(req["op"], req)
                if self.journal is not None:
                    gate = self.journal.ticket()
        except P.ProtocolError as exc:
            resp, gate = P.err(str(exc), code=exc.code), 0
        except Exception as exc:        # never kill the IO loop
            resp = P.err(f"{type(exc).__name__}: {exc}", code=P.E_INTERNAL)
            gate = 0
        cs.out.append((gate, P.encode(resp)))

    def _io_flush(self, sel, conns, cs) -> None:
        """Move durable responses into the write buffer, push bytes,
        and keep the selector's write interest honest."""
        durable: Optional[int] = None
        while cs.out:
            gate, payload = cs.out[0]
            if gate:
                if durable is None:
                    durable = (self.journal.durable_upto()
                               if self.journal is not None else 0)
                if gate > durable:
                    err = (self.journal.commit_error()
                           if self.journal is not None else None)
                    if err is None:
                        break       # parked until the commit listener fires
                    payload = P.encode(P.err(
                        f"journal write failed: {err}", code=P.E_INTERNAL))
            cs.out.popleft()
            cs.wbuf += payload
        if cs.wbuf:
            try:
                sent = cs.sock.send(cs.wbuf)
                cs.wbuf = cs.wbuf[sent:]
            except (BlockingIOError, InterruptedError):
                pass
            except OSError:
                self._io_drop(sel, conns, cs)
                return
        want = selectors.EVENT_READ | (selectors.EVENT_WRITE
                                       if cs.wbuf else 0)
        if want != cs.interest:
            try:
                sel.modify(cs.sock, want, cs)
                cs.interest = want
            except (KeyError, ValueError):
                pass
        if cs.closing and not cs.wbuf and not cs.out:
            self._io_drop(sel, conns, cs)

    @staticmethod
    def _io_drop(sel, conns, cs) -> None:
        try:
            sel.unregister(cs.sock)
        except (KeyError, ValueError):
            pass
        try:
            cs.sock.close()
        except OSError:
            pass
        conns.discard(cs)
