"""Client side of the tuning service: blocking and handle-based callers.

``ServiceClient`` owns one socket and speaks the JSON-lines protocol
strictly request→response; it is thread-safe (a lock serializes the
socket) and reconnects lazily, so a client object can outlive daemon
restarts.  Failed responses raise ``ServiceError`` carrying the wire
``code``; transport failures (daemon not running, connection refused,
timeout) raise ``ServiceUnavailable`` — callers like the serve path
catch *that* to fall back to in-process tuning.

``AsyncServiceClient`` layers fire-and-forget submits on top: every
submit returns a ``PendingTuning`` handle whose ``result()`` blocks only
when the answer is actually needed — the natural shape for a serving
engine that wants tuning off its tick path.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Any, Dict, Optional, Sequence, Tuple, Union

from repro.service import protocol as P


class ServiceError(RuntimeError):
    """The daemon refused a request; ``code`` is the wire error code."""

    def __init__(self, message: str, code: str = P.E_INTERNAL,
                 response: Optional[Dict[str, Any]] = None):
        super().__init__(message)
        self.code = code
        self.response = response or {}


class ServiceUnavailable(ServiceError):
    """No daemon answered (refused / reset / timed out)."""

    def __init__(self, message: str):
        super().__init__(message, code="unavailable")


def parse_address(address: Union[str, Tuple[str, int]]
                  ) -> Tuple[str, int]:
    """``"host:port"`` / ``":port"`` / ``(host, port)`` → ``(host, port)``."""
    if isinstance(address, tuple):
        return address[0], int(address[1])
    host, _, port = address.rpartition(":")
    if not port.isdigit():
        raise ValueError(f"bad service address {address!r} "
                         f"(expected host:port)")
    return host or "127.0.0.1", int(port)


class ServiceClient:
    """Blocking JSON-lines client for one tuning daemon."""

    def __init__(self, address: Union[str, Tuple[str, int]],
                 timeout: float = 30.0):
        self.host, self.port = parse_address(address)
        self.timeout = float(timeout)
        self._lock = threading.Lock()
        self._sock: Optional[socket.socket] = None
        self._rfile = None

    # -- transport -------------------------------------------------------------
    def _connect(self) -> None:
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.timeout)
        self._sock = sock
        self._rfile = sock.makefile("rb")

    def _reset(self) -> None:
        if self._rfile is not None:
            try:
                self._rfile.close()
            except OSError:
                pass
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        self._sock = None
        self._rfile = None

    def close(self) -> None:
        with self._lock:
            self._reset()

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def call(self, obj: Dict[str, Any]) -> Dict[str, Any]:
        """One raw request→response round trip (no ok-checking)."""
        with self._lock:
            try:
                if self._sock is None:
                    self._connect()
                self._sock.sendall(P.encode(obj))
                line = P.read_line(self._rfile)
            except (OSError, P.ProtocolError) as exc:
                self._reset()
                raise ServiceUnavailable(
                    f"tuning service at {self.host}:{self.port} "
                    f"unavailable: {exc}") from None
            if line is None:
                self._reset()
                raise ServiceUnavailable(
                    f"tuning service at {self.host}:{self.port} "
                    f"closed the connection")
            return P.decode(line)

    def _checked(self, obj: Dict[str, Any]) -> Dict[str, Any]:
        resp = self.call(obj)
        if not resp.get("ok"):
            raise ServiceError(resp.get("error", "request failed"),
                               code=resp.get("code", P.E_INTERNAL),
                               response=resp)
        return resp

    # -- ops -------------------------------------------------------------------
    def ping(self) -> Dict[str, Any]:
        return self._checked({"op": "ping"})

    def submit_kernel(self, tenant: str, kernel: str, hardware: str,
                      input: Optional[str] = None,
                      budget: Optional[int] = None, seed: int = 0,
                      searcher: Optional[str] = None,
                      tenant_budget_s: Optional[float] = None
                      ) -> Dict[str, Any]:
        return self._checked({
            "op": "submit", "kind": "kernel", "tenant": tenant,
            "kernel": kernel, "input": input, "hardware": hardware,
            "budget": budget, "seed": seed, "searcher": searcher,
            "tenant_budget_s": tenant_budget_s})

    def submit_serve(self, tenant: str, hardware: str, bucket: str,
                     bucket_shape: Sequence[int],
                     batch_sizes: Sequence[int],
                     max_seqs: Sequence[int],
                     space: str = "serve_online", calib_n: int = 16,
                     stats: Optional[Dict[str, Any]] = None,
                     budget: Optional[int] = None, seed: int = 0,
                     tenant_budget_s: Optional[float] = None,
                     hardware_spec: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
        return self._checked({
            "op": "submit", "kind": "serve", "tenant": tenant,
            "hardware": hardware, "bucket": bucket,
            "bucket_shape": list(bucket_shape),
            "batch_sizes": list(batch_sizes),
            "max_seqs": list(max_seqs), "space": space,
            "calib_n": calib_n, "stats": dict(stats or {}),
            "budget": budget, "seed": seed,
            "tenant_budget_s": tenant_budget_s,
            "hardware_spec": hardware_spec})

    def status(self, request_id: str) -> Dict[str, Any]:
        return self._checked({"op": "status", "request_id": request_id})

    def result(self, request_id: str, timeout: Optional[float] = None,
               poll: float = 0.05) -> Dict[str, Any]:
        """Block until the request resolves; return its result payload.

        Raises ``ServiceError(code="not_done")`` if the request was
        cancelled, ``TimeoutError`` past ``timeout`` seconds.
        """
        deadline = None if timeout is None \
            else time.monotonic() + float(timeout)
        while True:
            st = self.status(request_id)
            if st["state"] == "done":
                return self._checked({"op": "result",
                                      "request_id": request_id})
            if st["state"] == "cancelled":
                raise ServiceError(
                    st.get("error") or f"request {request_id} cancelled",
                    code=P.E_NOT_DONE, response=st)
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(
                    f"request {request_id} still {st['state']} after "
                    f"{timeout}s")
            time.sleep(poll)

    def cancel(self, request_id: str) -> Dict[str, Any]:
        return self._checked({"op": "cancel", "request_id": request_id})

    def stats(self) -> Dict[str, Any]:
        return self._checked({"op": "stats"})

    def shutdown(self, drain: bool = True) -> Dict[str, Any]:
        return self._checked({"op": "shutdown", "drain": drain})


class PendingTuning:
    """Handle for one submitted request (async client)."""

    def __init__(self, client: ServiceClient, request_id: str,
                 submit_response: Dict[str, Any]):
        self.client = client
        self.request_id = request_id
        self.submit_response = submit_response

    def status(self) -> Dict[str, Any]:
        return self.client.status(self.request_id)

    def done(self) -> bool:
        return self.status()["state"] in ("done", "cancelled")

    def result(self, timeout: Optional[float] = None,
               poll: float = 0.05) -> Dict[str, Any]:
        return self.client.result(self.request_id, timeout=timeout,
                                  poll=poll)

    def cancel(self) -> Dict[str, Any]:
        return self.client.cancel(self.request_id)


class AsyncServiceClient:
    """Handle-based wrapper: submits return ``PendingTuning``."""

    def __init__(self, address: Union[str, Tuple[str, int]],
                 timeout: float = 30.0):
        self.client = ServiceClient(address, timeout=timeout)

    def submit_kernel(self, *args, **kwargs) -> PendingTuning:
        resp = self.client.submit_kernel(*args, **kwargs)
        return PendingTuning(self.client, resp["request_id"], resp)

    def submit_serve(self, *args, **kwargs) -> PendingTuning:
        resp = self.client.submit_serve(*args, **kwargs)
        return PendingTuning(self.client, resp["request_id"], resp)

    def stats(self) -> Dict[str, Any]:
        return self.client.stats()

    def close(self) -> None:
        self.client.close()

    def __enter__(self) -> "AsyncServiceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
