"""Client side of the tuning service: blocking and handle-based callers.

``ServiceClient`` owns one socket and speaks the JSON-lines protocol
strictly request→response; it is thread-safe (a lock serializes the
socket) and reconnects lazily, so a client object can outlive daemon
restarts.  Failed responses raise ``ServiceError`` carrying the wire
``code``; transport failures (daemon not running, connection refused,
timeout) raise ``ServiceUnavailable`` — callers like the serve path
catch *that* to fall back to in-process tuning.

Self-healing: ``call`` distinguishes *request never sent* (connect or
send failed — the daemon cannot have acted on it, always safe to retry)
from *response never read* (sent, then the socket died — the daemon may
have already executed it).  The former is retried up to ``retries``
times with exponential backoff + jitter; the latter is retried only for
idempotent operations — reads (``status``/``result``/``stats``/
``health``/``ping``), operations safe to repeat (``cancel``,
``shutdown``), and submits that carry an ``idempotency_key`` (the daemon
dedupes those onto the original request, even across its own restarts).
A bare submit whose response was lost raises ``ServiceUnavailable``
rather than risk a duplicate paid tuning run.

``AsyncServiceClient`` layers fire-and-forget submits on top: every
submit returns a ``PendingTuning`` handle whose ``result()`` blocks only
when the answer is actually needed — the natural shape for a serving
engine that wants tuning off its tick path.  Async submits generate an
idempotency key automatically, so handles survive daemon crashes: the
daemon recovers the request from its journal and ``result()`` rides out
the restart inside its reconnect window.
"""
from __future__ import annotations

import random
import socket
import threading
import time
import uuid
from typing import Any, Dict, Optional, Sequence, Tuple, Union

from repro.service import protocol as P


class ServiceError(RuntimeError):
    """The daemon refused a request; ``code`` is the wire error code."""

    def __init__(self, message: str, code: str = P.E_INTERNAL,
                 response: Optional[Dict[str, Any]] = None):
        super().__init__(message)
        self.code = code
        self.response = response or {}


class ServiceUnavailable(ServiceError):
    """No daemon answered (refused / reset / timed out)."""

    def __init__(self, message: str):
        super().__init__(message, code="unavailable")


def parse_address(address: Union[str, Tuple[str, int]]
                  ) -> Tuple[str, int]:
    """``"host:port"`` / ``":port"`` / ``(host, port)`` → ``(host, port)``."""
    if isinstance(address, tuple):
        return address[0], int(address[1])
    host, _, port = address.rpartition(":")
    if not port.isdigit():
        raise ValueError(f"bad service address {address!r} "
                         f"(expected host:port)")
    return host or "127.0.0.1", int(port)


class ServiceClient:
    """Blocking JSON-lines client for one tuning daemon.

    ``retries`` bounds reconnect attempts per call; waits grow
    ``backoff * 2**attempt`` (capped at ``backoff_max``) with up to 50%
    jitter so a daemon restart is not greeted by a synchronized thundering
    herd of clients.  ``deadline`` per call (or the ``timeout`` socket
    default) bounds total wall time including the backoff sleeps.
    """

    def __init__(self, address: Union[str, Tuple[str, int]],
                 timeout: float = 30.0, retries: int = 3,
                 backoff: float = 0.05, backoff_max: float = 2.0,
                 jitter_seed: Optional[int] = None):
        self.host, self.port = parse_address(address)
        self.timeout = float(timeout)
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.backoff_max = float(backoff_max)
        self._rng = random.Random(jitter_seed)
        self._lock = threading.Lock()
        self._sock: Optional[socket.socket] = None
        self._rfile = None

    # -- transport -------------------------------------------------------------
    def _connect(self) -> None:
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.timeout)
        self._sock = sock
        self._rfile = sock.makefile("rb")

    def _reset(self) -> None:
        if self._rfile is not None:
            try:
                self._rfile.close()
            except OSError:
                pass
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        self._sock = None
        self._rfile = None

    def close(self) -> None:
        with self._lock:
            self._reset()

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _sleep_backoff(self, attempt: int,
                       deadline: Optional[float]) -> None:
        wait = min(self.backoff_max, self.backoff * (2 ** attempt))
        wait *= 1.0 + 0.5 * self._rng.random()
        if deadline is not None:
            wait = min(wait, max(0.0, deadline - time.monotonic()))
        if wait > 0:
            time.sleep(wait)

    def _round_trip(self, obj: Dict[str, Any]) -> Dict[str, Any]:
        """One attempt.  Raises ``(sent, exc)`` info via exception args."""
        sent = False
        try:
            if self._sock is None:
                self._connect()
            self._sock.sendall(P.encode(obj))
            sent = True
            line = P.read_line(self._rfile)
        except (OSError, P.ProtocolError) as exc:
            self._reset()
            raise _TransportFailure(sent, str(exc)) from None
        if line is None:
            self._reset()
            raise _TransportFailure(True, "daemon closed the connection")
        return P.decode(line)

    def call(self, obj: Dict[str, Any], idempotent: bool = False,
             deadline_s: Optional[float] = None) -> Dict[str, Any]:
        """One request→response round trip (no ok-checking), self-healing.

        ``idempotent=True`` allows retrying even after the request may
        have reached the daemon (response lost); otherwise only
        never-sent failures retry.  ``deadline_s`` caps total time spent
        across attempts and backoff waits.
        """
        deadline = None if deadline_s is None \
            else time.monotonic() + float(deadline_s)
        last = "unavailable"
        with self._lock:
            for attempt in range(self.retries + 1):
                if deadline is not None and time.monotonic() >= deadline:
                    break
                try:
                    return self._round_trip(obj)
                except _TransportFailure as tf:
                    last = tf.detail
                    retryable = idempotent or not tf.sent
                    if not retryable or attempt >= self.retries:
                        if tf.sent and not idempotent:
                            last += (" (request may have been received; "
                                     "not retrying a non-idempotent op)")
                        break
                    self._sleep_backoff(attempt, deadline)
        raise ServiceUnavailable(
            f"tuning service at {self.host}:{self.port} "
            f"unavailable: {last}")

    def _checked(self, obj: Dict[str, Any], idempotent: bool = False,
                 deadline_s: Optional[float] = None) -> Dict[str, Any]:
        resp = self.call(obj, idempotent=idempotent,
                         deadline_s=deadline_s)
        if not resp.get("ok"):
            raise ServiceError(resp.get("error", "request failed"),
                               code=resp.get("code", P.E_INTERNAL),
                               response=resp)
        return resp

    # -- ops -------------------------------------------------------------------
    def ping(self) -> Dict[str, Any]:
        return self._checked({"op": "ping"}, idempotent=True)

    def health(self) -> Dict[str, Any]:
        """Daemon liveness/readiness report (see ``service.health``)."""
        return self._checked({"op": "health"}, idempotent=True)

    # the ops vocabulary calls this the heartbeat; same probe
    heartbeat = health

    def wait_ready(self, timeout: float = 30.0,
                   poll: float = 0.1) -> Dict[str, Any]:
        """Block until the daemon reports ``ready`` (or raise on timeout)."""
        deadline = time.monotonic() + float(timeout)
        last: Dict[str, Any] = {}
        while time.monotonic() < deadline:
            try:
                last = self.health()
                if last.get("ready"):
                    return last
            except ServiceUnavailable:
                pass
            time.sleep(poll)
        raise ServiceUnavailable(
            f"tuning service at {self.host}:{self.port} not ready "
            f"after {timeout}s: {last.get('detail', 'unreachable')}")

    def submit_kernel(self, tenant: str, kernel: str, hardware: str,
                      input: Optional[str] = None,
                      budget: Optional[int] = None, seed: int = 0,
                      searcher: Optional[str] = None,
                      tenant_budget_s: Optional[float] = None,
                      idempotency_key: Optional[str] = None
                      ) -> Dict[str, Any]:
        return self._checked({
            "op": "submit", "kind": "kernel", "tenant": tenant,
            "kernel": kernel, "input": input, "hardware": hardware,
            "budget": budget, "seed": seed, "searcher": searcher,
            "tenant_budget_s": tenant_budget_s,
            "idempotency_key": idempotency_key},
            idempotent=idempotency_key is not None)

    def submit_problem(self, tenant: str, problem: str, hardware: str,
                       params: Optional[Dict[str, Any]] = None,
                       budget: Optional[int] = None, seed: int = 0,
                       searcher: Optional[str] = None,
                       tenant_budget_s: Optional[float] = None,
                       idempotency_key: Optional[str] = None
                       ) -> Dict[str, Any]:
        """Submit any registered ``TuningProblem`` by its ``"kind:name"``
        spec (e.g. ``"sharding:qwen2.5-3b/train_4k"``, ``"serve:p9n9"``,
        ``"kernel:matmul/128"``); ``params`` are forwarded to the
        problem's constructor."""
        return self._checked({
            "op": "submit", "kind": "problem", "tenant": tenant,
            "problem": problem, "params": dict(params or {}),
            "hardware": hardware, "budget": budget, "seed": seed,
            "searcher": searcher, "tenant_budget_s": tenant_budget_s,
            "idempotency_key": idempotency_key},
            idempotent=idempotency_key is not None)

    def submit_serve(self, tenant: str, hardware: str, bucket: str,
                     bucket_shape: Sequence[int],
                     batch_sizes: Sequence[int],
                     max_seqs: Sequence[int],
                     space: str = "serve_online", calib_n: int = 16,
                     stats: Optional[Dict[str, Any]] = None,
                     budget: Optional[int] = None, seed: int = 0,
                     tenant_budget_s: Optional[float] = None,
                     hardware_spec: Optional[Dict[str, Any]] = None,
                     idempotency_key: Optional[str] = None
                     ) -> Dict[str, Any]:
        return self._checked({
            "op": "submit", "kind": "serve", "tenant": tenant,
            "hardware": hardware, "bucket": bucket,
            "bucket_shape": list(bucket_shape),
            "batch_sizes": list(batch_sizes),
            "max_seqs": list(max_seqs), "space": space,
            "calib_n": calib_n, "stats": dict(stats or {}),
            "budget": budget, "seed": seed,
            "tenant_budget_s": tenant_budget_s,
            "hardware_spec": hardware_spec,
            "idempotency_key": idempotency_key},
            idempotent=idempotency_key is not None)

    def status(self, request_id: str) -> Dict[str, Any]:
        return self._checked({"op": "status", "request_id": request_id},
                             idempotent=True)

    def result(self, request_id: str, timeout: Optional[float] = None,
               poll: float = 0.05,
               reconnect_window: float = 60.0) -> Dict[str, Any]:
        """Block until the request resolves; return its result payload.

        Raises ``ServiceError(code="not_done")`` if the request was
        cancelled, ``TimeoutError`` past ``timeout`` seconds.  A daemon
        outage shorter than ``reconnect_window`` is ridden out: the poll
        keeps retrying, so a handle survives a crash + ``--recover``
        restart (the recovered daemon still knows the request id).
        """
        deadline = None if timeout is None \
            else time.monotonic() + float(timeout)
        down_since: Optional[float] = None
        while True:
            try:
                st = self.status(request_id)
                down_since = None
            except ServiceUnavailable:
                now = time.monotonic()
                if down_since is None:
                    down_since = now
                if now - down_since >= reconnect_window or \
                        (deadline is not None and now >= deadline):
                    raise
                time.sleep(min(1.0, poll * 4))
                continue
            if st["state"] == "done":
                return self._checked({"op": "result",
                                      "request_id": request_id},
                                     idempotent=True)
            if st["state"] == "cancelled":
                raise ServiceError(
                    st.get("error") or f"request {request_id} cancelled",
                    code=P.E_NOT_DONE, response=st)
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(
                    f"request {request_id} still {st['state']} after "
                    f"{timeout}s")
            time.sleep(poll)

    def cancel(self, request_id: str) -> Dict[str, Any]:
        return self._checked({"op": "cancel", "request_id": request_id},
                             idempotent=True)

    def stats(self) -> Dict[str, Any]:
        return self._checked({"op": "stats"}, idempotent=True)

    def shutdown(self, drain: bool = True) -> Dict[str, Any]:
        return self._checked({"op": "shutdown", "drain": drain},
                             idempotent=True)


class _TransportFailure(Exception):
    """Internal: one failed round trip; ``sent`` says whether the request
    bytes left this process before the failure."""

    def __init__(self, sent: bool, detail: str):
        super().__init__(detail)
        self.sent = sent
        self.detail = detail


class PendingTuning:
    """Handle for one submitted request (async client)."""

    def __init__(self, client: ServiceClient, request_id: str,
                 submit_response: Dict[str, Any]):
        self.client = client
        self.request_id = request_id
        self.submit_response = submit_response

    def status(self) -> Dict[str, Any]:
        return self.client.status(self.request_id)

    def done(self) -> bool:
        return self.status()["state"] in ("done", "cancelled")

    def result(self, timeout: Optional[float] = None,
               poll: float = 0.05,
               reconnect_window: float = 60.0) -> Dict[str, Any]:
        return self.client.result(self.request_id, timeout=timeout,
                                  poll=poll,
                                  reconnect_window=reconnect_window)

    def cancel(self) -> Dict[str, Any]:
        return self.client.cancel(self.request_id)


class AsyncServiceClient:
    """Handle-based wrapper: submits return ``PendingTuning``.

    Every submit carries an idempotency key (caller's, or a generated
    uuid), so a retried/resubmitted request can never fork into two paid
    tuning runs and handles stay valid across daemon crash-recovery.
    """

    def __init__(self, address: Union[str, Tuple[str, int]],
                 timeout: float = 30.0, **client_kwargs):
        self.client = ServiceClient(address, timeout=timeout,
                                    **client_kwargs)

    def submit_kernel(self, *args, **kwargs) -> PendingTuning:
        kwargs.setdefault("idempotency_key", uuid.uuid4().hex)
        resp = self.client.submit_kernel(*args, **kwargs)
        return PendingTuning(self.client, resp["request_id"], resp)

    def submit_serve(self, *args, **kwargs) -> PendingTuning:
        kwargs.setdefault("idempotency_key", uuid.uuid4().hex)
        resp = self.client.submit_serve(*args, **kwargs)
        return PendingTuning(self.client, resp["request_id"], resp)

    def submit_problem(self, *args, **kwargs) -> PendingTuning:
        kwargs.setdefault("idempotency_key", uuid.uuid4().hex)
        resp = self.client.submit_problem(*args, **kwargs)
        return PendingTuning(self.client, resp["request_id"], resp)

    def stats(self) -> Dict[str, Any]:
        return self.client.stats()

    def health(self) -> Dict[str, Any]:
        return self.client.health()

    def close(self) -> None:
        self.client.close()

    def __enter__(self) -> "AsyncServiceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
