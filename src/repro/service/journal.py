"""``RequestJournal`` — the daemon's write-ahead request journal.

The store persists *outcomes*; the journal persists *promises*.  Every
wire-visible state transition of every request — accepted, dispatched,
partial-progress checkpoints, resolved, cancelled — plus every tenant
budget charge is appended to a JSON-lines file BEFORE the response that
acknowledges it goes back to the client (write-ahead discipline).  A
daemon that dies mid-tuning can then be restarted with ``--recover``:
replaying the journal reconstructs the request table, answers
already-finished requests from the store, resubmits interrupted jobs
with their remaining trial budget, and restores tenant spend — so a
crash costs at most the in-flight work, never the whole run.

Record format (one JSON object per line)::

    {"seq": 17, "ev": "submit", "rid": "r000003", ..., "crc": 2974301200}

``seq`` is monotonic per journal file; ``crc`` is the crc32 of the
record's canonical JSON *without* the crc field, so truncated or
bit-rotted lines are detected on replay.  Replay is forgiving by design:
a torn final record (the classic SIGKILL-mid-write artifact) is dropped
and counted, an interior record failing its checksum is skipped and
counted — the daemon must come back up on the journal a crash actually
left behind, not on the journal we wish it had.

Event vocabulary (the ``ev`` field)::

    daemon_start   one per daemon boot ({"recovered": bool})
    submit         accepted request: validated request payload + rid +
                   idempotency key (enough to rebuild the TuningJob)
    start          request entered the fleet
    progress       per-request checkpoint: trials completed so far
    charge         tenant budget charge (worker-seconds delta)
    done           request resolved: full result payload
    cancelled      request resolved without a result: reason

Durability modes (``mode=``)::

    always   every append is written + fsynced inline before it returns —
             a SIGKILL or machine crash loses at most the record being
             written.  The per-record fsync is also the cost: under a
             multi-tenant submit storm every ack pays a full disk flush.
    batch    GROUP COMMIT: appends are enqueued, one committer thread
             coalesces everything pending into a single write + fsync,
             and ``wait_durable(seq)`` blocks a caller only until the
             commit covering *its* record completes.  Acked records carry
             the same machine-crash durability as ``always`` (the ack is
             held until the fsync lands) at ~1 fsync per concurrent
             batch instead of per record.  Records appended without
             waiting (the daemon's progress/charge checkpoints) sit in
             process memory until the next commit, so a SIGKILL can lose
             an un-acked tail — never an acked one.
    off      write + flush, no fsync: a process kill still loses nothing
             (the OS holds the page), only a machine crash can.

``fsync_lag_s`` reports how long the oldest unsynced record has been
exposed, which the ``health`` op surfaces as a readiness signal;
``stats()`` exposes records/bytes/commit counts and the group-commit
batch sizes so the coalescing is inspectable.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

FORMAT = "repro.tuning-journal"
VERSION = 1

MODE_ALWAYS = "always"
MODE_BATCH = "batch"
MODE_OFF = "off"
MODES = (MODE_ALWAYS, MODE_BATCH, MODE_OFF)

# the ``ev`` values replay understands; unknown events are skipped (a
# newer daemon's journal should degrade, not crash, an older one)
EV_DAEMON_START = "daemon_start"
EV_SUBMIT = "submit"
EV_START = "start"
EV_PROGRESS = "progress"
EV_CHARGE = "charge"
EV_DONE = "done"
EV_CANCELLED = "cancelled"


def record_crc(record: Dict[str, Any]) -> int:
    """crc32 over the record's canonical JSON, excluding ``crc`` itself."""
    body = {k: v for k, v in record.items() if k != "crc"}
    return zlib.crc32(json.dumps(body, separators=(",", ":"),
                                 sort_keys=True).encode("utf-8"))


@dataclasses.dataclass
class ReplayStats:
    """What a journal replay found (and what it had to forgive)."""

    events: int = 0          # well-formed records yielded
    corrupt: int = 0         # interior records failing JSON/crc, skipped
    torn: int = 0            # truncated tail records dropped (SIGKILL scar)
    last_seq: int = 0        # highest seq seen (appends continue after it)

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def replay(path: str) -> Tuple[List[Dict[str, Any]], ReplayStats]:
    """Read every verifiable record from a journal file, in order.

    Never raises on a damaged journal: malformed/bad-crc lines are
    skipped (counted ``corrupt``, or ``torn`` when they form the
    file's tail — the expected scar of a kill mid-append).
    """
    events: List[Dict[str, Any]] = []
    stats = ReplayStats()
    if not os.path.exists(path):
        return events, stats
    bad_streak = 0           # trailing bad lines -> torn, interior -> corrupt
    with open(path, "rb") as f:
        for raw in f:
            try:
                rec = json.loads(raw.decode("utf-8"))
                if not isinstance(rec, dict) \
                        or rec.get("crc") != record_crc(rec):
                    raise ValueError("bad checksum")
            except (ValueError, UnicodeDecodeError):
                bad_streak += 1
                continue
            stats.corrupt += bad_streak   # bad lines had good ones after
            bad_streak = 0
            stats.events += 1
            stats.last_seq = max(stats.last_seq, int(rec.get("seq", 0)))
            events.append(rec)
    stats.torn = bad_streak
    return events, stats


class RequestJournal:
    """Append-only, checksummed JSON-lines journal bound to one file.

    ``append`` is thread-safe in every mode.  In ``always``/``off`` the
    record is written inline under the journal's internal lock; in
    ``batch`` it is enqueued for the committer thread, and callers that
    need the write-ahead guarantee block (``wait=True``, or an explicit
    ``wait_durable``) until the group commit covering their record has
    fsynced.  The daemon still serializes appends under its request lock,
    which keeps journal order matching response order — but it waits for
    durability *outside* that lock, which is what lets one fsync cover
    many concurrent requests.
    """

    def __init__(self, path: str, fsync: bool = True,
                 mode: Optional[str] = None,
                 batch_window_s: float = 0.0005,
                 batch_max_delay_s: float = 0.004):
        if mode is None:
            mode = MODE_ALWAYS if fsync else MODE_OFF
        if mode not in MODES:
            raise ValueError(
                "unknown journal mode %r (valid modes: %s)"
                % (mode, ", ".join(MODES)))
        self.path = path
        self.mode = mode
        self.fsync = mode != MODE_OFF   # back-compat readers
        d = os.path.dirname(os.path.abspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "ab")
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._seq = 0                 # last seq assigned
        self._durable = 0             # last seq the disk is known to hold
        self._pending: List[bytes] = []   # encoded records awaiting commit
        self._pending_upto = 0        # seq of the last pending record
        self._appends = 0
        self._bytes = 0
        self._commits = 0             # fsync-bearing writes issued
        self._last_batch = 0          # records covered by the last commit
        self._max_batch = 0
        self._oldest_unsynced: Optional[float] = None
        self._io_error: Optional[BaseException] = None
        self._closed = False
        self._listeners: List[Any] = []   # called (no args) after commits
        # group-commit pacing: absorb arrivals while they keep coming
        # (one quiet ``batch_window_s`` ends the batch), never delaying
        # the fsync more than ``batch_max_delay_s`` past the first record
        self._window = max(float(batch_window_s), 0.0)
        self._max_delay = max(float(batch_max_delay_s), self._window)
        self._kicked = False
        self._committer: Optional[threading.Thread] = None
        if mode == MODE_BATCH:
            self._committer = threading.Thread(
                target=self._commit_loop, name="journal-committer",
                daemon=True)
            self._committer.start()

    def replay(self) -> Tuple[List[Dict[str, Any]], ReplayStats]:
        """Replay this journal's existing records; future appends
        continue after the highest sequence number found."""
        events, stats = replay(self.path)
        with self._lock:
            self._seq = max(self._seq, stats.last_seq)
            self._durable = max(self._durable, stats.last_seq)
        return events, stats

    def append(self, ev: str, wait: bool = True,
               **fields: Any) -> Dict[str, Any]:
        """Append one record; returns it (with ``seq`` assigned).

        ``wait=True`` (the default) upholds the write-ahead guarantee:
        the call does not return until the record is as durable as the
        mode promises.  ``wait=False`` enqueues and returns immediately
        in ``batch`` mode (use for checkpoints whose ack does not
        depend on them); it is identical to ``wait=True`` in the inline
        modes.
        """
        line: bytes
        with self._lock:
            if self._io_error is not None:
                raise self._io_error
            self._seq += 1
            record: Dict[str, Any] = {"seq": self._seq, "ev": ev,
                                      "t": round(time.time(), 6)}
            record.update(fields)
            # one serialization serves both: the crc is computed over the
            # canonical body and spliced onto the line's tail (replay
            # re-canonicalizes the parsed dict, so on-disk key order is
            # free) — dumps is the hot path's single biggest line item,
            # and record_crc() would pay it a second time per record
            body = json.dumps(record, separators=(",", ":"), sort_keys=True)
            crc = zlib.crc32(body.encode("utf-8"))
            record["crc"] = crc
            line = (body[:-1] + ',"crc":' + str(crc)
                    + "}\n").encode("utf-8")
            self._appends += 1
            self._bytes += len(line)
            if self.mode == MODE_BATCH:
                first = not self._pending
                self._pending.append(line)
                self._pending_upto = record["seq"]
                if self._oldest_unsynced is None:
                    self._oldest_unsynced = time.monotonic()
                if first:
                    # later records of a burst ride the same commit; only
                    # the first needs to rouse the committer (its quiesce
                    # wait polls growth, and ``kick`` ends it early), so
                    # a storm isn't one context switch per record
                    self._cond.notify_all()
                seq = record["seq"]
            else:
                self._f.write(line)
                self._f.flush()
                if self.mode == MODE_ALWAYS:
                    os.fsync(self._f.fileno())
                    self._commits += 1
                    self._last_batch = 1
                    self._max_batch = max(self._max_batch, 1)
                    self._durable = record["seq"]
                    self._oldest_unsynced = None
                elif self._oldest_unsynced is None:
                    self._oldest_unsynced = time.monotonic()
                return record
        if wait:
            self.wait_durable(seq)
        return record

    def ticket(self) -> int:
        """Sequence number of the newest enqueued record.  Pass to
        ``wait_durable`` to block until everything enqueued so far —
        including records appended with ``wait=False`` — is on disk."""
        with self._lock:
            return self._seq

    def durable_upto(self) -> int:
        """Highest seq an ack may be released for right now.

        In the inline modes every append is already as durable as the
        mode promises when it returns, so this is simply the last seq
        assigned; in ``batch`` it is the last group-committed seq.  An
        event-driven server checks this instead of blocking in
        ``wait_durable`` — see ``add_commit_listener``.
        """
        with self._lock:
            if self.mode != MODE_BATCH:
                return self._seq
            return self._durable

    def commit_error(self) -> Optional[BaseException]:
        """The committer's fatal IO error, if it died (batch mode)."""
        return self._io_error

    def add_commit_listener(self, fn) -> None:
        """Register a zero-arg callback fired after every group commit
        (and on committer failure/close).  Called from the committer
        thread OUTSIDE the journal lock; must not block — the daemon's
        IO loop registers a self-pipe write here so deferred acks flush
        as soon as the fsync covering them lands."""
        with self._lock:
            self._listeners.append(fn)

    def _notify_listeners(self) -> None:
        for fn in list(self._listeners):
            try:
                fn()
            except Exception:
                pass

    def kick(self) -> None:
        """End the committer's quiesce window now: whatever is pending
        goes into the next fsync immediately.  The daemon's IO loop
        calls this the moment its event queue drains while acks are
        still parked on the journal — the server knows no more records
        are imminent, so waiting out the quiet window is pure latency.
        No-op outside ``batch`` mode or with nothing pending."""
        if self.mode != MODE_BATCH:
            return
        with self._cond:
            if self._pending:
                self._kicked = True
                self._cond.notify_all()

    def wait_durable(self, seq: int) -> None:
        """Block until record ``seq`` is covered by an fsync (batch
        mode) or already written (inline modes; returns immediately)."""
        if self.mode != MODE_BATCH:
            return
        with self._cond:
            while self._durable < seq and self._io_error is None \
                    and not self._closed:
                self._cond.wait(timeout=1.0)
            if self._durable < seq and self._io_error is not None:
                raise self._io_error

    def _commit_loop(self) -> None:
        """Committer thread: coalesce everything pending into one
        write + fsync, then wake every caller that commit covers."""
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if not self._pending and self._closed:
                    return
                # quiesce pacing: a burst's records arrive a few tens of
                # microseconds apart — keep absorbing while they keep
                # coming, so one fsync covers the whole burst instead of
                # racing it one-or-two records at a time.  A quiet
                # window ends the batch; ``_max_delay`` bounds how stale
                # the first record may go under a continuous trickle.
                if self._window > 0.0 and not self._closed \
                        and not self._kicked:
                    deadline = time.monotonic() + self._max_delay
                    last = self._pending_upto
                    while time.monotonic() < deadline:
                        self._cond.wait(self._window)
                        if self._closed or self._kicked \
                                or self._pending_upto == last:
                            break
                        last = self._pending_upto
                batch = self._pending
                upto = self._pending_upto
                self._pending = []
                self._kicked = False
            try:
                self._f.write(b"".join(batch))
                self._f.flush()
                os.fsync(self._f.fileno())
            except (OSError, ValueError) as exc:   # ValueError: closed file
                with self._cond:
                    self._io_error = exc
                    self._cond.notify_all()
                self._notify_listeners()
                return
            with self._cond:
                self._durable = max(self._durable, upto)
                self._commits += 1
                self._last_batch = len(batch)
                self._max_batch = max(self._max_batch, len(batch))
                if not self._pending:
                    self._oldest_unsynced = None
                self._cond.notify_all()
            self._notify_listeners()

    def sync(self) -> None:
        """Force the unsynced tail to disk (no-op when already clean)."""
        if self.mode == MODE_BATCH:
            self.wait_durable(self.ticket())
            return
        with self._lock:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._durable = self._seq
            self._oldest_unsynced = None

    @property
    def appends(self) -> int:
        return self._appends

    @property
    def fsync_lag_s(self) -> float:
        """Seconds the oldest unsynced record has been exposed (0: clean)."""
        if self._oldest_unsynced is None:
            return 0.0
        return time.monotonic() - self._oldest_unsynced

    def stats(self) -> Dict[str, Any]:
        """Counters that make the batching inspectable: total records
        and bytes appended, fsync-bearing commits, and how many records
        the last/largest group commit coalesced."""
        with self._lock:
            return {
                "mode": self.mode,
                "records": self._appends,
                "bytes": self._bytes,
                "commits": self._commits,
                "last_batch": self._last_batch,
                "max_batch": self._max_batch,
                "pending": len(self._pending),
            }

    def close(self) -> None:
        committer = self._committer
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if committer is not None and committer.is_alive() \
                and committer is not threading.current_thread():
            committer.join(timeout=5.0)
        self._notify_listeners()
        try:
            with self._lock:
                if self._pending:   # committer died/timed out: best effort
                    self._f.write(b"".join(self._pending))
                    self._pending = []
                self._f.close()
        except OSError:
            pass

    def __enter__(self) -> "RequestJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
