"""``RequestJournal`` — the daemon's write-ahead request journal.

The store persists *outcomes*; the journal persists *promises*.  Every
wire-visible state transition of every request — accepted, dispatched,
partial-progress checkpoints, resolved, cancelled — plus every tenant
budget charge is appended to a JSON-lines file BEFORE the response that
acknowledges it goes back to the client (write-ahead discipline).  A
daemon that dies mid-tuning can then be restarted with ``--recover``:
replaying the journal reconstructs the request table, answers
already-finished requests from the store, resubmits interrupted jobs
with their remaining trial budget, and restores tenant spend — so a
crash costs at most the in-flight work, never the whole run.

Record format (one JSON object per line)::

    {"seq": 17, "ev": "submit", "rid": "r000003", ..., "crc": 2974301200}

``seq`` is monotonic per journal file; ``crc`` is the crc32 of the
record's canonical JSON *without* the crc field, so truncated or
bit-rotted lines are detected on replay.  Replay is forgiving by design:
a torn final record (the classic SIGKILL-mid-write artifact) is dropped
and counted, an interior record failing its checksum is skipped and
counted — the daemon must come back up on the journal a crash actually
left behind, not on the journal we wish it had.

Event vocabulary (the ``ev`` field)::

    daemon_start   one per daemon boot ({"recovered": bool})
    submit         accepted request: validated request payload + rid +
                   idempotency key (enough to rebuild the TuningJob)
    start          request entered the fleet
    progress       per-request checkpoint: trials completed so far
    charge         tenant budget charge (worker-seconds delta)
    done           request resolved: full result payload
    cancelled      request resolved without a result: reason

Appends are flushed per record and (by default) fsynced, so a SIGKILL
loses at most the record being written; ``fsync=False`` trades that for
lower latency (a process kill still loses nothing — the OS holds the
page — only a machine crash can).  ``fsync_lag_s`` reports how long the
oldest unsynced record has been exposed, which the ``health`` op
surfaces as a readiness signal.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

FORMAT = "repro.tuning-journal"
VERSION = 1

# the ``ev`` values replay understands; unknown events are skipped (a
# newer daemon's journal should degrade, not crash, an older one)
EV_DAEMON_START = "daemon_start"
EV_SUBMIT = "submit"
EV_START = "start"
EV_PROGRESS = "progress"
EV_CHARGE = "charge"
EV_DONE = "done"
EV_CANCELLED = "cancelled"


def record_crc(record: Dict[str, Any]) -> int:
    """crc32 over the record's canonical JSON, excluding ``crc`` itself."""
    body = {k: v for k, v in record.items() if k != "crc"}
    return zlib.crc32(json.dumps(body, separators=(",", ":"),
                                 sort_keys=True).encode("utf-8"))


@dataclasses.dataclass
class ReplayStats:
    """What a journal replay found (and what it had to forgive)."""

    events: int = 0          # well-formed records yielded
    corrupt: int = 0         # interior records failing JSON/crc, skipped
    torn: int = 0            # truncated tail records dropped (SIGKILL scar)
    last_seq: int = 0        # highest seq seen (appends continue after it)

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def replay(path: str) -> Tuple[List[Dict[str, Any]], ReplayStats]:
    """Read every verifiable record from a journal file, in order.

    Never raises on a damaged journal: malformed/bad-crc lines are
    skipped (counted ``corrupt``, or ``torn`` when they form the
    file's tail — the expected scar of a kill mid-append).
    """
    events: List[Dict[str, Any]] = []
    stats = ReplayStats()
    if not os.path.exists(path):
        return events, stats
    bad_streak = 0           # trailing bad lines -> torn, interior -> corrupt
    with open(path, "rb") as f:
        for raw in f:
            try:
                rec = json.loads(raw.decode("utf-8"))
                if not isinstance(rec, dict) \
                        or rec.get("crc") != record_crc(rec):
                    raise ValueError("bad checksum")
            except (ValueError, UnicodeDecodeError):
                bad_streak += 1
                continue
            stats.corrupt += bad_streak   # bad lines had good ones after
            bad_streak = 0
            stats.events += 1
            stats.last_seq = max(stats.last_seq, int(rec.get("seq", 0)))
            events.append(rec)
    stats.torn = bad_streak
    return events, stats


class RequestJournal:
    """Append-only, checksummed JSON-lines journal bound to one file.

    ``append`` is the only mutator; it is NOT thread-safe on its own —
    the daemon calls it under its request lock, which also guarantees
    journal order matches the order responses were issued.
    """

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(os.path.abspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        self._seq = 0
        self._f = open(path, "ab")
        self._appends = 0
        self._oldest_unsynced: Optional[float] = None

    def replay(self) -> Tuple[List[Dict[str, Any]], ReplayStats]:
        """Replay this journal's existing records; future appends
        continue after the highest sequence number found."""
        events, stats = replay(self.path)
        self._seq = stats.last_seq
        return events, stats

    def append(self, ev: str, **fields: Any) -> Dict[str, Any]:
        self._seq += 1
        record: Dict[str, Any] = {"seq": self._seq, "ev": ev,
                                  "t": round(time.time(), 6)}
        record.update(fields)
        record["crc"] = record_crc(record)
        self._f.write((json.dumps(record, separators=(",", ":"),
                                  sort_keys=True) + "\n").encode("utf-8"))
        self._f.flush()
        self._appends += 1
        if self.fsync:
            os.fsync(self._f.fileno())
            self._oldest_unsynced = None
        elif self._oldest_unsynced is None:
            self._oldest_unsynced = time.monotonic()
        return record

    def sync(self) -> None:
        """Force the unsynced tail to disk (no-op when already clean)."""
        self._f.flush()
        os.fsync(self._f.fileno())
        self._oldest_unsynced = None

    @property
    def appends(self) -> int:
        return self._appends

    @property
    def fsync_lag_s(self) -> float:
        """Seconds the oldest unsynced record has been exposed (0: clean)."""
        if self._oldest_unsynced is None:
            return 0.0
        return time.monotonic() - self._oldest_unsynced

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass

    def __enter__(self) -> "RequestJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
