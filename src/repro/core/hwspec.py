"""Virtual TPU hardware specifications (paper §4.2, Table 3 — adapted).

The paper evaluates portability across four NVIDIA generations (Kepler,
Maxwell, Pascal, Turing).  We use four TPU generations with distinct
flop-to-byte ratios and VMEM capacities, so a kernel that is compute-bound on
one is memory-bound on another — exactly the property the paper exploits
(PC_stress varies across hardware; PC_ops does not).

Numbers are public per-chip peaks.  ``v5e`` is the production dry-run target
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI per the assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple, Union


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    generation: str
    # peak dense matmul throughput, FLOP/s (bf16)
    mxu_flops: float
    # peak vector unit throughput, op/s
    vpu_flops: float
    # transcendental throughput, op/s (slow VPU path)
    trans_flops: float
    hbm_bw: float          # bytes/s
    vmem_bw: float         # bytes/s (VMEM<->VREG aggregate)
    cmem_bw: float         # bytes/s scalar memory
    hbm_bytes: float       # HBM capacity
    vmem_bytes: float      # VMEM capacity per core
    cores: int             # TensorCores per chip
    ici_bw: float          # bytes/s per link
    ici_links: int         # usable links per chip (torus dimension * 2)
    dcn_bw: float          # bytes/s cross-pod (data-center network)
    # fixed per-grid-program dispatch latency (s): DMA setup, program launch
    launch_latency: float = 1.5e-6

    @property
    def flops_per_byte(self) -> float:
        return self.mxu_flops / self.hbm_bw

    @property
    def ici_chip_bw(self) -> float:
        """Aggregate ICI bandwidth per chip."""
        return self.ici_bw * self.ici_links


# Four generations — portability testbed (stand-ins for the paper's 4 GPUs).
TPU_V4 = HardwareSpec(
    name="tpu_v4", generation="v4",
    mxu_flops=275e12, vpu_flops=4.3e12, trans_flops=0.54e12,
    hbm_bw=1228e9, vmem_bw=11e12, cmem_bw=0.9e12,
    hbm_bytes=32e9, vmem_bytes=64 * 2**20, cores=2,
    ici_bw=50e9, ici_links=6, dcn_bw=6.25e9,
)
TPU_V5E = HardwareSpec(
    name="tpu_v5e", generation="v5e",
    mxu_flops=197e12, vpu_flops=3.1e12, trans_flops=0.39e12,
    hbm_bw=819e9, vmem_bw=8.5e12, cmem_bw=0.7e12,
    hbm_bytes=16e9, vmem_bytes=128 * 2**20, cores=1,
    ici_bw=50e9, ici_links=4, dcn_bw=6.25e9,
)
TPU_V5P = HardwareSpec(
    name="tpu_v5p", generation="v5p",
    mxu_flops=459e12, vpu_flops=7.2e12, trans_flops=0.9e12,
    hbm_bw=2765e9, vmem_bw=22e12, cmem_bw=1.8e12,
    hbm_bytes=95e9, vmem_bytes=112 * 2**20, cores=2,
    ici_bw=100e9, ici_links=6, dcn_bw=6.25e9,
)
TPU_V6E = HardwareSpec(
    name="tpu_v6e", generation="v6e",
    mxu_flops=918e12, vpu_flops=14.3e12, trans_flops=1.8e12,
    hbm_bw=1640e9, vmem_bw=17e12, cmem_bw=1.4e12,
    hbm_bytes=32e9, vmem_bytes=160 * 2**20, cores=1,
    ici_bw=90e9, ici_links=4, dcn_bw=6.25e9,
)

SPECS: Dict[str, HardwareSpec] = {
    s.name: s for s in (TPU_V4, TPU_V5E, TPU_V5P, TPU_V6E)
}
PORTABILITY_SET: Tuple[str, ...] = ("tpu_v4", "tpu_v5e", "tpu_v5p", "tpu_v6e")

# Production dry-run target.
PRODUCTION = TPU_V5E


def _squash(name: str) -> str:
    """Alphanumeric-only lowercase form used for drift-tolerant matching."""
    return re.sub(r"[^a-z0-9]", "", str(name).lower())


_NORMALIZE_CACHE: Dict[str, str] = {}


def normalize_name(name: str) -> str:
    """Canonical hardware-name string, stable under naming drift.

    Resolves to a registered spec's name whenever the alphanumeric forms
    match ("TPUv4", "tpu-v4", "TPU_V4" → "tpu_v4"); otherwise returns a
    lower_snake_case normalization of the given name, so even unregistered
    hardware gets a deterministic identity.  Memoized: the service hot
    path normalizes the same few names on every request, and the regex
    work shows up in profiles.
    """
    cached = _NORMALIZE_CACHE.get(name) if isinstance(name, str) else None
    if cached is not None:
        return cached
    sq = _squash(name)
    norm = None
    for canon in SPECS:
        if _squash(canon) == sq:
            norm = canon
            break
    if norm is None:
        norm = re.sub(r"[^a-z0-9]+", "_",
                      str(name).strip().lower()).strip("_") or "unknown"
    if isinstance(name, str) and len(_NORMALIZE_CACHE) < 4096:
        _NORMALIZE_CACHE[name] = norm
    return norm


def get(name: str) -> HardwareSpec:
    """Spec by name, tolerating naming drift via ``normalize_name``.

    Raises ``KeyError`` (with the registered names) only when even the
    normalized form is unknown.
    """
    if name in SPECS:
        return SPECS[name]
    canon = normalize_name(name)
    if canon in SPECS:
        return SPECS[canon]
    raise KeyError(
        f"unknown hardware {name!r} (normalized: {canon!r}); "
        f"registered: {sorted(SPECS)}")


def fingerprint(spec: HardwareSpec) -> str:
    """Stable identity for hardware outside the registry: the normalized
    name plus the declared peak matmul throughput and HBM bandwidth — two
    machines that agree on all three are the same tuning target for the
    cost model's purposes."""
    return (f"{normalize_name(spec.name)}"
            f"-{spec.mxu_flops / 1e12:.0f}tf-{spec.hbm_bw / 1e9:.0f}gbs")


def hardware_key(hw: Union[str, HardwareSpec]) -> str:
    """Canonical ``ConfigStore`` hardware key.

    Registered hardware (by spec or any naming-drift variant of its name)
    maps to the registry name, so "tpu_v4" and "TPUv4" share store entries;
    unregistered specs fall back to their ``fingerprint`` and unregistered
    name strings to their normalized form.
    """
    if isinstance(hw, HardwareSpec):
        canon = normalize_name(hw.name)
        # normalize_name resolves to a registry name exactly when the
        # squashed forms match, so this is the registered/unregistered test
        return canon if canon in SPECS else fingerprint(hw)
    return normalize_name(hw)
