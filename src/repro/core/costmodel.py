"""Analytic TPU execution model: PC_ops × HardwareSpec → runtime + PC_stress.

Plays the role of the physical devices in the paper's evaluation (§4.1 replays
recorded tuning spaces 1000x instead of re-running kernels; our recorded
spaces are produced by this model from statically-derived counters of real
Pallas kernels, validated for correctness in interpret mode).

The model implements the first-order TPU execution structure:
  * MXU and VPU issue on separate pipelines (dual issue — Volta analogy §3.5.1),
    transcendentals share the VPU's slow path;
  * per-program working set must fit VMEM; 2x (double buffering) is needed to
    overlap DMA with compute, otherwise DMA serializes with compute;
  * working set beyond VMEM capacity spills to HBM (read+write round trip) —
    the local-memory analog (paper Eq. 8);
  * fewer grid programs than cores leaves cores idle; fewer than
    LATENCY_HIDING_PROGRAMS per core fails to hide launch/DMA latency;
  * tile-padding lane waste derates MXU throughput (warp-efficiency analog);
  * inter-chip collectives occupy the ICI independently and overlap with
    compute only when double-buffered.

This is exactly the role of ``f : TP x I x GPU -> PC`` in the paper (Eq. 2):
hardware-dependent.  The *static* counter derivation in each kernel's
``space.py`` is ``g : TP x I -> PC`` (Eq. 3) — hardware-independent.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.core import counters as C
from repro.core.hwspec import HardwareSpec

# Programs per core needed to hide DMA/launch latency (paper Eq. 14 uses 5
# threads/core on GPUs; TPU double-buffered DMA pipelines need ~4 in flight).
LATENCY_HIDING_PROGRAMS = 4


def execute(ops: Dict[str, float], hw: HardwareSpec) -> C.CounterSet:
    """Run the analytic machine: ops counters -> (runtime, stress counters).

    ``ops`` are kernel totals (bytes / flops / program counts) as produced by a
    kernel's workload model or by XLA cost analysis.  ``SPILL_B`` may be 0 in
    the portable view; the true spill for *this* hardware's VMEM capacity is
    recomputed here (cache-capacity effect, paper §3.1 imprecision note).
    """
    grid = max(1.0, float(ops.get(C.GRID, 1.0)))
    ws = float(ops.get(C.VMEM_WS, 0.0))
    lane_e = _lane_efficiency(ops)

    # --- hardware-true spill (overrides portable estimate) -------------------
    spill_per_prog = max(0.0, ws - hw.vmem_bytes)
    spill_bytes = max(float(ops.get(C.SPILL_B, 0.0)), 2.0 * spill_per_prog * grid)

    # --- core-level parallel efficiency --------------------------------------
    cores = float(hw.cores)
    if grid < cores:
        core_e = grid / cores
    else:
        waves = math.ceil(grid / cores)
        core_e = grid / (waves * cores)  # tail-wave imbalance

    # --- pipe times (totals over the whole kernel) ---------------------------
    eff_mxu = hw.mxu_flops * core_e * max(lane_e, 1e-3)
    t_mxu = float(ops.get(C.MXU_FLOPS, 0.0)) / eff_mxu
    t_vpu = float(ops.get(C.VPU_OPS, 0.0)) / (hw.vpu_flops * core_e)
    t_trans = float(ops.get(C.TRANS_OPS, 0.0)) / (hw.trans_flops * core_e)
    t_hbm = (
        float(ops.get(C.HBM_RD, 0.0))
        + float(ops.get(C.HBM_WR, 0.0))
        + spill_bytes
    ) / hw.hbm_bw
    t_vmem = (
        float(ops.get(C.VMEM_RD, 0.0)) + float(ops.get(C.VMEM_WR, 0.0))
    ) / hw.vmem_bw
    t_cmem = float(ops.get(C.CMEM_RD, 0.0)) / hw.cmem_bw
    t_ici = float(ops.get(C.ICI_B, 0.0)) / hw.ici_chip_bw

    t_exec = max(t_mxu, t_vpu + t_trans)          # dual-issue pipes
    t_mem = max(t_hbm, t_vmem, t_cmem)

    # --- overlap structure ----------------------------------------------------
    double_buffered = ws > 0 and 2.0 * ws <= hw.vmem_bytes
    programs_per_core = grid / cores
    latency_hidden = programs_per_core >= LATENCY_HIDING_PROGRAMS

    t_launch = hw.launch_latency * grid / max(1.0, min(grid, cores))
    if double_buffered:
        t_body = max(t_exec, t_mem, t_ici)
    else:
        # DMA cannot overlap compute; collectives still use their own fabric.
        t_body = t_exec + t_mem + max(0.0, t_ici - t_exec - t_mem)
        t_body = max(t_body, t_ici)
    if not latency_hidden:
        # exposed per-program latency
        t_launch += hw.launch_latency * max(
            0.0, LATENCY_HIDING_PROGRAMS - programs_per_core
        )
    runtime = t_body + t_launch
    runtime = max(runtime, 1e-9)

    # --- stress counters -------------------------------------------------------
    stress = {
        C.HBM_U: min(1.0, t_hbm / runtime),
        C.VMEM_U: min(1.0, t_vmem / runtime),
        C.CMEM_U: min(1.0, t_cmem / runtime),
        C.ICI_U: min(1.0, t_ici / runtime),
        C.MXU_U: min(1.0, t_mxu / runtime),
        C.VPU_U: min(1.0, t_vpu / runtime),
        C.TRANS_U: min(1.0, t_trans / runtime),
        # dual pipe: 1.0 == both pipes saturated; 0.5 == one pipe saturated
        C.ISSUE_U: min(1.0, (min(1.0, t_mxu / runtime) + min(1.0, (t_vpu + t_trans) / runtime)) / 2.0),
        C.CORE_E: core_e,
        C.LANE_E: lane_e,
        C.VMEM_OCC: min(1.0, ws / hw.vmem_bytes) if hw.vmem_bytes else 0.0,
    }
    ops_out = {k: float(v) for k, v in ops.items() if k in C.PC_OPS}
    ops_out[C.SPILL_B] = spill_bytes
    return C.CounterSet(ops=ops_out, stress=stress, runtime=runtime)


def _lane_efficiency(ops: Dict[str, float]) -> float:
    """Useful-lane fraction; kernels report it via a pseudo-counter convention.

    Workload models fold padding waste into LANE_E by storing it under
    ``VMEM_WS`` metadata-free channels is ugly; instead they put the effective
    value in ops['LANE_E_HINT'] if present (kept out of PC_OPS — purely a
    model input).
    """
    return float(ops.get("LANE_E_HINT", 1.0))
