"""Expert system, part 1: performance counters → bottleneck vector B.

Faithful adaptation of paper §3.5.1 (Eqs. 6–14) with the TPU counter mapping
of DESIGN.md §2.  Bottleneck values live in [0, 1]: 0 = subsystem unstressed,
1 = at theoretical peak.
"""
from __future__ import annotations

from typing import Dict

from repro.core import counters as C
from repro.core.counters import CounterSet

# Bottleneck keys
B_HBM_READ = "b_hbm_read"
B_HBM_WRITE = "b_hbm_write"
B_VMEM_READ = "b_vmem_read"
B_VMEM_WRITE = "b_vmem_write"
B_CMEM = "b_cmem"
B_SPILL = "b_spill"
B_ICI = "b_ici"
B_MXU = "b_mxu"
B_VPU = "b_vpu"
B_TRANS = "b_trans"
B_ISSUE = "b_issue"
B_CORE = "b_core"      # paper b_sm (Eq. 13)
B_PARAL = "b_paral"    # paper Eq. 14

ALL_BOTTLENECKS = (
    B_HBM_READ, B_HBM_WRITE, B_VMEM_READ, B_VMEM_WRITE, B_CMEM, B_SPILL,
    B_ICI, B_MXU, B_VPU, B_TRANS, B_ISSUE, B_CORE, B_PARAL,
)

# Paper Eq. 14 uses cores*5 GPU threads; TPU needs ~4 programs/core in flight
# to keep double-buffered DMA pipelines busy (DESIGN.md §2).
PROGRAMS_PER_CORE = 4


def _rw_split(read: float, write: float, util: float) -> tuple:
    tot = read + write
    if tot <= 0.0:
        return 0.0, 0.0
    return read / tot * util, write / tot * util


def analyze(pc: CounterSet, cores: int) -> Dict[str, float]:
    """Compute the bottleneck vector B from one profiled sample.

    ``cores`` is the TensorCore count of the *autotuning* hardware (the
    bottleneck component always analyzes the device the kernel actually ran
    on — paper §3.3).
    """
    b: Dict[str, float] = {k: 0.0 for k in ALL_BOTTLENECKS}

    # --- memory subsystems (Eqs. 6-7 pattern) ---------------------------------
    b[B_HBM_READ], b[B_HBM_WRITE] = _rw_split(
        pc.op(C.HBM_RD), pc.op(C.HBM_WR), pc.st(C.HBM_U)
    )
    b[B_VMEM_READ], b[B_VMEM_WRITE] = _rw_split(
        pc.op(C.VMEM_RD), pc.op(C.VMEM_WR), pc.st(C.VMEM_U)
    )
    # texture-cache analog: read-only scalar/const path — utilization as-is
    b[B_CMEM] = pc.st(C.CMEM_U)

    # --- spill (local memory, Eq. 8) ------------------------------------------
    mem_bytes = pc.op(C.HBM_RD) + pc.op(C.HBM_WR) + pc.op(C.SPILL_B)
    spill_frac = pc.op(C.SPILL_B) / mem_bytes if mem_bytes > 0 else 0.0
    b[B_SPILL] = spill_frac * max(pc.st(C.HBM_U), pc.st(C.VMEM_U), pc.st(C.CMEM_U))

    # --- interconnect (TPU-specific; no GPU analog) ---------------------------
    b[B_ICI] = pc.st(C.ICI_U)

    # --- instruction utilizations (Eqs. 9-11) ---------------------------------
    # ins_fitted: total issued compute ops corrected by lane efficiency
    # (LANE_E is the warp-execution-efficiency analog: tile padding waste).
    issued = pc.op(C.ISSUE_OPS)
    if issued <= 0.0:
        issued = pc.op(C.MXU_FLOPS) + pc.op(C.VPU_OPS) + pc.op(C.TRANS_OPS)
    lane_e = max(pc.st(C.LANE_E, 1.0), 1e-6)
    ins_fitted = issued / lane_e if issued > 0 else 1.0

    # dual-issue rule (paper: Volta issues int and fp separately -> /50%):
    # TPU issues MXU and VPU on separate pipes, ISSUE_U==0.5 is one full pipe.
    ins_util = min(1.0, pc.st(C.ISSUE_U) / 0.5)

    frac_mxu = pc.op(C.MXU_FLOPS) / ins_fitted if ins_fitted > 0 else 0.0
    frac_vpu = pc.op(C.VPU_OPS) / ins_fitted if ins_fitted > 0 else 0.0
    frac_trans = pc.op(C.TRANS_OPS) / ins_fitted if ins_fitted > 0 else 0.0
    b[B_MXU] = min(1.0, frac_mxu) * ins_util
    b[B_VPU] = min(1.0, frac_vpu) * ins_util
    b[B_TRANS] = min(1.0, frac_trans) * ins_util

    # --- issue-slot starvation (Eq. 12) ----------------------------------------
    util_max = min(1.0, max(frac_mxu, frac_vpu, frac_trans))
    b[B_ISSUE] = util_max * (1.0 - pc.st(C.ISSUE_U))

    # --- parallelism (Eqs. 13-14) -----------------------------------------------
    b[B_CORE] = 1.0 - pc.st(C.CORE_E)
    target = cores * PROGRAMS_PER_CORE
    grid = pc.op(C.GRID, 1.0)
    b[B_PARAL] = max(0.0, (target - grid) / target)

    return b
