"""Tuning-space searchers in ask-tell form.

* ``ProfileBasedSearcher`` — the paper's contribution (Algorithm 1): biased
  weighted-random search navigated by performance counters, a portable
  TP→PC_ops model, and the bottleneck/ΔPC expert system.
* ``RandomSearcher`` — the paper's primary baseline.
* ``BasinHoppingSearcher`` — Kernel-Tuner-style global+local optimization
  (paper §4.7 comparison target).
* ``StarchartSearcher`` — recursive-partitioning surrogate model search
  (paper §4.8 comparison target).
* ``ProfileLocalSearcher`` — beyond-paper §3.9.1 gradient-following variant.

Every searcher exposes the same two-call interface:

    propose(k)            -> up to k ``Candidate``s to test next
    observe(observations) -> feed back the ``Observation``s for them

which makes Algorithm 1 resumable and inspectable mid-search, lets a driver
batch empirical tests (``Evaluator.measure_many``), and removes every
special case from ``autotune``/benchmark call sites.  The legacy
``search(ev, max_steps)`` entry point remains as a thin shim over
``run_search``.

Internally each searcher writes its strategy as a plain generator
(``_plan``) that yields candidate batches and receives observation batches —
sequential algorithms (basin hopping's first-improvement descent) read
naturally while the base class handles the ask-tell bookkeeping.

Constructors are uniform: ``Searcher(space, seed=..., **strategy_kwargs)``,
and every concrete class registers itself in the string-keyed ``SEARCHERS``
registry (``repro.tuning`` re-exports it):

    SEARCHERS["profile"](space, seed=3, model=m, cores=2)
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Type

import numpy as np

from repro.core import bottleneck, reaction, scoring
from repro.core.account import Candidate, Observation
from repro.core.model import (TPPCModel, _build_tree, _tree_predict_batch,
                              prediction_matrix)
from repro.core.tuning_space import TuningSpace

# String-keyed registry of all searcher classes (the public lookup table).
SEARCHERS: Dict[str, Type["Searcher"]] = {}


def register_searcher(name: str):
    """Class decorator: register under ``name`` and set ``cls.name``."""

    def deco(cls: Type["Searcher"]) -> Type["Searcher"]:
        cls.name = name
        SEARCHERS[name] = cls
        return cls

    return deco


class Searcher:
    """Ask-tell base: plumbing between ``propose``/``observe`` and ``_plan``.

    ``_plan`` is a generator yielding non-empty candidate batches; each
    ``yield`` receives the list of ``Observation``s for exactly the
    candidates it yielded (in order).  A batch may be drained across several
    ``propose`` calls; the generator resumes only once the whole batch has
    been observed, so budget-truncated runs simply leave it suspended.
    """

    name = "base"

    def __init__(self, space: TuningSpace, seed: int = 0):
        self.space = space
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._gen: Optional[Iterator] = None
        self._queue: List[Candidate] = []   # current batch, not yet proposed
        self._outstanding = 0               # proposed, not yet observed
        self._obs: List[Observation] = []   # observed, not yet sent to _plan
        self._finished = False

    # -- strategy (implemented by subclasses) ----------------------------------
    def _plan(self):
        raise NotImplementedError

    # -- ask-tell --------------------------------------------------------------
    def propose(self, k: int) -> List[Candidate]:
        """Return up to ``k`` candidates to evaluate next ([] when done)."""
        if k <= 0:
            return []
        while not self._queue and not self._finished:
            if self._outstanding:
                return []   # waiting on observations for the current batch
            self._advance()
        return self._take(k)

    def observe(self, observations: Sequence[Observation]) -> None:
        """Feed back results for previously proposed candidates (in order)."""
        for o in observations:
            self._obs.append(o)
            self._outstanding -= 1
        if self._outstanding < 0:
            raise RuntimeError("observe() got results never proposed")

    @property
    def done(self) -> bool:
        """True once the strategy has no further candidates to offer."""
        return self._finished and not self._queue

    def _take(self, k: int) -> List[Candidate]:
        out, self._queue = self._queue[:k], self._queue[k:]
        self._outstanding += len(out)
        return out

    def _advance(self) -> None:
        """Resume the plan generator with the completed observation batch."""
        try:
            if self._gen is None:
                self._gen = self._plan()
                batch = next(self._gen)
            else:
                sent, self._obs = self._obs, []
                batch = self._gen.send(sent)
        except StopIteration:
            self._finished = True
            return
        self._queue = [c if isinstance(c, Candidate) else Candidate(int(c))
                       for c in batch]

    # -- legacy entry point ----------------------------------------------------
    def search(self, ev, max_steps: int) -> None:
        """Drive ``ev`` until the budget or the strategy is exhausted."""
        run_search(self, ev, max_steps)


def sequential_run_search(searcher: Searcher, ev, max_steps: int) -> None:
    """The original synchronous driver, kept verbatim as the golden
    reference: ``run_search(..., in_flight=1)`` must replay it bit-for-bit
    (full trace, not just the best — see tests/test_fleet.py)."""
    start = ev.steps
    while ev.steps - start < max_steps and not ev.exhausted():
        cands = searcher.propose(max_steps - (ev.steps - start))
        if not cands:
            return
        searcher.observe(ev.measure_many(cands))


def run_search(searcher: Searcher, ev, max_steps: int,
               in_flight: int = 1,
               in_flight_max: Optional[int] = None) -> None:
    """The uniform event-driven ask-tell driver used by every call site.

    Keeps up to ``in_flight`` candidates outstanding on the evaluator:
    while earlier submissions are still measuring, the searcher is asked for
    more (a generator-backed searcher that is waiting on its current batch
    simply returns ``[]`` and the driver collects instead).  With the
    default synchronous submit/collect shim and ``in_flight=1`` this is
    provably trace-identical to ``sequential_run_search``: the same
    candidates are proposed in the same order, evaluated one at a time, and
    recorded with identical (steps, elapsed, runtime) rows.

    ``in_flight_max`` makes the window ELASTIC: the driver reads the
    evaluator's backpressure (its ``workers`` lane count when it has one,
    plus the variance of observed measurement durations through an
    ``ElasticInFlight`` controller) and grows/shrinks the outstanding-work
    target between ``[in_flight, in_flight_max]`` — high duration variance
    deepens the queue so fast lanes never idle behind a straggler, uniform
    durations shrink it back to the lane count.  ``None`` (default) keeps
    the historical fixed-window behaviour, so existing call sites — and the
    ``in_flight=1`` golden equivalence — are unchanged.

    ``max_steps`` budgets *submissions* relative to the evaluator's state on
    entry (an evaluator that already spent steps on a training phase still
    gets a full search budget); everything submitted is drained before
    returning, so the account always ends with zero outstanding tests.
    """
    if in_flight < 1:
        raise ValueError(f"in_flight must be >= 1, got {in_flight}")
    ctrl = None
    if in_flight_max is not None:
        if in_flight_max < in_flight:
            raise ValueError(
                f"in_flight_max must be >= in_flight, got "
                f"{in_flight_max} < {in_flight}")
        from repro.core.evaluate import ElasticInFlight

        ctrl = ElasticInFlight(lo=in_flight, hi=in_flight_max)
    limit = in_flight
    submitted = 0
    while True:
        while (submitted < max_steps and ev.outstanding() < limit
               and not ev.exhausted()):
            k = min(limit - ev.outstanding(), max_steps - submitted)
            cands = searcher.propose(k)
            if not cands:
                break   # searcher finished, or waiting on outstanding tests
            ev.submit(cands)
            submitted += len(cands)
        if ev.outstanding() == 0:
            return
        obs = ev.collect()
        if obs:
            searcher.observe(obs)
            if ctrl is not None:
                for o in obs:
                    ctrl.observe(o.runtime)
                limit = ctrl.target(getattr(ev, "workers", 1))


def resolve_searcher(searcher) -> Type[Searcher]:
    """Registry name (or class) -> searcher class."""
    if isinstance(searcher, str):
        if searcher not in SEARCHERS:
            raise KeyError(
                f"unknown searcher {searcher!r}; "
                f"registered: {sorted(SEARCHERS)}")
        return SEARCHERS[searcher]
    return searcher


def make_searcher(searcher, space: TuningSpace, seed: int = 0,
                  **context) -> Searcher:
    """Construct a searcher by registry name (or class), passing only the
    ``context`` kwargs its constructor accepts — so one call site can supply
    model/cores/... without special-casing which searcher wants what.

    The filtering is for shared context; explicit user options should be
    validated by the caller against ``resolve_searcher(...)``'s signature
    (``TuningSession.make_searcher`` does) so typos don't silently vanish.
    """
    import inspect

    cls = resolve_searcher(searcher)
    params = inspect.signature(cls.__init__).parameters
    accepted = {k: v for k, v in context.items() if k in params}
    return cls(space, seed=seed, **accepted)


@register_searcher("random")
class RandomSearcher(Searcher):
    """Uniform random search without replacement."""

    def __init__(self, space: TuningSpace, seed: int = 0):
        super().__init__(space, seed)

    def _plan(self):
        order = self.rng.permutation(len(self.space))
        yield [Candidate(int(i)) for i in order]


@register_searcher("warm_start")
class WarmStartSearcher(Searcher):
    """Walks the space in a caller-supplied predicted-best order.

    The order typically comes from a portable model's score/runtime ranking
    (e.g. the serving tuner ranks configs by TP→PC_ops predictions executed
    through the cost model), so a tight live budget — the paper's repeated-
    autotuning scenario (ii) — only spends empirical tests on the few most
    promising configurations.  Indices absent from ``order`` are appended in
    seed-shuffled order as a fallback tail, so an exhaustive budget still
    covers the space.
    """

    def __init__(self, space: TuningSpace, order: Optional[Sequence[int]] = None,
                 seed: int = 0):
        super().__init__(space, seed)
        self.order = [int(i) for i in (order if order is not None else [])]

    def _plan(self):
        seen = set(self.order)
        tail = [i for i in self.rng.permutation(len(self.space))
                if int(i) not in seen]
        yield [Candidate(int(i)) for i in list(self.order) + tail]


@register_searcher("transfer_warm_start")
class TransferredWarmStart(Searcher):
    """``WarmStartSearcher`` with a distrust-and-verify first wave, for
    orders that come from a model trained on a DIFFERENT tuning space.

    A transferred prior is a guess: the source model never saw this
    space, so its ranking may be anywhere between spot-on and misleading.
    The first wave hedges by spending ``verify`` trials on the prior's
    head AND ``verify`` random probes; if the prior's head beat the
    probes, the walk trusts the transferred order (probed indices
    excluded), otherwise it falls back to the seed-shuffled random walk a
    cold job would have run — so a bad transfer costs at most one wave,
    while a good one keeps the full warm-start benefit.
    """

    def __init__(self, space: TuningSpace,
                 order: Optional[Sequence[int]] = None,
                 seed: int = 0, verify: int = 4):
        super().__init__(space, seed)
        self.order = [int(i) for i in (order if order is not None else [])]
        self.verify = max(1, int(verify))
        self.trusted: Optional[bool] = None   # set after the first wave

    def _plan(self):
        perm = [int(i) for i in self.rng.permutation(len(self.space))]
        if not self.order:          # nothing transferred: plain random walk
            yield [Candidate(i) for i in perm]
            return
        k = min(self.verify, len(self.order))
        head = self.order[:k]
        head_set = set(head)
        probes = [i for i in perm if i not in head_set][:k]
        wave = head + probes
        obs = yield [Candidate(i) for i in wave]
        by_index = {o.index: o.runtime for o in obs}
        best_head = min(by_index.get(i, float("inf")) for i in head)
        best_probe = min((by_index.get(i, float("inf")) for i in probes),
                         default=float("inf"))
        self.trusted = best_head <= best_probe
        seen = set(wave)
        if self.trusted:
            rest = [i for i in self.order if i not in seen]
            seen.update(rest)
            tail = [i for i in perm if i not in seen]
            yield [Candidate(i) for i in rest + tail]
        else:
            yield [Candidate(i) for i in perm if i not in seen]


@register_searcher("profile")
class ProfileBasedSearcher(Searcher):
    """Algorithm 1: profile, detect bottlenecks, react, score, biased step.

    Parameters
    ----------
    model : TPPCModel — portable TP→PC_ops model (may come from a different
        GPU/input — §3.1/§4.4/§4.5 — or be an ExactCounterModel for §4.3).
        May be bound after construction (``searcher.model = m``) but must be
        set before the first ``propose``.
    cores : TensorCore count of the *autotuning* hardware (bottleneck analysis
        runs on the architecture being tuned — §3.3).
    n : un-profiled benchmark runs between profiled runs (default 5, §3.7).
    inst_reaction : instruction-bottleneck threshold (0.7 default, §3.5.2).
    """

    def __init__(
        self,
        space: TuningSpace,
        model: Optional[TPPCModel] = None,
        cores: Optional[int] = None,
        n: int = 5,
        inst_reaction: float = reaction.INST_REACTION_DEFAULT,
        seed: int = 0,
    ):
        super().__init__(space, seed)
        self.model = model
        self.cores = cores
        self.n = n
        self.inst_reaction = inst_reaction
        # (matrix, name->column, PC_used mask) — built lazily (the model may
        # be bound after construction) and keyed on the model identity
        self._pred = None
        self._pred_model = None

    def _prediction(self):
        """The model's whole-space prediction matrix, computed once.

        Delegates to the module-level ``prediction_matrix`` cache, so the
        expensive part is shared across searcher instances (the experiment
        harness constructs one searcher per repetition); the per-search state
        here only re-derives the column index and PC_used mask.
        """
        if self._pred is None or self._pred_model is not self.model:
            names, matrix = prediction_matrix(self.model, self.space)
            cols = {name: j for j, name in enumerate(names)}
            self._pred = (matrix, cols, matrix != 0.0)
            self._pred_model = self.model
        return self._pred

    def _check_bound(self) -> None:
        """model and cores may be bound after construction (the registry's
        uniform signature) but must be set before searching — a silent
        default would mis-analyze bottlenecks, not error."""
        if self.model is None:
            raise ValueError(
                f"{type(self).__name__} needs a TP→PC model: pass model= at "
                "construction or assign searcher.model before searching")
        if self.cores is None:
            raise ValueError(
                f"{type(self).__name__} needs the tuning hardware's core "
                "count: pass cores= at construction or assign "
                "searcher.cores before searching")

    def _plan(self):
        self._check_bound()
        size = len(self.space)
        pred, cols, used = self._prediction()
        evaluated = np.zeros(size, dtype=bool)
        c_profile = int(self.rng.integers(size))
        while True:
            # line 3: empirical measurement with performance counters
            obs = yield [Candidate(c_profile, profile=True)]
            pc = obs[0].counters
            evaluated[c_profile] = True
            if pc is None:
                # the profiled test failed (crashing config marked
                # known-bad by a fault-tolerant driver): re-anchor on a
                # fresh unevaluated config instead of crashing the search
                remaining = np.flatnonzero(~evaluated)
                if remaining.size == 0:
                    return
                c_profile = int(remaining[self.rng.integers(remaining.size)])
                continue
            t = pc.runtime
            # line 4: bottleneck analysis (on the autotuning architecture)
            b = bottleneck.analyze(pc, cores=self.cores)
            # line 5: required counter changes
            delta_pc = reaction.compute_delta_pc(b, self.inst_reaction)
            # lines 6-14: score the whole space in one array pass (the
            # prediction matrix is fixed; only the ΔPC re-weighting changes
            # per profiling step)
            raw = scoring.score_space(delta_pc, pred[c_profile], pred, cols,
                                      used)
            raw[evaluated] = 0.0
            mask = ~evaluated
            if not mask.any():
                return
            weights = scoring.normalize_scores(raw)
            # lines 16-25: n biased un-profiled steps
            picks: List[Candidate] = []
            for _ in range(self.n):
                if not mask.any():
                    break
                sel = scoring.weighted_choice(weights, self.rng, mask)
                mask[sel] = False
                picks.append(Candidate(int(sel)))
            obs = yield picks
            for o in obs:
                evaluated[o.index] = True
                if o.runtime <= t:
                    c_profile, t = o.index, o.runtime


@register_searcher("basin_hopping")
class BasinHoppingSearcher(Searcher):
    """Kernel-Tuner-inspired Basin Hopping: greedy local descent over
    1-parameter neighbourhoods + random perturbation hops with Metropolis
    acceptance.  (Kernel Tuner wraps scipy.basinhopping over a normalized
    encoding; this is the discrete equivalent used for §4.7.)
    """

    def __init__(self, space: TuningSpace, seed: int = 0,
                 temperature: float = 1.0):
        super().__init__(space, seed)
        self.temperature = temperature
        self._known: Dict[int, float] = {}

    def _neighbours(self, idx: int) -> list:
        # the space's slot-hash index makes this O(degree) per query
        return self.space.neighbours(idx)

    def _measure_g(self, idx: int):
        """Sub-plan: measure ``idx`` once, replaying cached runtimes."""
        if idx not in self._known:
            obs = yield [Candidate(int(idx))]
            self._known[idx] = obs[0].runtime
        return self._known[idx]

    def _descent_g(self, start: int):
        """Sub-plan: first-improvement greedy descent from ``start``."""
        cur = start
        cur_t = yield from self._measure_g(cur)
        improved = True
        while improved:
            improved = False
            nbrs = [n for n in self._neighbours(cur) if n not in self._known]
            self.rng.shuffle(nbrs)
            for nb in nbrs:
                t = yield from self._measure_g(nb)
                if t < cur_t:
                    cur, cur_t = nb, t
                    improved = True
                    break  # first-improvement greedy
        return cur, cur_t

    def _perturb(self, idx: int) -> int:
        """Hop: randomly change a fraction of parameters, snap into space."""
        base = dict(self.space[idx])
        names = [p.name for p in self.space.parameters]
        k = max(1, len(names) // 3)
        for name in self.rng.choice(names, size=k, replace=False):
            p = next(q for q in self.space.parameters if q.name == name)
            base[name] = p.values[int(self.rng.integers(len(p.values)))]
        try:
            return self.space.index_of(base)
        except KeyError:  # violated a constraint — random fallback
            return int(self.rng.integers(len(self.space)))

    def _plan(self):
        cur = int(self.rng.integers(len(self.space)))
        cur, cur_t = yield from self._descent_g(cur)
        while True:
            cand = self._perturb(cur)
            if cand in self._known:
                unexplored = [i for i in range(len(self.space))
                              if i not in self._known]
                if not unexplored:
                    return
                cand = int(self.rng.choice(unexplored))
            cand, cand_t = yield from self._descent_g(cand)
            # Metropolis acceptance on the hop
            if cand_t < cur_t or self.rng.random() < np.exp(
                -(cand_t - cur_t) / (self.temperature * max(cur_t, 1e-12))
            ):
                cur, cur_t = cand, cand_t


@register_searcher("starchart")
class StarchartSearcher(Searcher):
    """Starchart protocol (§4.8.1): train a runtime regression tree from
    random samples until median relative prediction error < 15% (or 200
    training points), then walk the space in predicted-best order.

    Both training and validation measurements are empirical tests and are
    counted (the paper's "model build" column includes them).
    """

    def __init__(
        self,
        space: TuningSpace,
        seed: int = 0,
        n_validation: int = 200,
        max_train: int = 200,
        target_med_err: float = 0.15,
    ):
        super().__init__(space, seed)
        self.n_validation = n_validation
        self.max_train = max_train
        self.target_med_err = target_med_err
        self.model_build_steps = 0
        self._building = True

    def observe(self, observations) -> None:
        # every empirical test up to the end of model building counts as a
        # build step (the paper's "model build" column), even when the
        # budget truncates the build mid-batch
        super().observe(observations)
        if self._building:
            self.model_build_steps += len(observations)

    def _plan(self):
        size = len(self.space)
        X = self.space.feature_matrix
        order = self.rng.permutation(size)
        n_val = min(self.n_validation, max(1, size // 4))
        val_idx = order[:n_val]
        pool = order[n_val:]
        obs = yield [Candidate(int(i)) for i in val_idx]
        y_val = np.array([o.runtime for o in obs])

        train_idx: list = []
        y_train: list = []
        tree = None
        batch = 20
        cap = min(self.max_train, len(pool))
        while len(train_idx) < cap:
            take = pool[len(train_idx): len(train_idx) + batch]
            if take.size == 0:
                break
            obs = yield [Candidate(int(i)) for i in take]
            for o in obs:
                train_idx.append(o.index)
                y_train.append(o.runtime)
            tree = _build_tree(
                X[np.array(train_idx)], np.asarray(y_train), 0, 12, 1
            )
            pred = _tree_predict_batch(tree, X[val_idx])
            rel_err = np.abs(pred - y_val) / np.maximum(y_val, 1e-12)
            if float(np.median(rel_err)) < self.target_med_err:
                break
        self._building = False
        if tree is None:
            return
        # prediction-ordered walk over the unexplored space
        explored = set(int(i) for i in val_idx) | set(train_idx)
        pred_all = _tree_predict_batch(tree, X)
        walk = [Candidate(int(i)) for i in np.argsort(pred_all)
                if int(i) not in explored]
        if walk:
            yield walk


@register_searcher("profile_local")
class ProfileLocalSearcher(Searcher):
    """Beyond-paper extension (paper §3.9.1 future work): use the score as a
    GRADIENT ESTIMATE for a local searcher, combined with the global biased
    sampling to escape local optima.

    Each iteration profiles c_profile as in Algorithm 1, but the n unprofiled
    steps are split: the first are taken greedily from the best-scoring
    NEIGHBOURS of c_profile (1-parameter moves — following the estimated
    gradient of the performance function), the rest fall back to the global
    score-biased sample.  Mirrors Kernel Tuner's global+local findings [40]
    with the gradient supplied by the counter model instead of runtime
    probes.
    """

    def __init__(
        self,
        space: TuningSpace,
        model: Optional[TPPCModel] = None,
        cores: Optional[int] = None,
        n: int = 5,
        local_frac: float = 0.6,
        inst_reaction: float = reaction.INST_REACTION_DEFAULT,
        seed: int = 0,
    ):
        super().__init__(space, seed)
        self.model = model
        self.cores = cores
        self.n = n
        self.local_frac = local_frac
        self.inst_reaction = inst_reaction
        self._pred = None
        self._pred_model = None

    _check_bound = ProfileBasedSearcher._check_bound
    _prediction = ProfileBasedSearcher._prediction

    def _plan(self):
        self._check_bound()
        size = len(self.space)
        pred, cols, used = self._prediction()
        evaluated = np.zeros(size, dtype=bool)
        c_profile = int(self.rng.integers(size))
        while True:
            obs = yield [Candidate(c_profile, profile=True)]
            pc = obs[0].counters
            evaluated[c_profile] = True
            if pc is None:      # failed profile test: re-anchor, keep going
                remaining = np.flatnonzero(~evaluated)
                if remaining.size == 0:
                    return
                c_profile = int(remaining[self.rng.integers(remaining.size)])
                continue
            t = pc.runtime
            b = bottleneck.analyze(pc, cores=self.cores)
            delta_pc = reaction.compute_delta_pc(b, self.inst_reaction)

            raw = scoring.score_space(delta_pc, pred[c_profile], pred, cols,
                                      used)
            raw[evaluated] = 0.0
            mask = ~evaluated
            if not mask.any():
                return
            weights = scoring.normalize_scores(raw)

            n_local = int(round(self.n * self.local_frac))
            # local phase: best-scoring unexplored neighbours (gradient step)
            nbrs = [j for j in self.space.neighbours(c_profile)
                    if not evaluated[j]]
            nbrs.sort(key=lambda j: raw[j], reverse=True)
            local = nbrs[:n_local]
            for j in local:
                mask[j] = False
            if local:
                obs = yield [Candidate(int(j)) for j in local]
                for o in obs:
                    evaluated[o.index] = True
                    if o.runtime <= t:
                        c_profile, t = o.index, o.runtime
            # global phase: score-biased sampling (escape hatch)
            picks: List[Candidate] = []
            for _ in range(self.n - min(n_local, len(nbrs))):
                if not mask.any():
                    break
                sel = scoring.weighted_choice(weights, self.rng, mask)
                mask[sel] = False
                picks.append(Candidate(int(sel)))
            if picks:
                obs = yield picks
                for o in obs:
                    evaluated[o.index] = True
                    if o.runtime <= t:
                        c_profile, t = o.index, o.runtime
