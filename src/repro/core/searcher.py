"""Tuning-space searchers.

* ``ProfileBasedSearcher`` — the paper's contribution (Algorithm 1): biased
  weighted-random search navigated by performance counters, a portable
  TP→PC_ops model, and the bottleneck/ΔPC expert system.
* ``RandomSearcher`` — the paper's primary baseline.
* ``BasinHoppingSearcher`` — Kernel-Tuner-style global+local optimization
  (paper §4.7 comparison target).
* ``StarchartSearcher`` — recursive-partitioning surrogate model search
  (paper §4.8 comparison target).

All searchers drive an evaluator (``measure``/``profile``) so empirical tests
are counted identically — the paper's primary metric.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core import bottleneck, reaction, scoring
from repro.core.model import TPPCModel, _build_tree, _tree_predict
from repro.core.tuning_space import TuningSpace


class Searcher:
    name = "base"

    def search(self, ev, max_steps: int) -> None:
        raise NotImplementedError


class RandomSearcher(Searcher):
    """Uniform random search without replacement."""

    name = "random"

    def __init__(self, space: TuningSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)

    def search(self, ev, max_steps: int) -> None:
        order = self.rng.permutation(len(self.space))
        for idx in order[:max_steps]:
            ev.measure(int(idx))


class ProfileBasedSearcher(Searcher):
    """Algorithm 1: profile, detect bottlenecks, react, score, biased step.

    Parameters
    ----------
    model : TPPCModel — portable TP→PC_ops model (may come from a different
        GPU/input — §3.1/§4.4/§4.5 — or be an ExactCounterModel for §4.3).
    cores : TensorCore count of the *autotuning* hardware (bottleneck analysis
        runs on the architecture being tuned — §3.3).
    n : un-profiled benchmark runs between profiled runs (default 5, §3.7).
    inst_reaction : instruction-bottleneck threshold (0.7 default, §3.5.2).
    """

    name = "profile"

    def __init__(
        self,
        space: TuningSpace,
        model: TPPCModel,
        cores: int,
        n: int = 5,
        inst_reaction: float = reaction.INST_REACTION_DEFAULT,
        seed: int = 0,
    ):
        self.space = space
        self.model = model
        self.cores = cores
        self.n = n
        self.inst_reaction = inst_reaction
        self.rng = np.random.default_rng(seed)
        # model predictions are config-indexed and reused across iterations
        self._pred_cache: Dict[int, Dict[str, float]] = {}

    def _predict(self, idx: int) -> Dict[str, float]:
        if idx not in self._pred_cache:
            self._pred_cache[idx] = self.model.predict(self.space[idx])
        return self._pred_cache[idx]

    def search(self, ev, max_steps: int) -> None:
        size = len(self.space)
        c_profile = int(self.rng.integers(size))
        while ev.steps < max_steps and not ev.exhausted():
            # line 3: empirical measurement with performance counters
            pc = ev.profile(c_profile)
            t = pc.runtime
            # line 4: bottleneck analysis (on the autotuning architecture)
            b = bottleneck.analyze(pc, cores=self.cores)
            # line 5: required counter changes
            delta_pc = reaction.compute_delta_pc(b, self.inst_reaction)
            # lines 6-14: score all unexplored configurations via the model
            pc_prof = self._predict(c_profile)
            raw = np.zeros(size)
            mask = np.zeros(size, dtype=bool)
            for k in range(size):
                if k in ev.evaluated:
                    continue
                mask[k] = True
                raw[k] = scoring.score_configuration(
                    delta_pc, pc_prof, self._predict(k)
                )
            if not mask.any():
                return
            weights = scoring.normalize_scores(raw)
            # lines 16-25: n biased un-profiled steps
            for _ in range(self.n):
                if ev.steps >= max_steps or not mask.any():
                    break
                sel = scoring.weighted_choice(weights, self.rng, mask)
                t_new = ev.measure(sel)
                mask[sel] = False
                if t_new <= t:
                    c_profile, t = sel, t_new
            if ev.exhausted():
                return


class BasinHoppingSearcher(Searcher):
    """Kernel-Tuner-inspired Basin Hopping: greedy local descent over
    1-parameter neighbourhoods + random perturbation hops with Metropolis
    acceptance.  (Kernel Tuner wraps scipy.basinhopping over a normalized
    encoding; this is the discrete equivalent used for §4.7.)
    """

    name = "basin_hopping"

    def __init__(self, space: TuningSpace, seed: int = 0, temperature: float = 1.0):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.temperature = temperature
        # neighbour lists are O(N^2) to build; cache lazily per index
        self._nbrs: Dict[int, list] = {}
        self._known: Dict[int, float] = {}

    def _neighbours(self, idx: int) -> list:
        if idx not in self._nbrs:
            self._nbrs[idx] = self.space.neighbours(idx)
        return self._nbrs[idx]

    def _measure(self, ev, idx: int) -> float:
        if idx not in self._known:
            self._known[idx] = ev.measure(idx)
        return self._known[idx]

    def _local_descent(self, ev, start: int, max_steps: int) -> tuple:
        cur = start
        cur_t = self._measure(ev, cur)
        improved = True
        while improved and ev.steps < max_steps:
            improved = False
            nbrs = [n for n in self._neighbours(cur) if n not in ev.evaluated]
            self.rng.shuffle(nbrs)
            for nb in nbrs:
                if ev.steps >= max_steps:
                    break
                t = self._measure(ev, nb)
                if t < cur_t:
                    cur, cur_t = nb, t
                    improved = True
                    break  # first-improvement greedy
        return cur, cur_t

    def _perturb(self, idx: int) -> int:
        """Hop: randomly change a fraction of parameters, snap into space."""
        base = dict(self.space[idx])
        names = [p.name for p in self.space.parameters]
        k = max(1, len(names) // 3)
        for name in self.rng.choice(names, size=k, replace=False):
            p = next(q for q in self.space.parameters if q.name == name)
            base[name] = p.values[int(self.rng.integers(len(p.values)))]
        try:
            return self.space.index_of(base)
        except KeyError:  # violated a constraint — random fallback
            return int(self.rng.integers(len(self.space)))

    def search(self, ev, max_steps: int) -> None:
        cur = int(self.rng.integers(len(self.space)))
        cur, cur_t = self._local_descent(ev, cur, max_steps)
        while ev.steps < max_steps and not ev.exhausted():
            cand = self._perturb(cur)
            if cand in ev.evaluated:
                unexplored = [i for i in range(len(self.space))
                              if i not in ev.evaluated]
                if not unexplored:
                    return
                cand = int(self.rng.choice(unexplored))
            cand, cand_t = self._local_descent(ev, cand, max_steps)
            # Metropolis acceptance on the hop
            if cand_t < cur_t or self.rng.random() < np.exp(
                -(cand_t - cur_t) / (self.temperature * max(cur_t, 1e-12))
            ):
                cur, cur_t = cand, cand_t


class StarchartSearcher(Searcher):
    """Starchart protocol (§4.8.1): train a runtime regression tree from
    random samples until median relative prediction error < 15% (or 200
    training points), then walk the space in predicted-best order.

    Both training and validation measurements are empirical tests and are
    counted (the paper's "model build" column includes them).
    """

    name = "starchart"

    def __init__(
        self,
        space: TuningSpace,
        seed: int = 0,
        n_validation: int = 200,
        max_train: int = 200,
        target_med_err: float = 0.15,
    ):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.n_validation = n_validation
        self.max_train = max_train
        self.target_med_err = target_med_err
        self.model_build_steps = 0

    def search(self, ev, max_steps: int) -> None:
        size = len(self.space)
        X = np.array([self.space.vectorize(c) for c in self.space])
        order = self.rng.permutation(size)
        n_val = min(self.n_validation, max(1, size // 4))
        val_idx = order[:n_val]
        pool = order[n_val:]
        y_val = np.array([ev.measure(int(i)) for i in val_idx])

        train_idx: list = []
        y_train: list = []
        tree = None
        batch = 20
        while ev.steps < max_steps and len(train_idx) < min(self.max_train,
                                                            len(pool)):
            take = pool[len(train_idx): len(train_idx) + batch]
            if take.size == 0:
                break
            for i in take:
                train_idx.append(int(i))
                y_train.append(ev.measure(int(i)))
            tree = _build_tree(
                X[np.array(train_idx)], np.asarray(y_train), 0, 12, 1
            )
            pred = np.array([_tree_predict(tree, X[i]) for i in val_idx])
            rel_err = np.abs(pred - y_val) / np.maximum(y_val, 1e-12)
            if float(np.median(rel_err)) < self.target_med_err:
                break
        self.model_build_steps = ev.steps
        if tree is None:
            return
        # prediction-ordered walk over the unexplored space
        pred_all = np.array([_tree_predict(tree, x) for x in X])
        for idx in np.argsort(pred_all):
            if ev.steps >= max_steps:
                return
            if int(idx) in ev.evaluated:
                continue
            ev.measure(int(idx))


class ProfileLocalSearcher(Searcher):
    """Beyond-paper extension (paper §3.9.1 future work): use the score as a
    GRADIENT ESTIMATE for a local searcher, combined with the global biased
    sampling to escape local optima.

    Each iteration profiles c_profile as in Algorithm 1, but the n unprofiled
    steps are split: the first are taken greedily from the best-scoring
    NEIGHBOURS of c_profile (1-parameter moves — following the estimated
    gradient of the performance function), the rest fall back to the global
    score-biased sample.  Mirrors Kernel Tuner's global+local findings [40]
    with the gradient supplied by the counter model instead of runtime
    probes.
    """

    name = "profile_local"

    def __init__(
        self,
        space: TuningSpace,
        model: TPPCModel,
        cores: int,
        n: int = 5,
        local_frac: float = 0.6,
        inst_reaction: float = reaction.INST_REACTION_DEFAULT,
        seed: int = 0,
    ):
        self.space = space
        self.model = model
        self.cores = cores
        self.n = n
        self.local_frac = local_frac
        self.inst_reaction = inst_reaction
        self.rng = np.random.default_rng(seed)
        self._pred_cache: Dict[int, Dict[str, float]] = {}
        self._nbrs: Dict[int, list] = {}

    def _predict(self, idx: int) -> Dict[str, float]:
        if idx not in self._pred_cache:
            self._pred_cache[idx] = self.model.predict(self.space[idx])
        return self._pred_cache[idx]

    def _neighbours(self, idx: int) -> list:
        if idx not in self._nbrs:
            self._nbrs[idx] = self.space.neighbours(idx)
        return self._nbrs[idx]

    def search(self, ev, max_steps: int) -> None:
        size = len(self.space)
        c_profile = int(self.rng.integers(size))
        while ev.steps < max_steps and not ev.exhausted():
            pc = ev.profile(c_profile)
            t = pc.runtime
            b = bottleneck.analyze(pc, cores=self.cores)
            delta_pc = reaction.compute_delta_pc(b, self.inst_reaction)
            pc_prof = self._predict(c_profile)

            raw = np.zeros(size)
            mask = np.zeros(size, dtype=bool)
            for k in range(size):
                if k in ev.evaluated:
                    continue
                mask[k] = True
                raw[k] = scoring.score_configuration(
                    delta_pc, pc_prof, self._predict(k))
            if not mask.any():
                return
            weights = scoring.normalize_scores(raw)

            n_local = int(round(self.n * self.local_frac))
            # local phase: best-scoring unexplored neighbours (gradient step)
            nbrs = [j for j in self._neighbours(c_profile)
                    if j not in ev.evaluated]
            nbrs.sort(key=lambda j: raw[j], reverse=True)
            for j in nbrs[:n_local]:
                if ev.steps >= max_steps:
                    return
                t_new = ev.measure(j)
                mask[j] = False
                if t_new <= t:
                    c_profile, t = j, t_new
            # global phase: score-biased sampling (escape hatch)
            for _ in range(self.n - min(n_local, len(nbrs))):
                if ev.steps >= max_steps or not mask.any():
                    break
                sel = scoring.weighted_choice(weights, self.rng, mask)
                t_new = ev.measure(sel)
                mask[sel] = False
                if t_new <= t:
                    c_profile, t = sel, t_new
            if ev.exhausted():
                return
