"""Core: the paper's contribution — profile-counter-guided tuning-space search.

Public API:
    TuningParameter, TuningSpace          — generic tuning spaces
    CounterSet, PC_OPS, PC_STRESS         — TPU counter taxonomy
    HardwareSpec, SPECS                   — virtual TPU testbed
    analyze / compute_delta_pc            — expert system
    DecisionTreeModel / QuadraticRegressionModel / ExactCounterModel
    ProfileBasedSearcher (+ baselines)    — Algorithm 1
    autotune / train_model / run_search_experiment
"""
from repro.core.bottleneck import analyze
from repro.core.counters import PC_OPS, PC_STRESS, CounterSet
from repro.core.evaluate import (CostModelEvaluator, RecordedSpace,
                                 ReplayEvaluator, record_space)
from repro.core.hwspec import PORTABILITY_SET, PRODUCTION, SPECS, HardwareSpec
from repro.core.model import (DecisionTreeModel, ExactCounterModel,
                              QuadraticRegressionModel,
                              deliberate_training_sample)
from repro.core.reaction import compute_delta_pc
from repro.core.searcher import (BasinHoppingSearcher, ProfileBasedSearcher,
                                 ProfileLocalSearcher, RandomSearcher,
                                 StarchartSearcher)
from repro.core.tuner import (SearchStats, TuneResult, autotune,
                              convergence_curve, run_search_experiment,
                              steps_to_well_performing, train_model,
                              train_model_deliberate)
from repro.core.tuning_space import (Config, TuningParameter, TuningSpace,
                                     powers_of_two)

__all__ = [
    "analyze", "autotune", "compute_delta_pc", "convergence_curve",
    "record_space", "run_search_experiment", "steps_to_well_performing",
    "train_model", "train_model_deliberate", "deliberate_training_sample",
    "powers_of_two",
    "BasinHoppingSearcher", "Config", "CostModelEvaluator", "CounterSet",
    "DecisionTreeModel", "ExactCounterModel", "HardwareSpec", "PC_OPS",
    "PC_STRESS", "PORTABILITY_SET", "PRODUCTION", "ProfileBasedSearcher",
    "ProfileLocalSearcher", "QuadraticRegressionModel",
    "RandomSearcher", "RecordedSpace",
    "ReplayEvaluator", "SPECS", "SearchStats", "StarchartSearcher",
    "TuneResult", "TuningParameter", "TuningSpace",
]
