"""Core: the paper's contribution — profile-counter-guided tuning-space search.

Public API:
    TuningParameter, TuningSpace          — generic tuning spaces
    CounterSet, PC_OPS, PC_STRESS         — TPU counter taxonomy
    HardwareSpec, SPECS                   — virtual TPU testbed
    analyze / compute_delta_pc            — expert system
    DecisionTreeModel / QuadraticRegressionModel / ExactCounterModel
    ProfileBasedSearcher (+ baselines)    — Algorithm 1
    autotune / train_model / run_search_experiment
"""
from repro.core.account import (AccountSnapshot, Candidate, EvalAccount,
                                Evaluator, Observation,
                                ProfilingUnsupported, Ticket)
from repro.core.bottleneck import analyze
from repro.core.counters import PC_OPS, PC_STRESS, CounterSet
from repro.core.evaluate import (CostModelEvaluator, FunctionEvaluator,
                                 RecordedSpace, ReplayEvaluator,
                                 VirtualAsyncEvaluator, record_space)
from repro.core.hwspec import (PORTABILITY_SET, PRODUCTION, SPECS,
                               HardwareSpec, fingerprint, hardware_key,
                               normalize_name)
from repro.core.model import (DecisionTreeModel, ExactCounterModel,
                              QuadraticRegressionModel,
                              deliberate_training_sample, prediction_matrix)
from repro.core.reaction import compute_delta_pc
from repro.core.searcher import (SEARCHERS, BasinHoppingSearcher,
                                 ProfileBasedSearcher, ProfileLocalSearcher,
                                 RandomSearcher, Searcher, StarchartSearcher,
                                 WarmStartSearcher, make_searcher,
                                 register_searcher, resolve_searcher,
                                 run_search, sequential_run_search)
from repro.core.tuner import (SearchStats, TuneResult, autotune,
                              convergence_curve, predicted_runtimes,
                              run_search_experiment,
                              steps_to_well_performing, train_model,
                              train_model_deliberate)
from repro.core.tuning_space import (Config, TuningParameter, TuningSpace,
                                     powers_of_two)

__all__ = [
    "analyze", "autotune", "compute_delta_pc", "convergence_curve",
    "fingerprint", "hardware_key", "make_searcher", "normalize_name",
    "record_space", "register_searcher", "resolve_searcher",
    "run_search", "sequential_run_search",
    "run_search_experiment", "steps_to_well_performing",
    "train_model", "train_model_deliberate", "deliberate_training_sample",
    "powers_of_two", "predicted_runtimes", "prediction_matrix",
    "AccountSnapshot", "BasinHoppingSearcher", "Candidate", "Config",
    "CostModelEvaluator",
    "CounterSet", "DecisionTreeModel", "EvalAccount", "Evaluator",
    "ExactCounterModel", "FunctionEvaluator", "HardwareSpec", "Observation",
    "PC_OPS", "PC_STRESS", "PORTABILITY_SET", "PRODUCTION",
    "ProfileBasedSearcher", "ProfileLocalSearcher", "ProfilingUnsupported",
    "QuadraticRegressionModel", "RandomSearcher", "RecordedSpace",
    "ReplayEvaluator", "SEARCHERS", "SearchStats", "Searcher",
    "StarchartSearcher", "Ticket", "TuneResult", "TuningParameter",
    "TuningSpace", "VirtualAsyncEvaluator", "WarmStartSearcher",
]
