"""Tuning orchestration: training phase, autotuning phase, experiment stats.

Mirrors the paper's two-phase architecture (Fig. 2):

  training phase:  sample/exhaust a tuning space on ANY hardware+input →
                   build a TP→PC_ops model (portable);
  autotuning:      profile → bottlenecks → ΔPC → score → biased step
                   on the hardware+input OF INTEREST.

Also provides the experiment harness used by benchmarks/: repeated stochastic
searches (1000x in the paper) with steps-to-well-performing statistics and
convergence-in-time traces.

The session-oriented public API lives in ``repro.tuning`` (``TuningSession``,
``SEARCHERS``); ``autotune`` below remains as a one-call shim over it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core import counters as C
from repro.core.evaluate import (CostModelEvaluator, RecordedSpace,
                                 ReplayEvaluator, record_space)
from repro.core.hwspec import HardwareSpec
from repro.core.model import (DecisionTreeModel, ExactCounterModel,
                              QuadraticRegressionModel, TPPCModel,
                              deliberate_training_sample, prediction_matrix)
from repro.core.searcher import ProfileBasedSearcher, Searcher
from repro.core.tuning_space import Config, TuningSpace

WELL_PERFORMING_FACTOR = 1.1  # paper §4.1


def predicted_runtimes(model: TPPCModel, space: TuningSpace,
                       hw: HardwareSpec) -> np.ndarray:
    """Whole-space predicted runtimes: the portable model's PC_ops
    predictions priced through the cost model on ``hw``.

    The warm-start substrate shared by the serving tuner's ranking and the
    fleet's ``predicted_runtime_order``: negative predictions are clamped
    to zero and non-ops columns dropped before pricing.  One scalar
    ``costmodel.execute`` per config — fine at serving/fleet space sizes
    (tens to ~1k); batch ``execute`` before pointing this at paper-scale
    (200k) spaces.
    """
    names, mat = prediction_matrix(model, space)
    pred = np.empty(len(space), dtype=np.float64)
    for i in range(len(space)):
        ops = {k: max(0.0, float(v)) for k, v in zip(names, mat[i])
               if k in C.PC_OPS}
        pred[i] = costmodel.execute(ops, hw).runtime
    return pred


def ensemble_runtime_scores(ensemble, space: TuningSpace,
                            hw: HardwareSpec) -> np.ndarray:
    """Whole-space RELATIVE runtime scores for a ``TransferEnsemble``.

    Each member's predictions are priced through the cost model like any
    warm start, normalized by its own predicted best (sources live on
    different absolute runtime scales), and blended as a
    similarity-weighted geometric mean.  The result is dimensionless
    (1.0 = a member-consensus best config); only its ARGSORT is
    meaningful — which is all the transferred warm start consumes.
    """
    log_sum = np.zeros(len(space), dtype=np.float64)
    w_sum = 0.0
    for model, weight in ensemble.members:
        r = np.maximum(predicted_runtimes(model, space, hw), 1e-300)
        log_sum += weight * np.log(r / r.min())
        w_sum += weight
    return np.exp(log_sum / max(w_sum, 1e-300))


# =============================================================================
# Training phase
# =============================================================================
def train_model(
    recorded: RecordedSpace,
    kind: str = "tree",
    sample: Optional[Sequence[int]] = None,
    seed: int = 0,
) -> TPPCModel:
    """Build a portable TP→PC_ops model from (possibly partial) tuning data.

    kind: 'tree' (§3.4.2), 'quadratic' (§3.4.1) or 'exact' (§4.3 replay).
    ``sample``: indices of the explored part of the space (defaults to all —
    the paper also trains on complete spaces).
    """
    space = recorded.space
    if kind == "exact":
        return ExactCounterModel(space, recorded.ops_list())
    idxs = list(sample) if sample is not None else list(range(len(space)))
    cfgs = [space[i] for i in idxs]
    ops = [recorded.counters[i].ops for i in idxs]
    if kind == "tree":
        return DecisionTreeModel(space, cfgs, ops,
                                 rng=np.random.default_rng(seed))
    if kind == "quadratic":
        return QuadraticRegressionModel(space, cfgs, ops)
    raise ValueError(f"unknown model kind {kind!r}")


def train_model_deliberate(
    recorded: RecordedSpace, kind: str = "tree", seed: int = 0
) -> TPPCModel:
    """Training on the deliberate 2-3-values-per-parameter sample (§3.4.1)."""
    sample = deliberate_training_sample(recorded.space,
                                        rng=np.random.default_rng(seed))
    return train_model(recorded, kind=kind, sample=sample, seed=seed)


# =============================================================================
# Experiment harness (paper §4 methodology)
# =============================================================================
@dataclasses.dataclass
class SearchStats:
    searcher: str
    steps_to_well: List[int]
    times_to_well: List[float]
    never_found: int

    @property
    def runs(self) -> int:
        return len(self.steps_to_well) + self.never_found

    @property
    def found_rate(self) -> float:
        """Fraction of repetitions that reached a well-performing config."""
        return len(self.steps_to_well) / self.runs if self.runs else 0.0

    @property
    def mean_steps(self) -> float:
        return float(np.mean(self.steps_to_well)) if self.steps_to_well else float("nan")

    @property
    def median_steps(self) -> float:
        return float(np.median(self.steps_to_well)) if self.steps_to_well else float("nan")

    @property
    def mean_time(self) -> float:
        return float(np.mean(self.times_to_well)) if self.times_to_well else float("nan")

    def summary(self) -> str:
        """Human-readable line; explicit about never-found runs instead of
        letting NaN means leak into reports."""
        if not self.steps_to_well:
            return (f"{self.searcher}: never found a well-performing config "
                    f"in {self.runs} runs")
        line = (f"{self.searcher}: mean {self.mean_steps:.1f} / median "
                f"{self.median_steps:.1f} steps to well-performing")
        if self.never_found:
            line += f" ({self.never_found}/{self.runs} runs never found)"
        return line


def steps_to_well_performing(
    ev, threshold: float
) -> Tuple[Optional[int], Optional[float]]:
    """First empirical test reaching runtime <= threshold: (steps, elapsed).

    Works on any evaluator implementing the shared protocol (reads the
    public trace).
    """
    for steps, elapsed, rt in ev.trace:
        if rt <= threshold:
            return steps, elapsed
    return None, None


def run_search_experiment(
    searcher_factory: Callable[[int], Searcher],
    recorded: RecordedSpace,
    repeats: int = 1000,
    max_steps: Optional[int] = None,
    well_factor: float = WELL_PERFORMING_FACTOR,
) -> SearchStats:
    """Repeat a stochastic search ``repeats`` times (paper: 1000)."""
    threshold = recorded.best_runtime * well_factor
    cap = max_steps if max_steps is not None else len(recorded.space)
    steps_list: List[int] = []
    times_list: List[float] = []
    never = 0
    name = ""
    for rep in range(repeats):
        searcher = searcher_factory(rep)
        name = searcher.name
        ev = ReplayEvaluator(recorded)
        searcher.search(ev, max_steps=cap)
        s, t = steps_to_well_performing(ev, threshold)
        if s is None:
            never += 1
        else:
            steps_list.append(s)
            times_list.append(t)
    return SearchStats(searcher=name, steps_to_well=steps_list,
                       times_to_well=times_list, never_found=never)


def convergence_curve(
    searcher_factory: Callable[[int], Searcher],
    recorded: RecordedSpace,
    repeats: int = 100,
    max_steps: Optional[int] = None,
    time_grid: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Average best-runtime-so-far at each second of tuning (paper Figs 3-8).

    Returns (time_grid, mean_curve, std_curve).  Curves start at the first
    instant when *all* repetitions have at least one finished kernel (§4.6.1).
    Repetitions that never finished a kernel are excluded; if none did, the
    curves are all-NaN over the given (or empty) grid rather than raising.
    """
    cap = max_steps if max_steps is not None else len(recorded.space)
    traces = []
    for rep in range(repeats):
        searcher = searcher_factory(rep)
        ev = ReplayEvaluator(recorded)
        searcher.search(ev, max_steps=cap)
        traces.append(ev.trace)
    traces = [tr for tr in traces if tr]
    if not traces:
        grid = (np.asarray(time_grid, dtype=np.float64)
                if time_grid is not None else np.empty(0))
        nan = np.full(grid.shape, np.nan)
        return grid, nan, nan.copy()
    first_done = max(tr[0][1] for tr in traces)
    t_end = max(tr[-1][1] for tr in traces)
    if time_grid is None:
        time_grid = np.linspace(first_done, t_end, 200)
    curves = np.empty((len(traces), time_grid.size))
    for i, tr in enumerate(traces):
        times = np.array([e for _, e, _ in tr])
        bests = np.minimum.accumulate(np.array([r for _, _, r in tr]))
        # best finished kernel at each grid time
        pos = np.searchsorted(times, time_grid, side="right") - 1
        pos = np.clip(pos, 0, len(bests) - 1)
        curves[i] = bests[pos]
        curves[i][time_grid < times[0]] = np.nan
    mean = np.nanmean(curves, axis=0)
    std = np.nanstd(curves, axis=0)
    return time_grid, mean, std


# =============================================================================
# High-level API: one-call shim over repro.tuning.TuningSession
# =============================================================================
@dataclasses.dataclass
class TuneResult:
    best_config: Config
    best_runtime: float
    steps: int
    history: List[Tuple[int, float]]


def autotune(
    space: TuningSpace,
    workload_fn: Callable[[Config], Dict[str, float]],
    hw: HardwareSpec,
    model: Optional[TPPCModel] = None,
    train_hw: Optional[HardwareSpec] = None,
    budget: int = 60,
    model_kind: str = "tree",
    seed: int = 0,
    searcher_cls: type = ProfileBasedSearcher,
) -> TuneResult:
    """One-call autotuning: train (if no model given) then search.

    ``train_hw`` lets the model be built on different (virtual) hardware than
    the autotuning target — the paper's headline capability.  Thin shim over
    ``repro.tuning.TuningSession`` kept for the one-liner use case.
    """
    from repro.tuning.session import TuningSession  # tuning builds on core

    session = TuningSession(space, workload_fn, hw, model=model, seed=seed)
    if session.model is None:
        session.train(train_hw=train_hw, kind=model_kind)
    return session.tune(budget=budget, searcher=searcher_cls)
