"""Tuning orchestration: training phase, autotuning phase, experiment stats.

Mirrors the paper's two-phase architecture (Fig. 2):

  training phase:  sample/exhaust a tuning space on ANY hardware+input →
                   build a TP→PC_ops model (portable);
  autotuning:      profile → bottlenecks → ΔPC → score → biased step
                   on the hardware+input OF INTEREST.

Also provides the experiment harness used by benchmarks/: repeated stochastic
searches (1000x in the paper) with steps-to-well-performing statistics and
convergence-in-time traces.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.evaluate import (CostModelEvaluator, RecordedSpace,
                                 ReplayEvaluator, record_space)
from repro.core.hwspec import HardwareSpec
from repro.core.model import (DecisionTreeModel, ExactCounterModel,
                              QuadraticRegressionModel, TPPCModel,
                              deliberate_training_sample)
from repro.core.searcher import (BasinHoppingSearcher, ProfileBasedSearcher,
                                 RandomSearcher, Searcher, StarchartSearcher)
from repro.core.tuning_space import Config, TuningSpace

WELL_PERFORMING_FACTOR = 1.1  # paper §4.1


# =============================================================================
# Training phase
# =============================================================================
def train_model(
    recorded: RecordedSpace,
    kind: str = "tree",
    sample: Optional[Sequence[int]] = None,
    seed: int = 0,
) -> TPPCModel:
    """Build a portable TP→PC_ops model from (possibly partial) tuning data.

    kind: 'tree' (§3.4.2), 'quadratic' (§3.4.1) or 'exact' (§4.3 replay).
    ``sample``: indices of the explored part of the space (defaults to all —
    the paper also trains on complete spaces).
    """
    space = recorded.space
    if kind == "exact":
        return ExactCounterModel(space, recorded.ops_list())
    idxs = list(sample) if sample is not None else list(range(len(space)))
    cfgs = [space[i] for i in idxs]
    ops = [recorded.counters[i].ops for i in idxs]
    if kind == "tree":
        return DecisionTreeModel(space, cfgs, ops,
                                 rng=np.random.default_rng(seed))
    if kind == "quadratic":
        return QuadraticRegressionModel(space, cfgs, ops)
    raise ValueError(f"unknown model kind {kind!r}")


def train_model_deliberate(
    recorded: RecordedSpace, kind: str = "tree", seed: int = 0
) -> TPPCModel:
    """Training on the deliberate 2-3-values-per-parameter sample (§3.4.1)."""
    sample = deliberate_training_sample(recorded.space,
                                        rng=np.random.default_rng(seed))
    return train_model(recorded, kind=kind, sample=sample, seed=seed)


# =============================================================================
# Experiment harness (paper §4 methodology)
# =============================================================================
@dataclasses.dataclass
class SearchStats:
    searcher: str
    steps_to_well: List[int]
    times_to_well: List[float]
    never_found: int

    @property
    def mean_steps(self) -> float:
        return float(np.mean(self.steps_to_well)) if self.steps_to_well else float("nan")

    @property
    def mean_time(self) -> float:
        return float(np.mean(self.times_to_well)) if self.times_to_well else float("nan")


def steps_to_well_performing(
    ev: ReplayEvaluator, threshold: float
) -> Tuple[Optional[int], Optional[float]]:
    """First empirical test reaching runtime <= threshold: (steps, elapsed)."""
    for steps, elapsed, rt in ev.trace:
        if rt <= threshold:
            return steps, elapsed
    return None, None


def run_search_experiment(
    searcher_factory: Callable[[int], Searcher],
    recorded: RecordedSpace,
    repeats: int = 1000,
    max_steps: Optional[int] = None,
    well_factor: float = WELL_PERFORMING_FACTOR,
) -> SearchStats:
    """Repeat a stochastic search ``repeats`` times (paper: 1000)."""
    threshold = recorded.best_runtime * well_factor
    cap = max_steps if max_steps is not None else len(recorded.space)
    steps_list: List[int] = []
    times_list: List[float] = []
    never = 0
    name = ""
    for rep in range(repeats):
        searcher = searcher_factory(rep)
        name = searcher.name
        ev = ReplayEvaluator(recorded)
        searcher.search(ev, max_steps=cap)
        s, t = steps_to_well_performing(ev, threshold)
        if s is None:
            never += 1
        else:
            steps_list.append(s)
            times_list.append(t)
    return SearchStats(searcher=name, steps_to_well=steps_list,
                       times_to_well=times_list, never_found=never)


def convergence_curve(
    searcher_factory: Callable[[int], Searcher],
    recorded: RecordedSpace,
    repeats: int = 100,
    max_steps: Optional[int] = None,
    time_grid: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Average best-runtime-so-far at each second of tuning (paper Figs 3-8).

    Returns (time_grid, mean_curve, std_curve).  Curves start at the first
    instant when *all* repetitions have at least one finished kernel (§4.6.1).
    """
    cap = max_steps if max_steps is not None else len(recorded.space)
    traces = []
    for rep in range(repeats):
        searcher = searcher_factory(rep)
        ev = ReplayEvaluator(recorded)
        searcher.search(ev, max_steps=cap)
        traces.append(ev.trace)
    first_done = max(tr[0][1] for tr in traces if tr)
    t_end = max(tr[-1][1] for tr in traces if tr)
    if time_grid is None:
        time_grid = np.linspace(first_done, t_end, 200)
    curves = np.empty((len(traces), time_grid.size))
    for i, tr in enumerate(traces):
        times = np.array([e for _, e, _ in tr])
        bests = np.minimum.accumulate(np.array([r for _, _, r in tr]))
        # best finished kernel at each grid time
        pos = np.searchsorted(times, time_grid, side="right") - 1
        pos = np.clip(pos, 0, len(bests) - 1)
        curves[i] = bests[pos]
        curves[i][time_grid < times[0]] = np.nan
    mean = np.nanmean(curves, axis=0)
    std = np.nanstd(curves, axis=0)
    return time_grid, mean, std


# =============================================================================
# High-level API: the framework feature
# =============================================================================
@dataclasses.dataclass
class TuneResult:
    best_config: Config
    best_runtime: float
    steps: int
    history: List[Tuple[int, float]]


def autotune(
    space: TuningSpace,
    workload_fn: Callable[[Config], Dict[str, float]],
    hw: HardwareSpec,
    model: Optional[TPPCModel] = None,
    train_hw: Optional[HardwareSpec] = None,
    budget: int = 60,
    model_kind: str = "tree",
    seed: int = 0,
    searcher_cls: type = ProfileBasedSearcher,
) -> TuneResult:
    """One-call autotuning: train (if no model given) then search.

    ``train_hw`` lets the model be built on different (virtual) hardware than
    the autotuning target — the paper's headline capability.
    """
    if model is None:
        rec_train = record_space(space, workload_fn, train_hw or hw)
        model = train_model_deliberate(rec_train, kind=model_kind, seed=seed)
    ev = CostModelEvaluator(space, workload_fn, hw)
    if searcher_cls is ProfileBasedSearcher:
        searcher = ProfileBasedSearcher(space, model, cores=hw.cores, seed=seed)
    else:
        searcher = searcher_cls(space, seed=seed)
    searcher.search(ev, max_steps=budget)
    assert ev.best_index is not None
    history = sorted((i, float(c.runtime)) for i, c in ev._cache.items())
    return TuneResult(
        best_config=space[ev.best_index],
        best_runtime=ev.best_runtime,
        steps=ev.steps,
        history=history,
    )
