"""Evaluators: configuration → (runtime, performance counters).

The paper's evaluation replays exhaustively recorded tuning spaces 1000x
instead of re-running kernels (§4.1).  ``RecordedSpace`` holds such a record;
``ReplayEvaluator`` serves it to searchers while accounting empirical-test
steps and simulated wall-clock (profiled runs are slower — §4.6).

``CostModelEvaluator`` produces records from a kernel workload model
(g: TP × I → PC_ops) executed on a virtual TPU (f: ... × GPU → runtime).
``FunctionEvaluator`` adapts any ``cfg -> seconds`` callable (runtime-only —
no counters, so only counter-free searchers can drive it).

All evaluators implement the shared ``repro.core.account.Evaluator``
protocol: ``measure`` / ``profile`` / ``measure_many`` / ``submit`` /
``collect`` plus the uniform ``EvalAccount`` bookkeeping (steps, elapsed,
busy, trace, history, best).  ``VirtualAsyncEvaluator`` wraps any of them
in a simulated ``workers``-lane concurrent backend (deterministic virtual
clock) — the reference implementation of the async half of the protocol.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import costmodel
from repro.core.account import (Candidate, Evaluator, Observation,
                                ProfilingUnsupported, Ticket)
from repro.core.counters import CounterSet
from repro.core.hwspec import HardwareSpec
from repro.core.tuning_space import Config, TuningSpace

# Empirical-test cost structure (seconds), mirroring §4.6 observations:
# every test pays compile+launch+data overhead; profiled tests additionally
# re-run the kernel per counter group (CUPTI-style multi-pass ≈ 4x slowdown).
TEST_OVERHEAD = 0.02
PROFILE_SLOWDOWN = 4.0
PROFILE_FIXED = 0.08


@dataclasses.dataclass
class RecordedSpace:
    """Exhaustive (runtime, counters) record of one space on one hardware."""

    space: TuningSpace
    runtimes: np.ndarray
    counters: List[CounterSet]
    hw: HardwareSpec
    input_tag: str = ""

    @property
    def best_runtime(self) -> float:
        return float(self.runtimes.min())

    def well_performing_mask(self, factor: float = 1.1) -> np.ndarray:
        """Configs within ``factor`` of the best runtime (paper §4.1)."""
        return self.runtimes <= factor * self.best_runtime

    def ops_list(self) -> List[Dict[str, float]]:
        return [cs.ops for cs in self.counters]


def record_space(
    space: TuningSpace,
    workload_fn: Callable[[Config], Dict[str, float]],
    hw: HardwareSpec,
    input_tag: str = "",
) -> RecordedSpace:
    """Exhaustively evaluate a space on a virtual TPU via the cost model."""
    counters: List[CounterSet] = []
    runtimes = np.empty(len(space), dtype=np.float64)
    for i, cfg in enumerate(space):
        cs = costmodel.execute(workload_fn(cfg), hw)
        counters.append(cs)
        runtimes[i] = cs.runtime
    return RecordedSpace(space=space, runtimes=runtimes, counters=counters,
                         hw=hw, input_tag=input_tag)


class ReplayEvaluator(Evaluator):
    """Serves a RecordedSpace to a searcher; accounts steps and time.

    ``steps``  — number of empirical tests (paper's primary metric)
    ``elapsed`` — simulated tuning wall-clock (runtime + overheads)
    ``trace``  — (steps, elapsed, runtime) per test, for convergence curves
    """

    def __init__(self, recorded: RecordedSpace):
        super().__init__(recorded.space)
        self.recorded = recorded

    def _evaluate(
        self, idx: int, profiled: bool
    ) -> Tuple[float, Optional[CounterSet], float]:
        rt = float(self.recorded.runtimes[idx])
        if profiled:
            cost = rt * PROFILE_SLOWDOWN + TEST_OVERHEAD + PROFILE_FIXED
            return rt, self.recorded.counters[idx], cost
        return rt, None, rt + TEST_OVERHEAD


class CostModelEvaluator(Evaluator):
    """Live evaluator: workload model + virtual hardware (no record needed)."""

    def __init__(
        self,
        space: TuningSpace,
        workload_fn: Callable[[Config], Dict[str, float]],
        hw: HardwareSpec,
    ):
        super().__init__(space)
        self.workload_fn = workload_fn
        self.hw = hw
        self._cache: Dict[int, CounterSet] = {}

    def _counters_for(self, idx: int) -> CounterSet:
        if idx not in self._cache:
            self._cache[idx] = costmodel.execute(
                self.workload_fn(self.space[idx]), self.hw
            )
        return self._cache[idx]

    def _evaluate(
        self, idx: int, profiled: bool
    ) -> Tuple[float, Optional[CounterSet], float]:
        cs = self._counters_for(idx)
        rt = float(cs.runtime)
        if profiled:
            cost = rt * PROFILE_SLOWDOWN + TEST_OVERHEAD + PROFILE_FIXED
            return rt, cs, cost
        return rt, None, rt + TEST_OVERHEAD


class FunctionEvaluator(Evaluator):
    """Adapts a plain ``cfg -> runtime_seconds`` callable to the protocol.

    Used to tune things with no counter story (e.g. serving batch sizes):
    ``profile`` raises ``ProfilingUnsupported``, so drive it with
    counter-free searchers (random, basin hopping, starchart, warm_start).

    Cost model: ``elapsed`` accounts seconds actually spent in ``fn``.  With
    ``cache=True`` (default) the first measurement of a config runs ``fn``
    and charges its runtime; re-measurements of the same config are served
    from the memo and charge **zero** additional elapsed — ``fn`` never
    re-ran, so billing it again would overstate tuning cost.  This differs
    from ``ReplayEvaluator`` deliberately: replay's clock is *simulated* and
    charges every empirical test because each one stands in for a real
    kernel launch.  Pass ``cache=False`` to genuinely re-run ``fn`` per
    measurement (e.g. noisy live timings that should be re-sampled); each
    test then pays its own cost, matching replay's re-measure semantics.
    Steps/trace/history count every measurement in both modes.
    """

    def __init__(self, space: TuningSpace,
                 fn: Callable[[Config], float],
                 cache: bool = True):
        super().__init__(space)
        self.fn = fn
        self.cache = cache
        self._cache: Dict[int, float] = {}

    def _evaluate(
        self, idx: int, profiled: bool
    ) -> Tuple[float, Optional[CounterSet], float]:
        if not self.cache:
            rt = float(self.fn(self.space[idx]))
            return rt, None, rt
        if idx in self._cache:
            return self._cache[idx], None, 0.0  # memo hit: fn did not re-run
        rt = float(self.fn(self.space[idx]))
        self._cache[idx] = rt
        return rt, None, rt


class ElasticInFlight:
    """Backpressure-driven target for outstanding empirical tests.

    The fleet drivers historically held ``in_flight`` constant; this
    controller grows or shrinks the target between ``[lo, hi]`` from two
    observable signals:

    * **lane utilization** — the baseline target is the number of live
      lanes (fewer outstanding tests than lanes guarantees idle workers;
      queueing much deeper than the lanes only adds latency to feedback);
    * **measurement variance** — the coefficient of variation over a
      rolling window of per-test durations.  High variance means lanes
      free up unevenly, so a deeper queue is needed to keep the fast
      lanes from idling while a straggler holds its lane; near-constant
      durations need no queue beyond the lanes themselves.

    ``target(workers)`` = clamp(workers + ceil(cv · workers), lo, hi) —
    deterministic given the observation sequence, so elastic runs stay
    bit-reproducible on the virtual backends.  With ``lo == hi`` the
    controller degenerates to the fixed policy.
    """

    def __init__(self, lo: int, hi: int, window: int = 16):
        if lo < 1 or hi < lo:
            raise ValueError(f"need 1 <= lo <= hi, got [{lo}, {hi}]")
        self.lo, self.hi = int(lo), int(hi)
        self._window = int(window)
        self._samples: List[float] = []

    def observe(self, duration: float) -> None:
        """Feed one per-test duration (cost or runtime) into the window."""
        if duration > 0.0 and np.isfinite(duration):
            self._samples.append(float(duration))
            if len(self._samples) > self._window:
                self._samples.pop(0)

    def cv(self) -> float:
        """Coefficient of variation over the current window (0 until two
        samples exist)."""
        if len(self._samples) < 2:
            return 0.0
        arr = np.asarray(self._samples)
        mean = float(arr.mean())
        if mean <= 0.0:
            return 0.0
        return float(arr.std() / mean)

    def target(self, workers: int) -> int:
        extra = int(np.ceil(self.cv() * max(1, int(workers))))
        return max(self.lo, min(self.hi, int(workers) + extra))


class VirtualAsyncEvaluator(Evaluator):
    """Simulated ``workers``-lane concurrency over any inner evaluator.

    ``submit`` dispatches each candidate to the earliest-free virtual
    worker; ``collect`` returns the earliest-*finishing* outstanding test,
    so completions come back out of submission order exactly as they would
    from a real device pool (a cheap config submitted after an expensive one
    finishes first).  Accounting goes through
    ``EvalAccount.record_completion``: the trace is ordered by completion
    time, ``elapsed`` is the completion frontier (wall-clock of a
    ``workers``-wide fleet), and ``busy`` is the familiar sum of per-test
    costs — with ``workers=1`` the two coincide and the behaviour degrades
    to the sequential evaluator's.

    The inner evaluator is used only for its pure ``_evaluate`` hook (all
    bookkeeping lives on THIS account); it must not be driven concurrently
    elsewhere.
    """

    def __init__(self, inner: Evaluator, workers: int = 4):
        super().__init__(inner.space)
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.inner = inner
        self.workers = int(workers)
        self._free = [0.0] * self.workers    # per-worker next-free time
        self._now = 0.0                      # time of the last collection
        self._heap: List[Tuple[float, int, Candidate, float,
                               Optional[CounterSet], float]] = []
        self._seq = 0

    def _evaluate(self, idx: int, profiled: bool
                  ) -> Tuple[float, Optional[CounterSet], float]:
        return self.inner._evaluate(idx, profiled)

    def submit(self, candidates: Sequence[Union[Candidate, int]]
               ) -> List[Ticket]:
        tickets = []
        for c in candidates:
            if not isinstance(c, Candidate):
                c = Candidate(int(c))
            rt, cs, cost = self.inner._evaluate(c.index, c.profile)
            if c.profile and cs is None:
                raise ProfilingUnsupported(
                    f"{type(self.inner).__name__} cannot collect "
                    "performance counters")
            w = min(range(self.workers), key=lambda i: self._free[i])
            start = max(self._now, self._free[w])
            finish = start + cost
            self._free[w] = finish
            heapq.heappush(self._heap, (finish, self._seq, c, rt, cs, cost))
            tickets.append(Ticket(uid=self._seq, candidate=c))
            self._seq += 1
        return tickets

    def collect(self, timeout: Optional[float] = None) -> List[Observation]:
        """Pop the earliest-finishing outstanding test ([] if none)."""
        if not self._heap:
            return []
        finish, _, c, rt, cs, cost = heapq.heappop(self._heap)
        self._now = max(self._now, finish)
        self.account.record_completion(c.index, rt, cost, finish)
        return [Observation(index=c.index, runtime=rt, counters=cs,
                            step=self.steps, elapsed=self.elapsed)]

    def outstanding(self) -> int:
        return len(self._heap)
