"""Evaluators: configuration → (runtime, performance counters).

The paper's evaluation replays exhaustively recorded tuning spaces 1000x
instead of re-running kernels (§4.1).  ``RecordedSpace`` holds such a record;
``ReplayEvaluator`` serves it to searchers while accounting empirical-test
steps and simulated wall-clock (profiled runs are slower — §4.6).

``CostModelEvaluator`` produces records from a kernel workload model
(g: TP × I → PC_ops) executed on a virtual TPU (f: ... × GPU → runtime).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core.counters import CounterSet
from repro.core.hwspec import HardwareSpec
from repro.core.tuning_space import Config, TuningSpace

# Empirical-test cost structure (seconds), mirroring §4.6 observations:
# every test pays compile+launch+data overhead; profiled tests additionally
# re-run the kernel per counter group (CUPTI-style multi-pass ≈ 4x slowdown).
TEST_OVERHEAD = 0.02
PROFILE_SLOWDOWN = 4.0
PROFILE_FIXED = 0.08


@dataclasses.dataclass
class RecordedSpace:
    """Exhaustive (runtime, counters) record of one space on one hardware."""

    space: TuningSpace
    runtimes: np.ndarray
    counters: List[CounterSet]
    hw: HardwareSpec
    input_tag: str = ""

    @property
    def best_runtime(self) -> float:
        return float(self.runtimes.min())

    def well_performing_mask(self, factor: float = 1.1) -> np.ndarray:
        """Configs within ``factor`` of the best runtime (paper §4.1)."""
        return self.runtimes <= factor * self.best_runtime

    def ops_list(self) -> List[Dict[str, float]]:
        return [cs.ops for cs in self.counters]


def record_space(
    space: TuningSpace,
    workload_fn: Callable[[Config], Dict[str, float]],
    hw: HardwareSpec,
    input_tag: str = "",
) -> RecordedSpace:
    """Exhaustively evaluate a space on a virtual TPU via the cost model."""
    counters: List[CounterSet] = []
    runtimes = np.empty(len(space), dtype=np.float64)
    for i, cfg in enumerate(space):
        cs = costmodel.execute(workload_fn(cfg), hw)
        counters.append(cs)
        runtimes[i] = cs.runtime
    return RecordedSpace(space=space, runtimes=runtimes, counters=counters,
                         hw=hw, input_tag=input_tag)


class ReplayEvaluator:
    """Serves a RecordedSpace to a searcher; accounts steps and time.

    ``steps``  — number of empirical tests (paper's primary metric)
    ``elapsed`` — simulated tuning wall-clock (runtime + overheads)
    ``trace``  — (steps, elapsed, runtime) per test, for convergence curves
    """

    def __init__(self, recorded: RecordedSpace):
        self.recorded = recorded
        self.steps = 0
        self.elapsed = 0.0
        self.trace: List[Tuple[int, float, float]] = []
        self.evaluated: set = set()
        self.best_runtime = float("inf")
        self.best_index: Optional[int] = None

    def __len__(self) -> int:
        return len(self.recorded.space)

    @property
    def space(self) -> TuningSpace:
        return self.recorded.space

    def _account(self, idx: int, cost: float) -> float:
        rt = float(self.recorded.runtimes[idx])
        self.steps += 1
        self.elapsed += cost
        self.evaluated.add(idx)
        if rt < self.best_runtime:
            self.best_runtime = rt
            self.best_index = idx
        self.trace.append((self.steps, self.elapsed, rt))
        return rt

    def measure(self, idx: int) -> float:
        """Empirical test without counter collection (fast)."""
        rt = float(self.recorded.runtimes[idx])
        return self._account(idx, rt + TEST_OVERHEAD)

    def profile(self, idx: int) -> CounterSet:
        """Empirical test with counter collection (slow: multi-pass replay)."""
        rt = float(self.recorded.runtimes[idx])
        self._account(idx, rt * PROFILE_SLOWDOWN + TEST_OVERHEAD + PROFILE_FIXED)
        return self.recorded.counters[idx]

    def exhausted(self) -> bool:
        return len(self.evaluated) >= len(self.recorded.space)


class CostModelEvaluator:
    """Live evaluator: workload model + virtual hardware (no record needed)."""

    def __init__(
        self,
        space: TuningSpace,
        workload_fn: Callable[[Config], Dict[str, float]],
        hw: HardwareSpec,
    ):
        self.space = space
        self.workload_fn = workload_fn
        self.hw = hw
        self.steps = 0
        self.evaluated: set = set()
        self.best_runtime = float("inf")
        self.best_index: Optional[int] = None
        self._cache: Dict[int, CounterSet] = {}

    def __len__(self) -> int:
        return len(self.space)

    def _eval(self, idx: int) -> CounterSet:
        if idx not in self._cache:
            self._cache[idx] = costmodel.execute(
                self.workload_fn(self.space[idx]), self.hw
            )
        cs = self._cache[idx]
        self.steps += 1
        self.evaluated.add(idx)
        if cs.runtime < self.best_runtime:
            self.best_runtime = cs.runtime
            self.best_index = idx
        return cs

    def measure(self, idx: int) -> float:
        return self._eval(idx).runtime

    def profile(self, idx: int) -> CounterSet:
        return self._eval(idx)

    def exhausted(self) -> bool:
        return len(self.evaluated) >= len(self.space)
