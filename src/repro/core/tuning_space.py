"""Generic tuning spaces (paper §1, §3) — array-backed.

A *tuning parameter* (TP) takes one of a pre-defined set of discrete values.
The cross product of TPs, pruned by user constraints, forms the *tuning space*;
one element is a *tuning configuration*.  The searcher is agnostic to what the
parameters mean — they may tune Pallas block sizes, sharding layouts, remat
policies or anything else (the paper's central genericity claim).

The space is the unit the searcher re-scores at EVERY profiling step
(Algorithm 1 l.7), and paper benchmarks reach 205,216 configurations, so the
space materializes its numeric representation once at construction:

* ``feature_matrix`` — ``n_configs × n_params`` float64, the vectorized form
  every TP→PC model consumes (one row == ``vectorize(config)``);
* a hash index making ``index_of`` O(1) instead of a full scan;
* ``subspace_key_matrix`` / ``subspace_keys`` — per-config binary-subspace
  keys (§3.4.1), precomputed for the quadratic model's per-subspace matmuls.

``neighbours`` uses per-parameter-slot hashing (configs sharing all values
except one slot land in the same bucket), built lazily in O(n·p) — the old
per-query O(n²) full scan made Basin Hopping's local phase quadratic.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import numpy as np

Config = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TuningParameter:
    """One discrete tuning parameter."""

    name: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        if len(self.values) == 0:
            raise ValueError(f"parameter {self.name!r} has no values")
        object.__setattr__(self, "values", tuple(self.values))

    @property
    def is_binary(self) -> bool:
        """Binary TPs split the space into model subspaces (paper §3.4.1)."""
        try:
            return set(self.values) <= {0, 1, True, False}
        except TypeError:  # unhashable values (tuples-as-lists from JSON, ...)
            return False

    def encode(self, v: Any) -> float:
        """Numeric feature code of one value.

        Strings — and any other value ``float()`` cannot convert (tuples,
        enums, ...; the space is generic over what a parameter means) —
        encode as their declared index."""
        if isinstance(v, bool):
            return float(int(v))
        if isinstance(v, str):
            return float(self.values.index(v))
        try:
            return float(v)
        except (TypeError, ValueError):
            return float(self.values.index(v))


def _all_hashable(values: Sequence[Any]) -> bool:
    try:
        set(values)
        return True
    except TypeError:
        return False


def _encode_column(p: TuningParameter, cfgs: Sequence[Config]) -> List[float]:
    """Feature codes of one parameter across configs (dict fast path when
    the values are hashable, per-value ``encode`` otherwise)."""
    try:
        code = {v: p.encode(v) for v in p.values}
        # .encode fallback: configs from ANOTHER space may carry values
        # outside this parameter's declared list (cross-space prediction)
        return [
            code[v] if v in code else p.encode(v)
            for v in (c[p.name] for c in cfgs)
        ]
    except TypeError:  # unhashable values (e.g. JSON round-tripped tuples)
        return [p.encode(c[p.name]) for c in cfgs]


class TuningSpace:
    """Cross product of tuning parameters pruned by constraints.

    Constraints are predicates over a full configuration dict.  The space is
    materialized eagerly — configs as dicts (the searcher/evaluator API) and
    as a dense ``feature_matrix`` (the model/scoring API).
    """

    def __init__(
        self,
        parameters: Sequence[TuningParameter],
        constraints: Sequence[Callable[[Config], bool]] = (),
        name: str = "space",
    ):
        names = [p.name for p in parameters]
        if len(set(names)) != len(names):
            raise ValueError("duplicate parameter names")
        self.name = name
        self.parameters: Tuple[TuningParameter, ...] = tuple(parameters)
        self.constraints = tuple(constraints)
        self._configs: List[Config] = [
            cfg
            for cfg in self._iter_cross_product()
            if all(c(cfg) for c in self.constraints)
        ]
        if not self._configs:
            raise ValueError(f"tuning space {name!r} is empty after constraints")
        # dense numeric form, one row per config (== vectorize(config))
        fm = np.empty((len(self._configs), len(self.parameters)),
                      dtype=np.float64)
        for j, p in enumerate(self.parameters):
            fm[:, j] = _encode_column(p, self._configs)
        fm.setflags(write=False)
        self._feature_matrix = fm
        # O(1) config -> index.  Keys are the RAW value tuples (exact
        # pre-hash-index equality semantics — feature encodings are not
        # injective when a parameter mixes strings and numerics); a
        # parameter whose values are unhashable (e.g. tuples deserialized
        # from JSON as lists) falls back to declared-index keys, which are
        # injective over its value list.
        self._hashable_values: Tuple[bool, ...] = tuple(
            _all_hashable(p.values) for p in self.parameters)
        self._index: Dict[Tuple[Any, ...], int] = {
            self._key_of(cfg): i for i, cfg in enumerate(self._configs)
        }
        # per-config binary-subspace keys (§3.4.1)
        bin_cols = [j for j, p in enumerate(self.parameters) if p.is_binary]
        skm = fm[:, bin_cols].astype(np.int64)
        skm.setflags(write=False)
        self._subspace_key_matrix = skm
        # slot-hash buckets for neighbours(); built lazily on first use
        self._slot_buckets: Optional[List[Dict[Tuple, List[int]]]] = None

    # -- basic container protocol ------------------------------------------------
    def _iter_cross_product(self) -> Iterator[Config]:
        names = [p.name for p in self.parameters]
        for combo in itertools.product(*(p.values for p in self.parameters)):
            yield dict(zip(names, combo))

    def __len__(self) -> int:
        return len(self._configs)

    def __getitem__(self, i: int) -> Config:
        return self._configs[i]

    def __iter__(self) -> Iterator[Config]:
        return iter(self._configs)

    @property
    def configs(self) -> List[Config]:
        return self._configs

    def _key_of(self, cfg: Config) -> Tuple[Any, ...]:
        """Hashable index key of a config: raw values, with declared-index
        fallback for parameters whose values are unhashable."""
        return tuple(
            cfg[p.name] if hashable else p.values.index(cfg[p.name])
            for p, hashable in zip(self.parameters, self._hashable_values)
        )

    def index_of(self, cfg: Config) -> int:
        if len(cfg) == len(self.parameters):
            try:
                i = self._index.get(self._key_of(cfg))
            except (KeyError, TypeError, ValueError):
                i = None  # missing parameter / unhashable / undeclared value
            # equality check: belt and braces for the declared-index
            # fallback path (an out-of-space value equal-comparing to a
            # declared one must not alias a different config)
            if i is not None and self._configs[i] == cfg:
                return i
        raise KeyError(f"config not in space: {cfg}")

    # -- structure queries used by the models (§3.4) ------------------------------
    @property
    def binary_parameters(self) -> List[TuningParameter]:
        return [p for p in self.parameters if p.is_binary]

    @property
    def nonbinary_parameters(self) -> List[TuningParameter]:
        return [p for p in self.parameters if not p.is_binary]

    @property
    def feature_matrix(self) -> np.ndarray:
        """``n_configs × n_params`` float64; row i == ``vectorize(self[i])``.

        Read-only: built once at construction and shared by every model.
        """
        return self._feature_matrix

    def vectorize(self, cfg: Config) -> List[float]:
        """Numeric feature vector in declared parameter order."""
        return [p.encode(cfg[p.name]) for p in self.parameters]

    def vectorize_configs(self, cfgs: Sequence[Config]) -> np.ndarray:
        """Batch ``vectorize``: ``len(cfgs) × n_params`` float64 matrix."""
        out = np.empty((len(cfgs), len(self.parameters)), dtype=np.float64)
        for j, p in enumerate(self.parameters):
            out[:, j] = _encode_column(p, cfgs)
        return out

    # -- neighbourhood structure (Basin Hopping §4.7, profile_local §3.9.1) -------
    def _buckets(self) -> List[Dict[Tuple, List[int]]]:
        if self._slot_buckets is None:
            n_slots = len(self.parameters)
            buckets: List[Dict[Tuple, List[int]]] = [
                {} for _ in range(n_slots)
            ]
            for i, cfg in enumerate(self._configs):
                key = self._key_of(cfg)
                for f in range(n_slots):
                    reduced = key[:f] + key[f + 1:]
                    buckets[f].setdefault(reduced, []).append(i)
            self._slot_buckets = buckets
        return self._slot_buckets

    def neighbours(self, idx: int) -> List[int]:
        """Indices of configs differing in exactly one parameter value.

        Used by the local phase of Basin Hopping (§4.7) — Kernel Tuner's
        greedy-ils neighbourhood.  Per-slot hashing: a neighbour differing
        only in slot f shares slot-f's reduced key with ``idx``, so each
        neighbour is found exactly once; total index build is O(n·p).
        """
        key = self._key_of(self._configs[idx])
        out: List[int] = []
        for f, bucket in enumerate(self._buckets()):
            out.extend(j for j in bucket[key[:f] + key[f + 1:]] if j != idx)
        out.sort()
        return out

    # -- binary-subspace structure (§3.4.1) ---------------------------------------
    @property
    def subspace_key_matrix(self) -> np.ndarray:
        """``n_configs × n_binary_params`` int64 key matrix (read-only)."""
        return self._subspace_key_matrix

    def subspace_key(self, cfg: Config) -> Tuple[Any, ...]:
        """Key identifying the binary-parameter subspace of cfg (§3.4.1)."""
        return tuple(int(bool(cfg[p.name])) for p in self.binary_parameters)

    def subspace_keys(self) -> List[Tuple[int, ...]]:
        """Per-config subspace keys, index-aligned with the space."""
        return [tuple(row) for row in self._subspace_key_matrix.tolist()]


def powers_of_two(lo: int, hi: int) -> Tuple[int, ...]:
    out = []
    v = lo
    while v <= hi:
        out.append(v)
        v *= 2
    return tuple(out)
