"""Generic tuning spaces (paper §1, §3).

A *tuning parameter* (TP) takes one of a pre-defined set of discrete values.
The cross product of TPs, pruned by user constraints, forms the *tuning space*;
one element is a *tuning configuration*.  The searcher is agnostic to what the
parameters mean — they may tune Pallas block sizes, sharding layouts, remat
policies or anything else (the paper's central genericity claim).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

Config = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TuningParameter:
    """One discrete tuning parameter."""

    name: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        if len(self.values) == 0:
            raise ValueError(f"parameter {self.name!r} has no values")
        object.__setattr__(self, "values", tuple(self.values))

    @property
    def is_binary(self) -> bool:
        """Binary TPs split the space into model subspaces (paper §3.4.1)."""
        return set(self.values) <= {0, 1, True, False}


class TuningSpace:
    """Cross product of tuning parameters pruned by constraints.

    Constraints are predicates over a full configuration dict.  The space is
    materialized eagerly (paper benchmarks range from 210 to 205,216 configs;
    the searcher scores the whole space each profiling step, Algorithm 1 l.7).
    """

    def __init__(
        self,
        parameters: Sequence[TuningParameter],
        constraints: Sequence[Callable[[Config], bool]] = (),
        name: str = "space",
    ):
        names = [p.name for p in parameters]
        if len(set(names)) != len(names):
            raise ValueError("duplicate parameter names")
        self.name = name
        self.parameters: Tuple[TuningParameter, ...] = tuple(parameters)
        self.constraints = tuple(constraints)
        self._configs: List[Config] = [
            cfg
            for cfg in self._iter_cross_product()
            if all(c(cfg) for c in self.constraints)
        ]
        if not self._configs:
            raise ValueError(f"tuning space {name!r} is empty after constraints")

    # -- basic container protocol ------------------------------------------------
    def _iter_cross_product(self) -> Iterator[Config]:
        names = [p.name for p in self.parameters]
        for combo in itertools.product(*(p.values for p in self.parameters)):
            yield dict(zip(names, combo))

    def __len__(self) -> int:
        return len(self._configs)

    def __getitem__(self, i: int) -> Config:
        return self._configs[i]

    def __iter__(self) -> Iterator[Config]:
        return iter(self._configs)

    @property
    def configs(self) -> List[Config]:
        return self._configs

    def index_of(self, cfg: Config) -> int:
        for i, c in enumerate(self._configs):
            if c == cfg:
                return i
        raise KeyError(f"config not in space: {cfg}")

    # -- structure queries used by the models (§3.4) ------------------------------
    @property
    def binary_parameters(self) -> List[TuningParameter]:
        return [p for p in self.parameters if p.is_binary]

    @property
    def nonbinary_parameters(self) -> List[TuningParameter]:
        return [p for p in self.parameters if not p.is_binary]

    def vectorize(self, cfg: Config) -> List[float]:
        """Numeric feature vector in declared parameter order."""
        out = []
        for p in self.parameters:
            v = cfg[p.name]
            if isinstance(v, bool):
                v = int(v)
            if isinstance(v, str):
                v = float(p.values.index(cfg[p.name]))
            out.append(float(v))
        return out

    def neighbours(self, idx: int) -> List[int]:
        """Indices of configs differing in exactly one parameter value.

        Used by the local phase of Basin Hopping (§4.7) — Kernel Tuner's
        greedy-ils neighbourhood.
        """
        base = self._configs[idx]
        out = []
        for j, cfg in enumerate(self._configs):
            if j == idx:
                continue
            diff = sum(1 for k in base if base[k] != cfg[k])
            if diff == 1:
                out.append(j)
        return out

    def subspace_key(self, cfg: Config) -> Tuple[Any, ...]:
        """Key identifying the binary-parameter subspace of cfg (§3.4.1)."""
        return tuple(int(bool(cfg[p.name])) for p in self.binary_parameters)


def powers_of_two(lo: int, hi: int) -> Tuple[int, ...]:
    out = []
    v = lo
    while v <= hi:
        out.append(v)
        v *= 2
    return tuple(out)
