"""Models of the TP → PC_ops relation (paper §3.4).

Two model families, both implemented from scratch on numpy:

* ``DecisionTreeModel`` (§3.4.2): regression trees built top-down greedily
  (ID3-style with Standard Deviation Reduction == MSE split criterion).  A
  candidate set of trees with varying structural hyperparameters is trained on
  a random 50% of the explored space, evaluated on the other 50%, and the tree
  with the lowest MAE (ties broken by RMSE) is selected — per counter.

* ``QuadraticRegressionModel`` (§3.4.1): per binary-parameter subspace,
  least-squares fit over main effects, pairwise interactions and quadratic
  terms of the non-binary parameters.  Training points are sampled
  deliberately: 2-3 values per non-binary parameter.

Models are trained once (on any hardware/input — the portability thesis) and
predict all PC_ops counters for unseen configurations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import counters as C
from repro.core.tuning_space import TuningSpace

# Counters the models learn (the portable PC_ops set).  GRID and VMEM_WS are
# included: they are statically known, making the model's job easy for them —
# the paper likewise feeds thread counts through the model path.
MODELED_COUNTERS: Tuple[str, ...] = C.PC_OPS


class TPPCModel:
    """Interface: predict PC_ops for a configuration index / dict."""

    def predict(self, cfg: Dict) -> Dict[str, float]:
        raise NotImplementedError

    def predict_many(self, cfgs: Sequence[Dict]) -> List[Dict[str, float]]:
        return [self.predict(c) for c in cfgs]


# =============================================================================
# Decision tree regression (from scratch)
# =============================================================================
@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    value: float = 0.0

    @property
    def is_leaf(self) -> bool:
        return self.left is None


def _build_tree(
    X: np.ndarray,
    y: np.ndarray,
    depth: int,
    max_depth: int,
    min_samples: int,
) -> _Node:
    node = _Node(value=float(y.mean()) if y.size else 0.0)
    if depth >= max_depth or y.size < 2 * min_samples or np.all(y == y[0]):
        return node
    best = None  # (sse, feature, threshold)
    base_sse = float(((y - y.mean()) ** 2).sum())
    for f in range(X.shape[1]):
        vals = np.unique(X[:, f])
        if vals.size < 2:
            continue
        # candidate thresholds between consecutive values
        for t in (vals[:-1] + vals[1:]) / 2.0:
            lm = X[:, f] <= t
            nl = int(lm.sum())
            if nl < min_samples or y.size - nl < min_samples:
                continue
            yl, yr = y[lm], y[~lm]
            sse = float(((yl - yl.mean()) ** 2).sum() + ((yr - yr.mean()) ** 2).sum())
            if best is None or sse < best[0]:
                best = (sse, f, float(t))
    if best is None or best[0] >= base_sse - 1e-12:
        return node
    _, f, t = best
    lm = X[:, f] <= t
    node.feature, node.threshold = f, t
    node.left = _build_tree(X[lm], y[lm], depth + 1, max_depth, min_samples)
    node.right = _build_tree(X[~lm], y[~lm], depth + 1, max_depth, min_samples)
    return node


def _tree_predict(node: _Node, x: np.ndarray) -> float:
    while not node.is_leaf:
        node = node.left if x[node.feature] <= node.threshold else node.right
    return node.value


# Candidate structural hyperparameters ("we also alter parent nodes" §3.4.2).
_TREE_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (4, 2), (6, 2), (8, 1), (10, 1), (12, 1), (16, 1),
)


class DecisionTreeModel(TPPCModel):
    """One selected regression tree per PC_ops counter (§3.4.2)."""

    def __init__(
        self,
        space: TuningSpace,
        cfgs: Sequence[Dict],
        counters: Sequence[Dict[str, float]],
        rng: Optional[np.random.Generator] = None,
        counters_to_model: Sequence[str] = MODELED_COUNTERS,
    ):
        rng = rng or np.random.default_rng(0)
        self.space = space
        X = np.array([space.vectorize(c) for c in cfgs], dtype=np.float64)
        n = X.shape[0]
        self.trees: Dict[str, _Node] = {}
        self.scale: Dict[str, float] = {}
        perm = rng.permutation(n)
        half = max(1, n // 2)
        tr, te = perm[:half], perm[half:]
        if te.size == 0:
            te = tr
        for name in counters_to_model:
            y = np.array([float(cs.get(name, 0.0)) for cs in counters])
            # scale to O(1) for numerically comparable MAE across counters
            scale = float(np.abs(y).max()) or 1.0
            ys = y / scale
            best = None  # (mae, rmse, tree)
            for max_depth, min_samples in _TREE_CANDIDATES:
                tree = _build_tree(X[tr], ys[tr], 0, max_depth, min_samples)
                pred = np.array([_tree_predict(tree, x) for x in X[te]])
                err = pred - ys[te]
                mae = float(np.abs(err).mean())
                rmse = float(np.sqrt((err**2).mean()))
                if best is None or (mae, rmse) < (best[0], best[1]):
                    best = (mae, rmse, tree)
            self.trees[name] = best[2]
            self.scale[name] = scale

    def predict(self, cfg: Dict) -> Dict[str, float]:
        x = np.asarray(self.space.vectorize(cfg), dtype=np.float64)
        return {
            name: _tree_predict(tree, x) * self.scale[name]
            for name, tree in self.trees.items()
        }

    @classmethod
    def from_state(
        cls, space: TuningSpace, trees: Dict[str, _Node],
        scale: Dict[str, float],
    ) -> "DecisionTreeModel":
        """Rebuild a trained model from serialized state (no re-training)."""
        obj = cls.__new__(cls)
        obj.space = space
        obj.trees = trees
        obj.scale = scale
        return obj


# =============================================================================
# Least-squares quadratic regression per binary subspace (§3.4.1)
# =============================================================================
def _poly_features(v: np.ndarray) -> np.ndarray:
    """[1, x_i, x_i^2, x_i*x_j] feature expansion."""
    feats = [1.0]
    k = v.size
    feats.extend(v.tolist())
    feats.extend((v**2).tolist())
    for i in range(k):
        for j in range(i + 1, k):
            feats.append(v[i] * v[j])
    return np.asarray(feats)


class QuadraticRegressionModel(TPPCModel):
    """Least-squares non-linear regression per binary subspace (§3.4.1)."""

    def __init__(
        self,
        space: TuningSpace,
        cfgs: Sequence[Dict],
        counters: Sequence[Dict[str, float]],
        counters_to_model: Sequence[str] = MODELED_COUNTERS,
    ):
        self.space = space
        self.counter_names = tuple(counters_to_model)
        nb = space.nonbinary_parameters
        self._nb_names = [p.name for p in nb]
        # group samples by binary subspace
        groups: Dict[Tuple, List[int]] = {}
        for i, cfg in enumerate(cfgs):
            groups.setdefault(space.subspace_key(cfg), []).append(i)
        self.coefs: Dict[Tuple, Dict[str, np.ndarray]] = {}
        self._fallback: Dict[str, float] = {
            name: float(
                np.mean([cs.get(name, 0.0) for cs in counters]) if counters else 0.0
            )
            for name in counters_to_model
        }
        for key, idxs in groups.items():
            Xf = np.stack(
                [_poly_features(self._nb_vector(cfgs[i])) for i in idxs]
            )
            per_counter: Dict[str, np.ndarray] = {}
            for name in counters_to_model:
                y = np.array([float(counters[i].get(name, 0.0)) for i in idxs])
                coef, *_ = np.linalg.lstsq(Xf, y, rcond=None)
                per_counter[name] = coef
            self.coefs[key] = per_counter

    def _nb_vector(self, cfg: Dict) -> np.ndarray:
        full = dict(zip([p.name for p in self.space.parameters],
                        self.space.vectorize(cfg)))
        return np.asarray([full[n] for n in self._nb_names], dtype=np.float64)

    def predict(self, cfg: Dict) -> Dict[str, float]:
        key = self.space.subspace_key(cfg)
        if key not in self.coefs:
            return dict(self._fallback)
        feats = _poly_features(self._nb_vector(cfg))
        return {
            name: float(feats @ coef)
            for name, coef in self.coefs[key].items()
        }

    @classmethod
    def from_state(
        cls,
        space: TuningSpace,
        counter_names: Sequence[str],
        coefs: Dict[Tuple, Dict[str, np.ndarray]],
        fallback: Dict[str, float],
    ) -> "QuadraticRegressionModel":
        """Rebuild a trained model from serialized state (no re-fitting)."""
        obj = cls.__new__(cls)
        obj.space = space
        obj.counter_names = tuple(counter_names)
        obj._nb_names = [p.name for p in space.nonbinary_parameters]
        obj.coefs = coefs
        obj._fallback = dict(fallback)
        return obj


# =============================================================================
# Exact "model": reads recorded counters (paper §4.3 — eliminates model error)
# =============================================================================
class ExactCounterModel(TPPCModel):
    """Replays exhaustively-measured PC_ops (no ML prediction error)."""

    def __init__(self, space: TuningSpace, counters: Sequence[Dict[str, float]]):
        self.space = space
        self._by_index = [dict(cs) for cs in counters]
        self._index: Optional[Dict[Tuple, int]] = None

    def predict(self, cfg: Dict) -> Dict[str, float]:
        if self._index is not None:
            return self._by_index[self._index[tuple(sorted(cfg.items()))]]
        return self._by_index[self.space.index_of(cfg)]

    def predict_index(self, idx: int) -> Dict[str, float]:
        if self._index is not None:
            # from_pairs remap: the bound space may enumerate configs in a
            # different order than the serialized counters list
            return self.predict(self.space[idx])
        return self._by_index[idx]

    @classmethod
    def from_pairs(
        cls, space: TuningSpace, configs: Sequence[Dict],
        counters: Sequence[Dict[str, float]],
    ) -> "ExactCounterModel":
        """Rebuild from explicit (config, counters) pairs — robust to the
        deserialized space enumerating configs in a different order."""
        obj = cls(space, counters)
        obj._index = {tuple(sorted(c.items())): i
                      for i, c in enumerate(configs)}
        return obj


def deliberate_training_sample(
    space: TuningSpace, values_per_param: int = 3,
    rng: Optional[np.random.Generator] = None,
) -> List[int]:
    """§3.4.1 sampling: 2-3 values per non-binary parameter, all binary combos.

    Returns indices into the space.  Keeps total combinations low while
    sampling each subspace evenly despite constraints.
    """
    rng = rng or np.random.default_rng(0)
    keep: Dict[str, set] = {}
    for p in space.nonbinary_parameters:
        vals = list(p.values)
        if len(vals) <= values_per_param:
            keep[p.name] = set(vals)
        else:
            # endpoints (+ middle when 3 values wanted) — even coverage
            picks = {vals[0], vals[-1]}
            if values_per_param >= 3:
                picks.add(vals[len(vals) // 2])
            while len(picks) < values_per_param:
                picks.add(vals[int(rng.integers(len(vals)))])
            keep[p.name] = picks
    out = []
    for i, cfg in enumerate(space):
        if all(cfg[n] in keep[n] for n in keep):
            out.append(i)
    return out
