"""Models of the TP → PC_ops relation (paper §3.4).

Two model families, both implemented from scratch on numpy:

* ``DecisionTreeModel`` (§3.4.2): regression trees built top-down greedily
  (ID3-style with Standard Deviation Reduction == MSE split criterion).  A
  candidate set of trees with varying structural hyperparameters is trained on
  a random 50% of the explored space, evaluated on the other 50%, and the tree
  with the lowest MAE (ties broken by RMSE) is selected — per counter.

* ``QuadraticRegressionModel`` (§3.4.1): per binary-parameter subspace,
  least-squares fit over main effects, pairwise interactions and quadratic
  terms of the non-binary parameters.  Training points are sampled
  deliberately: 2-3 values per non-binary parameter.

Models are trained once (on any hardware/input — the portability thesis) and
predict all PC_ops counters for unseen configurations.

Every model answers two prediction questions:

* ``predict(cfg) -> Dict[str, float]`` — one configuration (kept for
  single-config call sites and as the golden scalar reference);
* ``predict_matrix(space) -> n_configs × n_counters ndarray`` — the whole
  space at once, column j holding counter ``counter_names[j]``.  Algorithm 1
  re-scores the entire space at every profiling step, so this is the shape
  the searcher actually consumes; ``prediction_matrix`` below memoizes it
  per (model, space) so repeated searches (the paper's 1000 repetitions)
  compute it exactly once.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import counters as C
from repro.core.tuning_space import TuningSpace

# Counters the models learn (the portable PC_ops set).  GRID and VMEM_WS are
# included: they are statically known, making the model's job easy for them —
# the paper likewise feeds thread counts through the model path.
MODELED_COUNTERS: Tuple[str, ...] = C.PC_OPS


def _dicts_to_matrix(dicts: Sequence[Dict[str, float]],
                     names: Sequence[str]) -> np.ndarray:
    """Stack per-config counter dicts into an (n × len(names)) ndarray,
    missing counters filling as 0.0 (== outside PC_used for scoring)."""
    out = np.zeros((len(dicts), len(names)), dtype=np.float64)
    for j, name in enumerate(names):
        out[:, j] = [d.get(name, 0.0) for d in dicts]
    return out


class TPPCModel:
    """Interface: predict PC_ops for a configuration / a whole space."""

    # structural space signature of the space the model was trained on
    # (``repro.tuning.signature.SpaceSignature``); bound by the
    # serializer on load and by training call sites that know it.  None
    # on models that predate signatures — the serializer recomputes it
    # from the artifact's recorded parameters.
    signature = None

    def predict(self, cfg: Dict) -> Dict[str, float]:
        raise NotImplementedError

    def predict_many(self, cfgs: Sequence[Dict]) -> List[Dict[str, float]]:
        return [self.predict(c) for c in cfgs]

    @property
    def counter_names(self) -> Tuple[str, ...]:
        """Column order of ``predict_matrix``."""
        raise NotImplementedError

    def predict_matrix(self, space: Optional[TuningSpace] = None) -> np.ndarray:
        """``len(space) × len(counter_names)`` predictions for every config.

        Generic fallback: loops ``predict``.  Concrete models override with
        batched array implementations.
        """
        space = space if space is not None else self.space
        return _dicts_to_matrix(self.predict_many(space.configs),
                                self.counter_names)


# =============================================================================
# Shared prediction-matrix cache (model- and space-keyed)
# =============================================================================
# model (weak) -> {id(space): (weakref(space), counter_names, matrix)}.
# Searchers are re-instantiated per repetition in the experiment harness;
# predictions are repetition-invariant, so the matrix must outlive searchers
# but die with the model.
_PRED_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _compute_prediction_matrix(model, space: TuningSpace):
    try:
        # probed separately so a real bug inside predict_matrix() below
        # propagates instead of silently degrading to the per-config loop
        names: Optional[Tuple[str, ...]] = tuple(model.counter_names)
    except (AttributeError, NotImplementedError):
        names = None
    if names is not None and hasattr(model, "predict_matrix"):
        matrix = np.asarray(model.predict_matrix(space), dtype=np.float64)
        # column-major: score_space works column-wise, so per-counter slices
        # must be contiguous (same values, ~4x faster scoring on big spaces)
        matrix = np.asfortranarray(matrix)
    else:
        # model exposing only .predict (duck-typed, or a minimal TPPCModel
        # subclass that never declared counter_names): materialize per config
        preds = [model.predict(space[i]) for i in range(len(space))]
        names_l: List[str] = []
        seen = set()
        for d in preds:
            for k in d:
                if k not in seen:
                    seen.add(k)
                    names_l.append(k)
        names = tuple(names_l)
        matrix = _dicts_to_matrix(preds, names)
    matrix.setflags(write=False)
    return names, matrix


def prediction_matrix(model, space: TuningSpace
                      ) -> Tuple[Tuple[str, ...], np.ndarray]:
    """Memoized (counter_names, n_configs × n_counters) for model × space.

    The matrix is read-only and shared: every searcher instance over the same
    (model, space) pair — e.g. the 1000 repetitions of one experiment —
    reuses the same array.
    """
    try:
        per_model = _PRED_CACHE.get(model)
        if per_model is None:
            per_model = {}
            _PRED_CACHE[model] = per_model
    except TypeError:  # unhashable / non-weakrefable model
        return _compute_prediction_matrix(model, space)
    key = id(space)
    entry = per_model.get(key)
    if entry is not None:
        ref, names, matrix = entry
        if ref() is space:
            return names, matrix
    names, matrix = _compute_prediction_matrix(model, space)

    def _evict(dead_ref, per_model=per_model, key=key):
        # drop the dead space's matrix now rather than holding it for the
        # model's lifetime; guard against the id having been reused
        cur = per_model.get(key)
        if cur is not None and cur[0] is dead_ref:
            del per_model[key]

    per_model[key] = (weakref.ref(space, _evict), names, matrix)
    return names, matrix


# =============================================================================
# Decision tree regression (from scratch)
# =============================================================================
@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    value: float = 0.0

    @property
    def is_leaf(self) -> bool:
        return self.left is None


def _best_split(X: np.ndarray, y: np.ndarray, min_samples: int):
    """Lowest-SSE (feature, threshold) via cumulative sums, O(n log n)/feature.

    For each feature the samples are sorted once; left/right SSE at every
    candidate threshold (midpoints between consecutive distinct values) comes
    from prefix sums of y and y² — replacing the former O(n²·p) rescan.
    Ties keep the lowest threshold of the earliest feature (same scan order
    as before; note the prefix-sum SSE rounds differently from the old
    two-pass sum, so exact-tie resolution — and hence trained trees — can
    differ from the pre-vectorization builder at fp round-off).

    y is centered first: SSE is shift-invariant, and on near-constant
    targets the raw ``Σy² − (Σy)²/n`` form cancels catastrophically
    (negative SSEs → phantom splits fitting float noise).
    """
    n = y.size
    y = y - y.mean()
    best = None  # (sse, feature, threshold)
    for f in range(X.shape[1]):
        order = np.argsort(X[:, f], kind="stable")
        xo = X[order, f]
        yo = y[order]
        cut = np.flatnonzero(xo[1:] != xo[:-1])  # left block = [0 .. cut]
        if cut.size == 0:
            continue
        nl = cut + 1
        nr = n - nl
        valid = (nl >= min_samples) & (nr >= min_samples)
        if not valid.any():
            continue
        c1 = np.cumsum(yo)
        c2 = np.cumsum(yo * yo)
        s1l, s2l = c1[cut], c2[cut]
        s1r, s2r = c1[-1] - s1l, c2[-1] - s2l
        sse = np.maximum(s2l - s1l * s1l / nl, 0.0) \
            + np.maximum(s2r - s1r * s1r / nr, 0.0)
        sse[~valid] = np.inf
        i = int(np.argmin(sse))
        if best is None or sse[i] < best[0]:
            t = (xo[cut[i]] + xo[cut[i] + 1]) / 2.0
            best = (float(sse[i]), f, float(t))
    return best


def _build_tree(
    X: np.ndarray,
    y: np.ndarray,
    depth: int,
    max_depth: int,
    min_samples: int,
) -> _Node:
    node = _Node(value=float(y.mean()) if y.size else 0.0)
    if depth >= max_depth or y.size < 2 * min_samples or np.all(y == y[0]):
        return node
    base_sse = float(((y - y.mean()) ** 2).sum())
    best = _best_split(X, y, min_samples)
    if best is None or best[0] >= base_sse - 1e-12:
        return node
    _, f, t = best
    lm = X[:, f] <= t
    node.feature, node.threshold = f, t
    node.left = _build_tree(X[lm], y[lm], depth + 1, max_depth, min_samples)
    node.right = _build_tree(X[~lm], y[~lm], depth + 1, max_depth, min_samples)
    return node


def _tree_predict(node: _Node, x: np.ndarray) -> float:
    while not node.is_leaf:
        node = node.left if x[node.feature] <= node.threshold else node.right
    return node.value


def _tree_predict_batch(node: _Node, X: np.ndarray) -> np.ndarray:
    """All rows of X through one tree, partitioning index sets iteratively.

    Identical leaf assignment to ``_tree_predict`` row by row (the same
    ``<=`` comparisons), without the per-row Python descent.
    """
    out = np.empty(X.shape[0], dtype=np.float64)
    stack = [(node, np.arange(X.shape[0]))]
    while stack:
        nd, idx = stack.pop()
        if idx.size == 0:
            continue
        if nd.is_leaf:
            out[idx] = nd.value
        else:
            lm = X[idx, nd.feature] <= nd.threshold
            stack.append((nd.left, idx[lm]))
            stack.append((nd.right, idx[~lm]))
    return out


# Candidate structural hyperparameters ("we also alter parent nodes" §3.4.2).
_TREE_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (4, 2), (6, 2), (8, 1), (10, 1), (12, 1), (16, 1),
)


class DecisionTreeModel(TPPCModel):
    """One selected regression tree per PC_ops counter (§3.4.2)."""

    def __init__(
        self,
        space: TuningSpace,
        cfgs: Sequence[Dict],
        counters: Sequence[Dict[str, float]],
        rng: Optional[np.random.Generator] = None,
        counters_to_model: Sequence[str] = MODELED_COUNTERS,
    ):
        rng = rng or np.random.default_rng(0)
        self.space = space
        X = space.vectorize_configs(cfgs)
        n = X.shape[0]
        self.trees: Dict[str, _Node] = {}
        self.scale: Dict[str, float] = {}
        perm = rng.permutation(n)
        half = max(1, n // 2)
        tr, te = perm[:half], perm[half:]
        if te.size == 0:
            te = tr
        for name in counters_to_model:
            y = np.array([float(cs.get(name, 0.0)) for cs in counters])
            # scale to O(1) for numerically comparable MAE across counters
            scale = float(np.abs(y).max()) or 1.0
            ys = y / scale
            best = None  # (mae, rmse, tree)
            for max_depth, min_samples in _TREE_CANDIDATES:
                tree = _build_tree(X[tr], ys[tr], 0, max_depth, min_samples)
                err = _tree_predict_batch(tree, X[te]) - ys[te]
                mae = float(np.abs(err).mean())
                rmse = float(np.sqrt((err**2).mean()))
                if best is None or (mae, rmse) < (best[0], best[1]):
                    best = (mae, rmse, tree)
            self.trees[name] = best[2]
            self.scale[name] = scale

    @property
    def counter_names(self) -> Tuple[str, ...]:
        return tuple(self.trees)

    def predict(self, cfg: Dict) -> Dict[str, float]:
        x = np.asarray(self.space.vectorize(cfg), dtype=np.float64)
        return {
            name: _tree_predict(tree, x) * self.scale[name]
            for name, tree in self.trees.items()
        }

    def predict_matrix(self, space: Optional[TuningSpace] = None) -> np.ndarray:
        space = space if space is not None else self.space
        # features must be encoded by the MODEL's space (cross-space search:
        # a model from the reduced GEMM space scoring the full space)
        X = (space.feature_matrix if space is self.space
             else self.space.vectorize_configs(space.configs))
        out = np.empty((X.shape[0], len(self.trees)), dtype=np.float64)
        for j, name in enumerate(self.counter_names):
            out[:, j] = _tree_predict_batch(self.trees[name], X) \
                * self.scale[name]
        return out

    @classmethod
    def from_state(
        cls, space: TuningSpace, trees: Dict[str, _Node],
        scale: Dict[str, float],
    ) -> "DecisionTreeModel":
        """Rebuild a trained model from serialized state (no re-training)."""
        obj = cls.__new__(cls)
        obj.space = space
        obj.trees = trees
        obj.scale = scale
        return obj


# =============================================================================
# Least-squares quadratic regression per binary subspace (§3.4.1)
# =============================================================================
def _poly_features(v: np.ndarray) -> np.ndarray:
    """[1, x_i, x_i^2, x_i*x_j] feature expansion."""
    feats = [1.0]
    k = v.size
    feats.extend(v.tolist())
    feats.extend((v**2).tolist())
    for i in range(k):
        for j in range(i + 1, k):
            feats.append(v[i] * v[j])
    return np.asarray(feats)


def _poly_features_batch(V: np.ndarray) -> np.ndarray:
    """Row-wise ``_poly_features``: (m × k) -> (m × n_feats)."""
    m, k = V.shape
    cols = [np.ones((m, 1)), V, V * V]
    for i in range(k):
        for j in range(i + 1, k):
            cols.append((V[:, i] * V[:, j])[:, None])
    return np.concatenate(cols, axis=1)


class QuadraticRegressionModel(TPPCModel):
    """Least-squares non-linear regression per binary subspace (§3.4.1)."""

    def __init__(
        self,
        space: TuningSpace,
        cfgs: Sequence[Dict],
        counters: Sequence[Dict[str, float]],
        counters_to_model: Sequence[str] = MODELED_COUNTERS,
    ):
        self.space = space
        self._counter_names = tuple(counters_to_model)
        nb = space.nonbinary_parameters
        self._nb_names = [p.name for p in nb]
        X = space.vectorize_configs(cfgs)
        nb_cols = [j for j, p in enumerate(space.parameters)
                   if not p.is_binary]
        bin_cols = [j for j, p in enumerate(space.parameters) if p.is_binary]
        V = X[:, nb_cols]
        keys = [tuple(r) for r in
                X[:, bin_cols].astype(np.int64).tolist()]
        # group samples by binary subspace
        groups: Dict[Tuple, List[int]] = {}
        for i, key in enumerate(keys):
            groups.setdefault(key, []).append(i)
        self.coefs: Dict[Tuple, Dict[str, np.ndarray]] = {}
        self._fallback: Dict[str, float] = {
            name: float(
                np.mean([cs.get(name, 0.0) for cs in counters]) if counters else 0.0
            )
            for name in counters_to_model
        }
        for key, idxs in groups.items():
            Xf = _poly_features_batch(V[np.asarray(idxs)])
            per_counter: Dict[str, np.ndarray] = {}
            for name in counters_to_model:
                y = np.array([float(counters[i].get(name, 0.0)) for i in idxs])
                coef, *_ = np.linalg.lstsq(Xf, y, rcond=None)
                per_counter[name] = coef
            self.coefs[key] = per_counter
        self._coef_mats: Dict[Tuple, np.ndarray] = {}

    @property
    def counter_names(self) -> Tuple[str, ...]:
        return self._counter_names

    def _nb_vector(self, cfg: Dict) -> np.ndarray:
        full = dict(zip([p.name for p in self.space.parameters],
                        self.space.vectorize(cfg)))
        return np.asarray([full[n] for n in self._nb_names], dtype=np.float64)

    def predict(self, cfg: Dict) -> Dict[str, float]:
        key = self.space.subspace_key(cfg)
        if key not in self.coefs:
            return dict(self._fallback)
        feats = _poly_features(self._nb_vector(cfg))
        return {
            name: float(feats @ coef)
            for name, coef in self.coefs[key].items()
        }

    def _coef_matrix(self, key: Tuple) -> np.ndarray:
        """(n_feats × n_counters) stacked coefficients of one subspace."""
        mat = self._coef_mats.get(key)
        if mat is None:
            per = self.coefs[key]
            mat = np.stack([per[name] for name in self._counter_names],
                           axis=1)
            self._coef_mats[key] = mat
        return mat

    def predict_matrix(self, space: Optional[TuningSpace] = None) -> np.ndarray:
        space = space if space is not None else self.space
        if space is self.space:
            X = space.feature_matrix
            keys = space.subspace_keys()
        else:
            X = self.space.vectorize_configs(space.configs)
            keys = [self.space.subspace_key(c) for c in space.configs]
        nb_cols = [j for j, p in enumerate(self.space.parameters)
                   if not p.is_binary]
        V = X[:, nb_cols]
        out = np.empty((len(keys), len(self._counter_names)),
                       dtype=np.float64)
        fallback = np.array([self._fallback[n] for n in self._counter_names])
        groups: Dict[Tuple, List[int]] = {}
        for i, key in enumerate(keys):
            groups.setdefault(key, []).append(i)
        for key, idxs in groups.items():
            rows = np.asarray(idxs)
            if key in self.coefs:
                out[rows] = _poly_features_batch(V[rows]) \
                    @ self._coef_matrix(key)
            else:
                out[rows] = fallback
        return out

    @classmethod
    def from_state(
        cls,
        space: TuningSpace,
        counter_names: Sequence[str],
        coefs: Dict[Tuple, Dict[str, np.ndarray]],
        fallback: Dict[str, float],
    ) -> "QuadraticRegressionModel":
        """Rebuild a trained model from serialized state (no re-fitting)."""
        obj = cls.__new__(cls)
        obj.space = space
        obj._counter_names = tuple(counter_names)
        obj._nb_names = [p.name for p in space.nonbinary_parameters]
        obj.coefs = coefs
        obj._fallback = dict(fallback)
        obj._coef_mats = {}
        return obj


# =============================================================================
# Exact "model": reads recorded counters (paper §4.3 — eliminates model error)
# =============================================================================
class ExactCounterModel(TPPCModel):
    """Replays exhaustively-measured PC_ops (no ML prediction error)."""

    def __init__(self, space: TuningSpace, counters: Sequence[Dict[str, float]]):
        self.space = space
        self._by_index = [dict(cs) for cs in counters]
        self._index: Optional[Dict[Tuple, int]] = None
        self._remap: Optional[np.ndarray] = None
        self._counter_names: Optional[Tuple[str, ...]] = None

    @property
    def counter_names(self) -> Tuple[str, ...]:
        if self._counter_names is None:
            names = list(C.PC_OPS)
            seen = set(names)
            for d in self._by_index:
                for k in d:
                    if k not in seen:
                        seen.add(k)
                        names.append(k)
            self._counter_names = tuple(names)
        return self._counter_names

    def _record_index(self, idx: int) -> int:
        """Space index -> position in the recorded counters list."""
        if self._remap is None:
            return idx
        rec = int(self._remap[idx])
        if rec < 0:
            raise KeyError(f"config not in recorded pairs: {self.space[idx]}")
        return rec

    def predict(self, cfg: Dict) -> Dict[str, float]:
        try:
            return self._by_index[self._record_index(self.space.index_of(cfg))]
        except KeyError:
            if self._index is not None:  # cfg outside the bound space but in
                # the recorded pairs (differently-pruned space)
                return self._by_index[self._index[tuple(sorted(cfg.items()))]]
            raise

    def predict_index(self, idx: int) -> Dict[str, float]:
        return self._by_index[self._record_index(idx)]

    def predict_matrix(self, space: Optional[TuningSpace] = None) -> np.ndarray:
        space = space if space is not None else self.space
        if space is self.space:
            recs = [self._by_index[self._record_index(i)]
                    for i in range(len(space))]
        else:
            recs = [self.predict(space[i]) for i in range(len(space))]
        return _dicts_to_matrix(recs, self.counter_names)

    @classmethod
    def from_pairs(
        cls, space: TuningSpace, configs: Sequence[Dict],
        counters: Sequence[Dict[str, float]],
    ) -> "ExactCounterModel":
        """Rebuild from explicit (config, counters) pairs — robust to the
        deserialized space enumerating configs in a different order.  The
        space-index → record remap is computed once here, so ``predict``
        stays an O(1) lookup instead of rebuilding a sorted key per call."""
        obj = cls(space, counters)
        obj._index = {tuple(sorted(c.items())): i
                      for i, c in enumerate(configs)}
        obj._remap = np.array(
            [obj._index.get(tuple(sorted(space[i].items())), -1)
             for i in range(len(space))], dtype=np.int64)
        return obj


# =============================================================================
# Cross-space transfer: rebind a trained model onto a DIFFERENT space
# =============================================================================
class _ConfigList:
    """Minimal space-shaped view over a list of config dicts.

    The concrete models' batched ``predict_matrix(space)`` paths only
    touch ``space.configs`` / ``space[i]`` / ``len(space)`` when the
    space is not their own — this shim lets ``TransferredModel`` reuse
    those batched paths on remapped configs without materializing a
    cross-product ``TuningSpace``.
    """

    def __init__(self, configs: Sequence[Dict]):
        self.configs = list(configs)

    def __len__(self) -> int:
        return len(self.configs)

    def __getitem__(self, i: int) -> Dict:
        return self.configs[i]


class TransferredModel(TPPCModel):
    """A trained TP→PC model rebound onto a space it was never fit on.

    The transfer mechanism of the cross-space warm start (paper §4.4/§4.5
    portability, extended across kernels per arXiv 2102.05299): a target
    config is translated into the source model's own space — each source
    parameter reads the target parameter its hashed slot mapped to
    (``param_map``: source parameter index → target parameter index),
    the raw value snapped to the nearest *declared* source value by
    feature code; unmapped source parameters pin to their median declared
    value — and predictions are restricted to the **shared-counter
    intersection**: only counters both spaces name are reported, so the
    downstream cost-model pricing never consumes a counter the target
    workload would not emit.

    The rebound model is a read-time construct (built by
    ``repro.tuning.serialize.rebind_model_dict``); it is never
    re-serialized — a transferred job that completes trains and publishes
    a native model for its own key, which then outranks the transfer tier.
    """

    def __init__(self, source: TPPCModel, target_space: TuningSpace,
                 param_map: Dict[int, int],
                 counters: Optional[Sequence[str]] = None,
                 similarity: float = 0.0,
                 source_key: Optional[str] = None):
        self.source = source
        self.space = target_space
        self.source_space = source.space
        self.param_map = dict(param_map)
        src_names = tuple(source.counter_names)
        if counters is None:
            shared = src_names
        else:
            want = set(counters)
            shared = tuple(n for n in src_names if n in want)
        if not shared:      # nothing both spaces name: nothing to predict
            raise ValueError(
                "transfer has an empty shared-counter intersection: "
                f"source predicts {list(src_names)}, target names "
                f"{sorted(want)}")
        self._counter_names = shared
        self.similarity = float(similarity)
        self.source_key = source_key
        # per-source-parameter translation plan, built once
        self._plan: List[Tuple[Any, ...]] = []
        for i, p in enumerate(self.source_space.parameters):
            j = self.param_map.get(i)
            if j is None or j >= len(target_space.parameters):
                # unmapped slot: pin to the median declared value
                self._plan.append(("pin", p.name,
                                   p.values[len(p.values) // 2]))
                continue
            tp = target_space.parameters[j]
            codes = np.asarray([p.encode(v) for v in p.values],
                               dtype=np.float64)
            self._plan.append(("map", p.name, p, tp, codes))

    @property
    def counter_names(self) -> Tuple[str, ...]:
        return self._counter_names

    @staticmethod
    def _snap(p, tp, codes: np.ndarray, value):
        """Nearest declared source value for a target value: exact raw
        match when the value is in the source list, else nearest by
        feature code (the numeric shadow both models consume)."""
        try:
            if value in p.values:
                return value
        except TypeError:
            pass
        try:
            code = float(tp.encode(value))
        except (TypeError, ValueError):
            return p.values[len(p.values) // 2]
        return p.values[int(np.argmin(np.abs(codes - code)))]

    def translate(self, cfg: Dict) -> Dict:
        """Target-space config → the source-space config the wrapped
        model actually predicts for."""
        out: Dict = {}
        for step in self._plan:
            if step[0] == "pin":
                out[step[1]] = step[2]
            else:
                _, name, p, tp, codes = step
                out[name] = self._snap(p, tp, codes, cfg[tp.name])
        return out

    def predict(self, cfg: Dict) -> Dict[str, float]:
        pred = self.source.predict(self.translate(cfg))
        return {n: float(pred.get(n, 0.0)) for n in self._counter_names}

    def predict_matrix(self, space: Optional[TuningSpace] = None) -> np.ndarray:
        space = space if space is not None else self.space
        view = _ConfigList([self.translate(c) for c in space.configs])
        mat = np.asarray(self.source.predict_matrix(view),
                         dtype=np.float64)
        src_names = list(self.source.counter_names)
        cols = [src_names.index(n) for n in self._counter_names]
        return mat[:, cols]


class TransferEnsemble:
    """Similarity-weighted committee of rebound cross-space models.

    A single borrowed model's absolute runtime predictions are noisy on
    a space it was never fit on, but the parts of the ranking DIFFERENT
    source spaces agree on are exactly the structure that generalizes —
    a similarity-weighted blend of every compatible source's relative
    ranking is far more reliable at the head (where the warm start
    spends its trials) than the single most-similar source alone.

    ``members`` is ``[(TransferredModel, similarity), ...]``, best
    first; provenance (``source_key``/``similarity``) reports the top
    member.  Scoring lives in
    ``repro.core.tuner.ensemble_runtime_scores`` — the committee itself
    is a read-time construct like its members and is never serialized.
    """

    def __init__(self, members: Sequence[Tuple["TransferredModel", float]]):
        if not members:
            raise ValueError("TransferEnsemble needs at least one member")
        self.members: List[Tuple["TransferredModel", float]] = \
            [(m, float(s)) for m, s in members]

    @property
    def top(self) -> "TransferredModel":
        return self.members[0][0]

    @property
    def source_key(self) -> Optional[str]:
        return self.top.source_key

    @property
    def similarity(self) -> float:
        return self.members[0][1]

    @property
    def counter_names(self) -> Tuple[str, ...]:
        return self.top.counter_names

    def __len__(self) -> int:
        return len(self.members)


def deliberate_training_sample(
    space: TuningSpace, values_per_param: int = 3,
    rng: Optional[np.random.Generator] = None,
) -> List[int]:
    """§3.4.1 sampling: 2-3 values per non-binary parameter, all binary combos.

    Returns indices into the space.  Keeps total combinations low while
    sampling each subspace evenly despite constraints.
    """
    rng = rng or np.random.default_rng(0)
    keep: Dict[str, set] = {}
    for p in space.nonbinary_parameters:
        vals = list(p.values)
        if len(vals) <= values_per_param:
            keep[p.name] = set(vals)
        else:
            # endpoints (+ middle when 3 values wanted) — even coverage
            picks = {vals[0], vals[-1]}
            if values_per_param >= 3:
                picks.add(vals[len(vals) // 2])
            while len(picks) < values_per_param:
                picks.add(vals[int(rng.integers(len(vals)))])
            keep[p.name] = picks
    # vectorized membership over the feature matrix (was a full Python scan)
    mask = np.ones(len(space), dtype=bool)
    fm = space.feature_matrix
    for j, p in enumerate(space.parameters):
        if p.name not in keep:
            continue
        if len({p.encode(v) for v in p.values}) == len(p.values):
            codes = np.array(sorted(p.encode(v) for v in keep[p.name]))
            mask &= np.isin(fm[:, j], codes)
        else:
            # non-injective encoding (parameter mixing strings/numerics):
            # feature codes would alias distinct values — match raw values
            kept = keep[p.name]
            mask &= np.fromiter((c[p.name] in kept for c in space.configs),
                                dtype=bool, count=len(space))
    return [int(i) for i in np.flatnonzero(mask)]
