"""The paper's technique applied to the framework itself: profile-counter-
guided search over the DISTRIBUTED STEP configuration (microbatches, remat
policy, loss chunking, attention chunk, FSDP on/off).

"Kernel" ↦ compiled train step; "performance counters" ↦ the trip-count-aware
HLO parse of the dry-run artifact (flops/bytes/collective bytes/live memory);
"runtime" ↦ the three-term roofline bound.  Empirical tests are REAL compiles
(tens of seconds each) — exactly the expensive-measurement regime the paper's
searcher exists for.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Tuple

import jax

from repro.core import counters as C
from repro.core.account import Evaluator
from repro.core.counters import CounterSet
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.roofline import analysis as roofline


def make_step_space() -> TuningSpace:
    params = [
        TuningParameter("MICROBATCHES", (1, 2, 4, 8)),
        TuningParameter("REMAT", ("nothing_saveable", "dots_saveable")),
        TuningParameter("LOSS_CHUNKS", (1, 4, 8, 16)),
        TuningParameter("KV_CHUNK", (512, 1024, 2048, 4096)),
        TuningParameter("FSDP", (0, 1)),
    ]
    return TuningSpace(params, name="train_step")


class CompiledStepEvaluator(Evaluator):
    """config -> (estimated runtime, counters) via a real lower+compile.

    Implements the shared evaluator protocol; the ``cost`` charged per
    empirical test is the real compile wall-clock (0 on compile-cache hits),
    so ``elapsed`` is honest tuning time in this expensive-measurement
    regime.  Each test times its own compile (the shared
    ``compile_seconds`` total is lock-guarded), so an async driver that
    overlaps compiles still charges every test its true cost instead of a
    racy delta of the shared counter.
    """

    def __init__(self, arch_name: str, shape_name: str,
                 hbm_bytes: float = 16e9, verbose: bool = True):
        super().__init__(make_step_space())
        self.arch_name = arch_name
        self.shape_name = shape_name
        self.hbm_bytes = hbm_bytes
        self.verbose = verbose
        self._cache: Dict[int, CounterSet] = {}
        self._lock = threading.Lock()
        self.compile_seconds = 0.0

    def _counters_for(self, cfg: Config) -> Tuple[CounterSet, float]:
        from repro.distributed.sharding import default_rules
        from repro.launch import dryrun

        rules_override = None if cfg["FSDP"] else {"embed": None}
        t0 = time.time()
        rec = dryrun.lower_cell(
            self.arch_name, self.shape_name, multi_pod=False,
            step_overrides=dict(
                microbatches=cfg["MICROBATCHES"], remat=cfg["REMAT"],
                loss_chunks=cfg["LOSS_CHUNKS"], kv_chunk=cfg["KV_CHUNK"],
            ),
            rules_overrides=rules_override,
            verbose=False,
        )
        compile_s = time.time() - t0
        with self._lock:
            self.compile_seconds += compile_s
        rf = rec["roofline"]
        mem_live = rec["memory"]["peak_bytes"]
        compute_s, memory_s = rf["compute_s"], rf["memory_s"]
        coll_s = rf["collective_s"]
        runtime = max(compute_s, memory_s, coll_s)
        oom = mem_live > self.hbm_bytes
        if oom:
            runtime *= 100.0  # OOM configs are effectively unrunnable

        ops = {
            C.MXU_FLOPS: rf["flops"] / rec["chips"],
            C.VPU_OPS: 0.0,
            C.TRANS_OPS: 0.0,
            C.ISSUE_OPS: rf["flops"] / rec["chips"],
            C.HBM_RD: rf["hbm_bytes"] / rec["chips"] * 2 / 3,
            C.HBM_WR: rf["hbm_bytes"] / rec["chips"] / 3,
            C.VMEM_RD: 0.0, C.VMEM_WR: 0.0, C.CMEM_RD: 0.0,
            C.ICI_B: rf["collective_bytes"],
            C.GRID: 64.0,                       # step-level: no grid axis
            C.VMEM_WS: float(mem_live),
            C.SPILL_B: float(max(0.0, mem_live - self.hbm_bytes)),
        }
        stress = {
            C.HBM_U: min(1.0, memory_s / runtime),
            C.VMEM_U: 0.0, C.CMEM_U: 0.0,
            C.ICI_U: min(1.0, coll_s / runtime),
            C.MXU_U: min(1.0, compute_s / runtime),
            C.VPU_U: 0.0, C.TRANS_U: 0.0,
            C.ISSUE_U: min(1.0, compute_s / runtime) / 2.0,
            C.CORE_E: 1.0, C.LANE_E: 1.0,
            C.VMEM_OCC: min(1.0, mem_live / self.hbm_bytes),
        }
        cs = CounterSet(ops=ops, stress=stress, runtime=runtime)
        if self.verbose:
            print(f"  [step-tune] {cfg} -> {runtime*1e3:8.1f}ms"
                  f"{' (OOM)' if oom else ''}")
        return cs, compile_s

    def _evaluate(
        self, idx: int, profiled: bool
    ) -> Tuple[float, CounterSet, float]:
        with self._lock:
            cs = self._cache.get(idx)
        if cs is not None:
            return float(cs.runtime), cs, 0.0
        cs, compile_s = self._counters_for(self.space[idx])
        with self._lock:
            self._cache[idx] = cs
        return float(cs.runtime), cs, compile_s
