"""Frozen scalar (pre-vectorization) searcher implementations.

These are verbatim ports of the per-config dict-walking hot path that
``ProfileBasedSearcher`` / ``ProfileLocalSearcher`` used before the
array-native scoring engine: ``model.predict`` one config at a time behind a
dict cache, ``score_configuration`` in a Python loop over the space, and an
O(n²) neighbourhood scan.

They exist for two reasons and must NOT be "optimized":

* golden equivalence — tests/test_vectorized_golden.py proves the vectorized
  searchers replay these traces step-for-step at fixed seeds;
* the overhead baseline — benchmarks/bench_search_overhead.py measures the
  propose/observe speedup of the vectorized engine against exactly this code.

Not registered in ``SEARCHERS``: internal measurement/verification aids only.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core import bottleneck, reaction, scoring
from repro.core.account import Candidate
from repro.core.model import TPPCModel
from repro.core.searcher import ProfileBasedSearcher, Searcher
from repro.core.tuning_space import TuningSpace


def scalar_neighbours(space: TuningSpace, idx: int) -> List[int]:
    """The original O(n²-ish) full-scan 1-parameter neighbourhood."""
    base = space[idx]
    out = []
    for j, cfg in enumerate(space):
        if j == idx:
            continue
        diff = sum(1 for k in base if base[k] != cfg[k])
        if diff == 1:
            out.append(j)
    return out


class ScalarProfileBasedSearcher(Searcher):
    """Algorithm 1 exactly as implemented before vectorization."""

    name = "profile_scalar_reference"

    def __init__(
        self,
        space: TuningSpace,
        model: Optional[TPPCModel] = None,
        cores: Optional[int] = None,
        n: int = 5,
        inst_reaction: float = reaction.INST_REACTION_DEFAULT,
        seed: int = 0,
    ):
        super().__init__(space, seed)
        self.model = model
        self.cores = cores
        self.n = n
        self.inst_reaction = inst_reaction
        self._pred_cache: Dict[int, Dict[str, float]] = {}

    _check_bound = ProfileBasedSearcher._check_bound

    def _predict(self, idx: int) -> Dict[str, float]:
        if idx not in self._pred_cache:
            self._pred_cache[idx] = self.model.predict(self.space[idx])
        return self._pred_cache[idx]

    def _plan(self):
        self._check_bound()
        size = len(self.space)
        evaluated: set = set()
        c_profile = int(self.rng.integers(size))
        while True:
            obs = yield [Candidate(c_profile, profile=True)]
            pc = obs[0].counters
            t = pc.runtime
            evaluated.add(c_profile)
            b = bottleneck.analyze(pc, cores=self.cores)
            delta_pc = reaction.compute_delta_pc(b, self.inst_reaction)
            pc_prof = self._predict(c_profile)
            raw = np.zeros(size)
            mask = np.zeros(size, dtype=bool)
            for k in range(size):
                if k in evaluated:
                    continue
                mask[k] = True
                raw[k] = scoring.score_configuration(
                    delta_pc, pc_prof, self._predict(k)
                )
            if not mask.any():
                return
            weights = scoring.normalize_scores(raw)
            picks: List[Candidate] = []
            for _ in range(self.n):
                if not mask.any():
                    break
                sel = scoring.weighted_choice(weights, self.rng, mask)
                mask[sel] = False
                picks.append(Candidate(int(sel)))
            obs = yield picks
            for o in obs:
                evaluated.add(o.index)
                if o.runtime <= t:
                    c_profile, t = o.index, o.runtime


class ScalarProfileLocalSearcher(ScalarProfileBasedSearcher):
    """§3.9.1 gradient-following variant as implemented before vectorization."""

    name = "profile_local_scalar_reference"

    def __init__(
        self,
        space: TuningSpace,
        model: Optional[TPPCModel] = None,
        cores: Optional[int] = None,
        n: int = 5,
        local_frac: float = 0.6,
        inst_reaction: float = reaction.INST_REACTION_DEFAULT,
        seed: int = 0,
    ):
        super().__init__(space, model=model, cores=cores, n=n,
                         inst_reaction=inst_reaction, seed=seed)
        self.local_frac = local_frac
        self._nbrs: Dict[int, list] = {}

    def _neighbours(self, idx: int) -> list:
        if idx not in self._nbrs:
            self._nbrs[idx] = scalar_neighbours(self.space, idx)
        return self._nbrs[idx]

    def _plan(self):
        self._check_bound()
        size = len(self.space)
        evaluated: set = set()
        c_profile = int(self.rng.integers(size))
        while True:
            obs = yield [Candidate(c_profile, profile=True)]
            pc = obs[0].counters
            t = pc.runtime
            evaluated.add(c_profile)
            b = bottleneck.analyze(pc, cores=self.cores)
            delta_pc = reaction.compute_delta_pc(b, self.inst_reaction)
            pc_prof = self._predict(c_profile)

            raw = np.zeros(size)
            mask = np.zeros(size, dtype=bool)
            for k in range(size):
                if k in evaluated:
                    continue
                mask[k] = True
                raw[k] = scoring.score_configuration(
                    delta_pc, pc_prof, self._predict(k))
            if not mask.any():
                return
            weights = scoring.normalize_scores(raw)

            n_local = int(round(self.n * self.local_frac))
            nbrs = [j for j in self._neighbours(c_profile)
                    if j not in evaluated]
            nbrs.sort(key=lambda j: raw[j], reverse=True)
            local = nbrs[:n_local]
            for j in local:
                mask[j] = False
            if local:
                obs = yield [Candidate(int(j)) for j in local]
                for o in obs:
                    evaluated.add(o.index)
                    if o.runtime <= t:
                        c_profile, t = o.index, o.runtime
            picks: List[Candidate] = []
            for _ in range(self.n - min(n_local, len(nbrs))):
                if not mask.any():
                    break
                sel = scoring.weighted_choice(weights, self.rng, mask)
                mask[sel] = False
                picks.append(Candidate(int(sel)))
            if picks:
                obs = yield picks
                for o in obs:
                    evaluated.add(o.index)
                    if o.runtime <= t:
                        c_profile, t = o.index, o.runtime
