"""TPU performance-counter taxonomy (paper §3.1, Table 1 — adapted).

The paper splits NVIDIA counters into ``PC_ops`` (operation counts; the
TP→PC_ops relation is portable across hardware and inputs, Eqs. 3–5) and
``PC_stress`` (subsystem utilizations; hardware/input dependent, measured live).

On TPU there is no CUPTI; every Ops counter is statically derivable from the
compiled artifact / BlockSpec arithmetic (see DESIGN.md §2 for the mapping
table).  Stress counters are produced by the execution model (or, on real
hardware, by the profiler) — they describe *how loaded* each subsystem was.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# --- PC_ops: hardware/input-portable operation counts -------------------------
# bytes moved HBM -> VMEM (analog of dram_read_transactions)
HBM_RD = "HBM_RD"
# bytes moved VMEM -> HBM (analog of dram_write_transactions)
HBM_WR = "HBM_WR"
# VMEM<->VREG traffic bytes (analog of l2 transactions)
VMEM_RD = "VMEM_RD"
VMEM_WR = "VMEM_WR"
# scalar/const memory reads (analog of tex_cache_transactions)
CMEM_RD = "CMEM_RD"
# spill bytes: VMEM oversubscription spilling to HBM (analog local_memory_overhead)
SPILL_B = "SPILL_B"
# MXU matrix fused ops (analog inst_fp_32)
MXU_FLOPS = "MXU_FLOPS"
# vector (VPU) elementwise ops (analog inst_integer / misc)
VPU_OPS = "VPU_OPS"
# transcendental ops: exp/rsqrt/log — slow path on VPU (analog inst_fp special)
TRANS_OPS = "TRANS_OPS"
# total issued ops (analog inst_executed)
ISSUE_OPS = "ISSUE_OPS"
# number of grid programs (parallelism; analog of thread count / Δpc_global)
GRID = "GRID"
# inter-chip collective bytes crossing ICI (no GPU analog; TPU-specific)
ICI_B = "ICI_B"
# working-set bytes held in VMEM per program (occupancy input)
VMEM_WS = "VMEM_WS"

PC_OPS = (
    HBM_RD, HBM_WR, VMEM_RD, VMEM_WR, CMEM_RD, SPILL_B,
    MXU_FLOPS, VPU_OPS, TRANS_OPS, ISSUE_OPS, GRID, ICI_B, VMEM_WS,
)

# --- PC_stress: live utilizations in [0, 1] -----------------------------------
HBM_U = "HBM_U"        # HBM bandwidth utilization
VMEM_U = "VMEM_U"      # VMEM bandwidth utilization
CMEM_U = "CMEM_U"      # scalar/const memory utilization (tex analog)
ICI_U = "ICI_U"        # interconnect utilization
MXU_U = "MXU_U"        # matrix unit utilization
VPU_U = "VPU_U"        # vector unit utilization
TRANS_U = "TRANS_U"    # transcendental path utilization
ISSUE_U = "ISSUE_U"    # issue-slot utilization (MXU+VPU dual pipe)
CORE_E = "CORE_E"      # fraction of cores with >=1 program (sm_efficiency analog)
LANE_E = "LANE_E"      # useful-lane fraction, tile padding waste (warp_e analog)
VMEM_OCC = "VMEM_OCC"  # VMEM occupancy: working set / capacity

PC_STRESS = (
    HBM_U, VMEM_U, CMEM_U, ICI_U, MXU_U, VPU_U, TRANS_U, ISSUE_U,
    CORE_E, LANE_E, VMEM_OCC,
)

ALL_COUNTERS = PC_OPS + PC_STRESS


@dataclasses.dataclass
class CounterSet:
    """One profiled sample: ops counts + stress utilizations + runtime."""

    ops: Dict[str, float]
    stress: Dict[str, float]
    runtime: float  # seconds

    def __post_init__(self):
        for k in self.ops:
            if k not in PC_OPS:
                raise KeyError(f"unknown PC_ops counter {k!r}")
        for k in self.stress:
            if k not in PC_STRESS:
                raise KeyError(f"unknown PC_stress counter {k!r}")

    def op(self, name: str, default: float = 0.0) -> float:
        return float(self.ops.get(name, default))

    def st(self, name: str, default: float = 0.0) -> float:
        return float(self.stress.get(name, default))


def zero_ops() -> Dict[str, float]:
    return {k: 0.0 for k in PC_OPS}
