"""Shared evaluator protocol: the ask-tell side of empirical testing.

Every evaluator in the system — replayed records (``ReplayEvaluator``), the
virtual-TPU cost model (``CostModelEvaluator``), real compiles
(``step_tuner.CompiledStepEvaluator``) and timed callables
(``FunctionEvaluator``) — answers the same three questions:

  * ``measure(idx)``       — empirical test, runtime only (fast path);
  * ``profile(idx)``       — empirical test with performance counters
                             (slow path; optional — counter-less evaluators
                             raise ``ProfilingUnsupported``);
  * ``measure_many(batch)`` — evaluate a batch of ``Candidate``s, returning
                             ``Observation``s (the hook for async/parallel
                             tuning backends).

Accounting — steps, simulated wall-clock, per-step trace, best-so-far — is
the paper's primary metric and must be identical across evaluators, so it
lives in one place: ``EvalAccount``.  Searchers and the experiment harness
read it through public accessors (``steps``, ``trace``, ``history()``) and
never through evaluator internals.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set, Tuple, Union

from repro.core.counters import CounterSet
from repro.core.tuning_space import TuningSpace


class ProfilingUnsupported(RuntimeError):
    """Raised by evaluators that cannot collect performance counters."""


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One proposed empirical test: which config, and whether to profile."""

    index: int
    profile: bool = False


@dataclasses.dataclass(frozen=True)
class Observation:
    """Result of one empirical test, as delivered back to a searcher."""

    index: int
    runtime: float
    counters: Optional[CounterSet] = None   # present iff the test was profiled
    step: int = 0                           # evaluator step count after this test
    elapsed: float = 0.0                    # simulated tuning wall-clock so far


class EvalAccount:
    """Steps / elapsed / trace / best bookkeeping shared by all evaluators.

    ``trace`` is the paper's convergence record: (steps, elapsed, runtime)
    per empirical test.  ``history`` is the per-test (index, runtime) log in
    measurement order — the public replacement for peeking at private caches.
    """

    def __init__(self) -> None:
        self.steps: int = 0
        self.elapsed: float = 0.0
        self.trace: List[Tuple[int, float, float]] = []
        self.history: List[Tuple[int, float]] = []
        self.evaluated: Set[int] = set()
        self.best_runtime: float = float("inf")
        self.best_index: Optional[int] = None

    def record(self, idx: int, runtime: float, cost: float) -> None:
        self.steps += 1
        self.elapsed += cost
        self.evaluated.add(idx)
        if runtime < self.best_runtime:
            self.best_runtime = runtime
            self.best_index = idx
        self.trace.append((self.steps, self.elapsed, runtime))
        self.history.append((idx, runtime))


class Evaluator:
    """Base class implementing the shared protocol over one ``_evaluate``.

    Subclasses implement ``_evaluate(idx, profiled) -> (runtime, counters,
    cost)`` where ``cost`` is the simulated (or real) wall-clock charged to
    this empirical test and ``counters`` may be None for unprofiled tests.
    """

    def __init__(self, space: TuningSpace):
        self.space = space
        self.account = EvalAccount()

    # -- accounting accessors (read-only views over the account) ---------------
    @property
    def steps(self) -> int:
        return self.account.steps

    @property
    def elapsed(self) -> float:
        return self.account.elapsed

    @property
    def trace(self) -> List[Tuple[int, float, float]]:
        return self.account.trace

    @property
    def evaluated(self) -> Set[int]:
        return self.account.evaluated

    @property
    def best_runtime(self) -> float:
        return self.account.best_runtime

    @property
    def best_index(self) -> Optional[int]:
        return self.account.best_index

    def history(self) -> List[Tuple[int, float]]:
        """Per-test (config index, runtime) in measurement order."""
        return list(self.account.history)

    def __len__(self) -> int:
        return len(self.space)

    def exhausted(self) -> bool:
        return len(self.account.evaluated) >= len(self.space)

    # -- the protocol ----------------------------------------------------------
    def _evaluate(
        self, idx: int, profiled: bool
    ) -> Tuple[float, Optional[CounterSet], float]:
        raise NotImplementedError

    def measure(self, idx: int) -> float:
        """Empirical test without counter collection (fast)."""
        rt, _, cost = self._evaluate(int(idx), False)
        self.account.record(int(idx), rt, cost)
        return rt

    def profile(self, idx: int) -> CounterSet:
        """Empirical test with counter collection (slow: multi-pass replay)."""
        rt, cs, cost = self._evaluate(int(idx), True)
        if cs is None:
            raise ProfilingUnsupported(
                f"{type(self).__name__} cannot collect performance counters")
        self.account.record(int(idx), rt, cost)
        return cs

    def measure_many(
        self, candidates: Sequence[Union[Candidate, int]]
    ) -> List[Observation]:
        """Evaluate a candidate batch; the extension point for parallelism."""
        out: List[Observation] = []
        for c in candidates:
            if not isinstance(c, Candidate):
                c = Candidate(int(c))
            if c.profile:
                cs = self.profile(c.index)
                rt = cs.runtime
            else:
                rt = self.measure(c.index)
                cs = None
            out.append(Observation(index=c.index, runtime=rt, counters=cs,
                                   step=self.steps, elapsed=self.elapsed))
        return out
