"""Shared evaluator protocol: the ask-tell side of empirical testing.

Every evaluator in the system — replayed records (``ReplayEvaluator``), the
virtual-TPU cost model (``CostModelEvaluator``), real compiles
(``step_tuner.CompiledStepEvaluator``) and timed callables
(``FunctionEvaluator``) — answers the same three questions:

  * ``measure(idx)``       — empirical test, runtime only (fast path);
  * ``profile(idx)``       — empirical test with performance counters
                             (slow path; optional — counter-less evaluators
                             raise ``ProfilingUnsupported``);
  * ``measure_many(batch)`` — evaluate a batch of ``Candidate``s, returning
                             ``Observation``s (the hook for async/parallel
                             tuning backends);
  * ``submit(batch)`` /
    ``collect()``           — the asynchronous form of the same protocol:
                             ``submit`` hands candidates to the evaluator
                             without waiting, ``collect`` returns finished
                             ``Observation``s (possibly out of submission
                             order).  The base class provides a synchronous
                             shim (submit queues, collect evaluates), so
                             every existing evaluator is already a valid —
                             if serial — async backend.

Accounting — steps, simulated wall-clock, per-step trace, best-so-far — is
the paper's primary metric and must be identical across evaluators, so it
lives in one place: ``EvalAccount``.  Searchers and the experiment harness
read it through public accessors (``steps``, ``trace``, ``history()``) and
never through evaluator internals.

Cost accounting under concurrency: ``elapsed`` is the completion-time
frontier (the wall-clock at which the latest finished test completed) and
``busy`` is the sum of per-test costs (worker-seconds).  A sequential
evaluator records through ``record`` where the two coincide; concurrent
backends record through ``record_completion`` in completion order, so the
trace stays sorted by *when results became known* — which is what
best-so-far convergence curves must be ordered by — rather than by
submission order.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set, Tuple, Union

from repro.core.counters import CounterSet
from repro.core.tuning_space import TuningSpace


class ProfilingUnsupported(RuntimeError):
    """Raised by evaluators that cannot collect performance counters."""


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One proposed empirical test: which config, and whether to profile."""

    index: int
    profile: bool = False


@dataclasses.dataclass(frozen=True)
class Observation:
    """Result of one empirical test, as delivered back to a searcher."""

    index: int
    runtime: float
    counters: Optional[CounterSet] = None   # present iff the test was profiled
    step: int = 0                           # evaluator step count after this test
    elapsed: float = 0.0                    # simulated tuning wall-clock so far


@dataclasses.dataclass(frozen=True)
class Ticket:
    """Receipt for a submitted-but-not-yet-collected empirical test."""

    uid: int
    candidate: Candidate


@dataclasses.dataclass(frozen=True)
class AccountSnapshot:
    """Point-in-time (or delta) view of an ``EvalAccount``'s meters.

    ``EvalAccount.snapshot()`` freezes the current counters;
    ``EvalAccount.diff(since)`` subtracts an earlier snapshot, giving the
    steps / worker-seconds / abandoned cost accrued *between* the two — the
    metering primitive a multi-tenant scheduler charges budgets with
    (abandoned cost is part of ``busy``, so discarded attempts are billed
    too).  ``best_runtime``/``best_index`` are not deltas: they reflect the
    account's state at snapshot time.
    """

    steps: int
    elapsed: float
    busy: float              # worker-seconds (includes abandoned)
    abandoned: float         # worker-seconds of discarded attempts
    abandoned_count: int
    best_runtime: float
    best_index: Optional[int]


class EvalAccount:
    """Steps / elapsed / trace / best bookkeeping shared by all evaluators.

    ``trace`` is the paper's convergence record: (steps, elapsed, runtime)
    per empirical test.  ``history`` is the per-test (index, runtime) log in
    measurement order — the public replacement for peeking at private caches.
    """

    def __init__(self) -> None:
        self.steps: int = 0
        self.elapsed: float = 0.0
        self.busy: float = 0.0
        self.abandoned: float = 0.0       # worker-seconds of discarded work
        self.abandoned_count: int = 0     # discarded attempts
        self.trace: List[Tuple[int, float, float]] = []
        self.history: List[Tuple[int, float]] = []
        self.evaluated: Set[int] = set()
        self.best_runtime: float = float("inf")
        self.best_index: Optional[int] = None

    def _note(self, idx: int, runtime: float) -> None:
        self.evaluated.add(idx)
        if runtime < self.best_runtime:
            self.best_runtime = runtime
            self.best_index = idx
        self.history.append((idx, runtime))

    def record(self, idx: int, runtime: float, cost: float) -> None:
        """Sequential completion: the clock advances by the test's cost."""
        self.steps += 1
        self.elapsed += cost
        self.busy += cost
        self._note(idx, runtime)
        self.trace.append((self.steps, self.elapsed, runtime))

    def record_completion(self, idx: int, runtime: float, cost: float,
                          finished_at: float) -> None:
        """Concurrent completion at wall-clock ``finished_at``.

        Must be called in completion order (collect() guarantees this): the
        trace then stays sorted by when each result became known, so
        best-so-far curves are correct even when tests finish out of
        submission order.  ``elapsed`` advances to the completion frontier;
        ``cost`` accrues to ``busy`` (worker-seconds) only — under
        ``k``-way concurrency the wall-clock is NOT the sum of costs.
        """
        self.steps += 1
        self.elapsed = max(self.elapsed, float(finished_at))
        self.busy += cost
        self._note(idx, runtime)
        self.trace.append((self.steps, float(finished_at), runtime))

    def snapshot(self) -> AccountSnapshot:
        """Freeze the current meters (cheap; no trace/history copies)."""
        return AccountSnapshot(
            steps=self.steps, elapsed=self.elapsed, busy=self.busy,
            abandoned=self.abandoned, abandoned_count=self.abandoned_count,
            best_runtime=self.best_runtime, best_index=self.best_index)

    def diff(self, since: Optional[AccountSnapshot] = None
             ) -> AccountSnapshot:
        """Meters accrued since ``since`` (``None``: since creation).

        Counter fields (``steps``, ``busy``, ``abandoned``, ...) subtract;
        ``elapsed`` is the frontier advance; ``best_runtime``/``best_index``
        are the CURRENT values, not deltas.  This is how a tenant manager
        meters per-request worker-seconds off a live job account without
        monkeypatching the recording hooks — and because abandoned cost
        accrues into ``busy``, discarded attempts are charged too.
        """
        if since is None:
            return self.snapshot()
        return AccountSnapshot(
            steps=self.steps - since.steps,
            elapsed=self.elapsed - since.elapsed,
            busy=self.busy - since.busy,
            abandoned=self.abandoned - since.abandoned,
            abandoned_count=self.abandoned_count - since.abandoned_count,
            best_runtime=self.best_runtime, best_index=self.best_index)

    def record_abandoned(self, cost: float) -> None:
        """Work that was started and then discarded — a failed attempt
        that will be retried, or a straggler timed out and resubmitted
        elsewhere.  The worker-seconds were genuinely burned, so they
        accrue to ``busy`` (anything else under-reports the fleet's true
        cost), but the measurement produced no usable result: no step, no
        trace row, no best/history update.
        """
        self.busy += float(cost)
        self.abandoned += float(cost)
        self.abandoned_count += 1


class Evaluator:
    """Base class implementing the shared protocol over one ``_evaluate``.

    Subclasses implement ``_evaluate(idx, profiled) -> (runtime, counters,
    cost)`` where ``cost`` is the simulated (or real) wall-clock charged to
    this empirical test and ``counters`` may be None for unprofiled tests.
    """

    def __init__(self, space: TuningSpace):
        self.space = space
        self.account = EvalAccount()
        self._pending: List[Ticket] = []    # submitted, not yet collected
        self._ticket_uid = 0

    # -- accounting accessors (read-only views over the account) ---------------
    @property
    def steps(self) -> int:
        return self.account.steps

    @property
    def elapsed(self) -> float:
        return self.account.elapsed

    @property
    def busy(self) -> float:
        return self.account.busy

    @property
    def trace(self) -> List[Tuple[int, float, float]]:
        return self.account.trace

    @property
    def evaluated(self) -> Set[int]:
        return self.account.evaluated

    @property
    def best_runtime(self) -> float:
        return self.account.best_runtime

    @property
    def best_index(self) -> Optional[int]:
        return self.account.best_index

    def history(self) -> List[Tuple[int, float]]:
        """Per-test (config index, runtime) in measurement order."""
        return list(self.account.history)

    def __len__(self) -> int:
        return len(self.space)

    def exhausted(self) -> bool:
        return len(self.account.evaluated) >= len(self.space)

    # -- the protocol ----------------------------------------------------------
    def _evaluate(
        self, idx: int, profiled: bool
    ) -> Tuple[float, Optional[CounterSet], float]:
        raise NotImplementedError

    def measure(self, idx: int) -> float:
        """Empirical test without counter collection (fast)."""
        rt, _, cost = self._evaluate(int(idx), False)
        self.account.record(int(idx), rt, cost)
        return rt

    def profile(self, idx: int) -> CounterSet:
        """Empirical test with counter collection (slow: multi-pass replay)."""
        rt, cs, cost = self._evaluate(int(idx), True)
        if cs is None:
            raise ProfilingUnsupported(
                f"{type(self).__name__} cannot collect performance counters")
        self.account.record(int(idx), rt, cost)
        return cs

    def measure_many(
        self, candidates: Sequence[Union[Candidate, int]]
    ) -> List[Observation]:
        """Evaluate a candidate batch; the extension point for parallelism."""
        out: List[Observation] = []
        for c in candidates:
            if not isinstance(c, Candidate):
                c = Candidate(int(c))
            if c.profile:
                cs = self.profile(c.index)
                rt = cs.runtime
            else:
                rt = self.measure(c.index)
                cs = None
            out.append(Observation(index=c.index, runtime=rt, counters=cs,
                                   step=self.steps, elapsed=self.elapsed))
        return out

    # -- asynchronous protocol (default synchronous shim) ----------------------
    def submit(self, candidates: Sequence[Union[Candidate, int]]
               ) -> List[Ticket]:
        """Hand candidates to the evaluator without waiting for results.

        The base implementation only queues them; real async backends
        override submit/collect to start work immediately.  Either way the
        contract is the same: every submitted candidate is eventually
        returned by ``collect`` exactly once, and accounting happens at
        collection (completion) time.
        """
        tickets = []
        for c in candidates:
            if not isinstance(c, Candidate):
                c = Candidate(int(c))
            t = Ticket(uid=self._ticket_uid, candidate=c)
            self._ticket_uid += 1
            self._pending.append(t)
            tickets.append(t)
        return tickets

    def collect(self, timeout: Optional[float] = None) -> List[Observation]:
        """Return finished observations for submitted candidates.

        The synchronous shim evaluates everything pending, in submission
        order, right now — which makes ``submit``+``collect`` through this
        shim bit-identical to ``measure_many`` (and hence the ``in_flight=1``
        event-driven driver bit-identical to the sequential one).  Async
        backends instead block up to ``timeout`` for at least one completion
        and return observations in completion order.
        """
        pending, self._pending = self._pending, []
        return self.measure_many([t.candidate for t in pending])

    def outstanding(self) -> int:
        """Number of submitted-but-not-yet-collected empirical tests."""
        return len(self._pending)
