"""Expert system, part 2: bottlenecks → ΔPC_ops (paper §3.5.2, Eq. 15).

Produces the required-change vector ΔPC_ops over PC_ops counters, each in
[-1, 1]: negative = decrease this counter, positive = increase, 0 = no change.

``inst_reaction`` thresholds instruction-related reactions: instructions have
low latency and only become a real bottleneck under high stress (paper sets
0.7 by default, 0.5 when the user declares the problem instruction-bound).
"""
from __future__ import annotations

from typing import Dict

from repro.core import bottleneck as B
from repro.core import counters as C

INST_REACTION_DEFAULT = 0.7
INST_REACTION_COMPUTE_BOUND = 0.5


def _inst_delta(b_val: float, inst_reaction: float) -> float:
    """Eq. 15: thresholded reaction to an instruction bottleneck."""
    if b_val <= inst_reaction:
        return 0.0
    return -(b_val - inst_reaction) / (1.0 - inst_reaction)


def compute_delta_pc(
    b: Dict[str, float], inst_reaction: float = INST_REACTION_DEFAULT
) -> Dict[str, float]:
    """Map the bottleneck vector to required PC_ops changes.

    Memory-subsystem reactions are the inverted bottleneck values
    (straightforward per §3.5.2); instruction reactions are thresholded
    (Eq. 15); parallelism reactions are positive (more programs wanted).
    The paper emits Δpc_SM_E and Δpc_global(threads); both map to our GRID
    pseudo-counter (grid programs are the TPU parallelism unit and are
    statically known, so the "model prediction" of GRID is exact).
    """
    delta: Dict[str, float] = {k: 0.0 for k in C.PC_OPS}

    # memory subsystems — straight inversion
    delta[C.HBM_RD] = -b[B.B_HBM_READ]
    delta[C.HBM_WR] = -b[B.B_HBM_WRITE]
    delta[C.VMEM_RD] = -b[B.B_VMEM_READ]
    delta[C.VMEM_WR] = -b[B.B_VMEM_WRITE]
    delta[C.CMEM_RD] = -b[B.B_CMEM]
    delta[C.SPILL_B] = -b[B.B_SPILL]
    # spilling is caused by per-program working set: also push VMEM_WS down
    delta[C.VMEM_WS] = -b[B.B_SPILL]
    delta[C.ICI_B] = -b[B.B_ICI]

    # instruction-related — thresholded (Eq. 15)
    delta[C.MXU_FLOPS] = _inst_delta(b[B.B_MXU], inst_reaction)
    delta[C.VPU_OPS] = _inst_delta(b[B.B_VPU], inst_reaction)
    delta[C.TRANS_OPS] = _inst_delta(b[B.B_TRANS], inst_reaction)
    delta[C.ISSUE_OPS] = _inst_delta(b[B.B_ISSUE], inst_reaction)

    # parallelism — positive reaction (paper: Δpc_SM_E = b_sm, Δpc_global =
    # b_paral); GRID absorbs both, saturating at 1.
    delta[C.GRID] = min(1.0, b[B.B_CORE] + b[B.B_PARAL])

    return delta
