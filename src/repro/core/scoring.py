"""Configuration scoring (paper §3.6, Eqs. 16-17).

Scores unexplored configurations by whether the model predicts they move
PC_ops in the direction required by ΔPC_ops, then normalizes scores into
<0.0001, 256> for weighted random selection.

Sign convention note: paper Eq. 16 as printed reads
``Δpc_p · (pc_p(c_profile) − pc_p(c_candidate)) / (pc_p(c_profile) + pc_p(c_candidate))``
which, with Δpc < 0 meaning "decrease", would *penalize* candidates that
decrease the counter.  The text's intent (§3.6: "set higher scores to
configurations which are predicted to change PC_ops in the required way")
requires the candidate-minus-profile orientation, which we use:
score contribution = Δpc_p · (cand − prof)/(cand + prof)  — positive when the
predicted change matches the required direction.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence

import numpy as np

# Eq. 17 constants
GAMMA = -0.25        # cutoff threshold
EXPONENT = 8
FLOOR = 1e-4
CEIL = 256.0


def score_configuration(
    delta_pc: Dict[str, float],
    pc_profile: Dict[str, float],
    pc_candidate: Dict[str, float],
) -> float:
    """Raw score s of one candidate (Eq. 16).

    Only counters with non-zero predictions for both configurations are used
    (PC_used in the paper).
    """
    s = 0.0
    for name, dpc in delta_pc.items():
        if dpc == 0.0:
            continue
        p = float(pc_profile.get(name, 0.0))
        c = float(pc_candidate.get(name, 0.0))
        if p == 0.0 or c == 0.0:
            continue  # outside PC_used
        s += dpc * (c - p) / (c + p)
    return s


def score_space(
    delta_pc: Dict[str, float],
    pc_profile: np.ndarray,
    pred_matrix: np.ndarray,
    counter_index: Mapping[str, int],
    pc_used_mask: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Eq. 16 for EVERY configuration at once (Algorithm 1 l.7).

    ``pred_matrix`` is a model's ``predict_matrix`` output (n_configs ×
    n_counters, columns named by ``counter_index``), ``pc_profile`` the row of
    the profiled configuration, and ``pc_used_mask`` an optional precomputed
    ``pred_matrix != 0`` (the PC_used membership — it only depends on the
    model, so searchers compute it once per search, not per profiling step).

    Accumulates per counter in ``delta_pc`` iteration order with masked
    contributions forced to 0.0, so the result is bit-for-bit what a
    ``score_configuration`` loop over the space produces — the vectorized
    searcher replays the scalar searcher's traces exactly.
    """
    if pc_used_mask is None:
        pc_used_mask = pred_matrix != 0.0
    s = np.zeros(pred_matrix.shape[0], dtype=np.float64)
    for name, dpc in delta_pc.items():
        if dpc == 0.0:
            continue
        j = counter_index.get(name)
        if j is None:
            continue  # counter not modeled: prediction 0 -> outside PC_used
        p = float(pc_profile[j])
        if p == 0.0:
            continue
        c = pred_matrix[:, j]
        with np.errstate(divide="ignore", invalid="ignore"):
            s += np.where(pc_used_mask[:, j], dpc * (c - p) / (c + p), 0.0)
    return s


def normalize_scores(scores: Sequence[float]) -> np.ndarray:
    """Eq. 17: map raw scores into <0.0001, 256> selection weights.

    Positive scores are amplified into <1, 256>; negative scores above the
    cutoff γ retain small non-zero probability (escape hatch from local
    optima / model error §3.6); scores at or below γ get the floor weight.
    """
    s = np.asarray(scores, dtype=np.float64)
    out = np.full(s.shape, FLOOR)
    if s.size == 0:
        return out
    s_max = float(s.max())
    s_min = float(s.min())

    pos = s > 0.0
    if s_max > 0.0:
        out[pos] = np.power(1.0 + s[pos] / s_max, EXPONENT)
    else:
        out[pos] = 1.0  # unreachable when s_max <= 0, kept for safety

    mid = (~pos) & (s > GAMMA)
    if s_min < 0.0:
        out[mid] = np.maximum(FLOOR, np.power(1.0 - s[mid] / s_min, EXPONENT))
    else:
        out[mid] = 1.0  # all-zero scores: uniform weight

    # s <= GAMMA stays at FLOOR
    return np.clip(out, FLOOR, CEIL)


def weighted_choice(
    weights: np.ndarray, rng: np.random.Generator, mask: np.ndarray
) -> int:
    """Sample an index with probability ∝ weight among mask==True entries.

    Mirrors Algorithm 1 lines 17-18 (already-evaluated entries carry weight 0
    via the mask).
    """
    w = np.where(mask, weights, 0.0)
    tot = w.sum()
    if tot <= 0.0:
        # nothing scoreable left — uniform over the mask
        idxs = np.flatnonzero(mask)
        if idxs.size == 0:
            raise RuntimeError("no unexplored configurations left")
        return int(rng.choice(idxs))
    # inlined ``rng.choice(len(w), p=w / tot)``: identical arithmetic and
    # identical rng-stream consumption (one ``random()`` draw), minus the
    # per-call probability re-validation — this runs once per biased step
    # over the whole space, so the O(n) constant matters.  Equivalence with
    # Generator.choice is pinned by tests/test_vectorized_golden.py.
    cdf = (w / tot).cumsum()
    cdf /= cdf[-1]
    return min(int(cdf.searchsorted(rng.random(), side="right")), len(w) - 1)
