"""N-body gravitational acceleration Pallas TPU kernel (paper benchmark).

a_i = Σ_j G·m_j·(p_j − p_i) / (|p_j − p_i|² + ε²)^{3/2}

One program owns a (BLOCK_I, 4) tile of bodies and accumulates accelerations
while marching over all bodies in (BLOCK_J, 4) tiles on a sequential grid
dimension — the classic compute-bound O(N²) kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import cdiv


def _nbody_kernel(
    bi_ref, bj_ref, out_ref, acc_ref, *,
    j_steps: int, n_bodies: int, block_j: int, softening: float,
):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bi = bi_ref[...]  # (BI, 4): x, y, z, m
    j_idx = pl.program_id(1) * block_j + jax.lax.broadcasted_iota(
        jnp.int32, (block_j,), 0
    )
    # zero the whole tail tile: padded rows hold undefined values (NaN in
    # interpret mode) and even mass-masked NaN positions would poison s*dx
    bj = jnp.where((j_idx < n_bodies)[:, None], bj_ref[...], 0.0)
    mj = bj[:, 3]

    # pairwise displacement: (BI, BJ)
    dx = bj[None, :, 0] - bi[:, None, 0]
    dy = bj[None, :, 1] - bi[:, None, 1]
    dz = bj[None, :, 2] - bi[:, None, 2]
    r2 = dx * dx + dy * dy + dz * dz + softening
    inv_r = jax.lax.rsqrt(r2)
    s = mj[None, :] * inv_r * inv_r * inv_r  # (BI, BJ)

    ax = jnp.sum(s * dx, axis=1)
    ay = jnp.sum(s * dy, axis=1)
    az = jnp.sum(s * dz, axis=1)
    acc_ref[...] += jnp.stack([ax, ay, az, jnp.zeros_like(ax)], axis=1)

    @pl.when(pl.program_id(1) == j_steps - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_i", "block_j", "softening", "interpret"),
)
def nbody(
    bodies: jax.Array,  # (N, 4) float32: x, y, z, mass
    *,
    block_i: int = 256,
    block_j: int = 256,
    softening: float = 1e-3,
    interpret: bool = False,
) -> jax.Array:
    n = bodies.shape[0]
    j_steps = cdiv(n, block_j)
    grid = (cdiv(n, block_i), j_steps)
    return pl.pallas_call(
        functools.partial(
            _nbody_kernel, j_steps=j_steps, n_bodies=n, block_j=block_j,
            softening=softening,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, 4), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 4), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_i, 4), jnp.float32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bodies, bodies)
