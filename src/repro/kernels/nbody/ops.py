"""Jit'd wrapper: tuning-config dict -> N-body kernel invocation."""
from repro.kernels.nbody.kernel import nbody


def run(cfg, bodies, interpret: bool = True):
    return nbody(bodies, block_i=cfg["BLOCK_I"], block_j=cfg["BLOCK_J"],
                 interpret=interpret)
