"""N-body tuning space + portable workload model."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import counters as C
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.kernels.common import cdiv, round_up


@dataclasses.dataclass(frozen=True)
class NBodyInput:
    n: int

    @property
    def tag(self) -> str:
        return f"n{self.n}"


DEFAULT_INPUT = NBodyInput(16384)
LARGE_INPUT = NBodyInput(131072)


def make_space() -> TuningSpace:
    params = [
        TuningParameter("BLOCK_I", (8, 16, 32, 64, 128, 256, 512, 1024)),
        TuningParameter("BLOCK_J", (32, 64, 128, 256, 512, 1024, 2048)),
        TuningParameter("J_UNROLL", (1, 2, 4)),
        # recompute r² vs keep (BI,BJ) temporaries resident (register pressure)
        TuningParameter("KEEP_PAIRWISE", (0, 1)),
    ]
    return TuningSpace(params, name="nbody")


def workload_fn(cfg: Config, inp: NBodyInput = DEFAULT_INPUT) -> Dict[str, float]:
    n = inp.n
    bi, bj = cfg["BLOCK_I"], cfg["BLOCK_J"]
    unroll, keep = cfg["J_UNROLL"], cfg["KEEP_PAIRWISE"]
    ni, nj = cdiv(n, bi), cdiv(n, bj)
    pairs = (ni * bi) * (nj * bj)  # padded pairwise interactions

    # ~14/17 VPU ops per pair (displacements, r², 3 MACs per axis) + 1 rsqrt;
    # the tap loop costs control ops unless unrolled
    vpu = pairs * (14.0 if keep else 17.0) + pairs * 3.0 / max(unroll, 1)
    trans = pairs * 1.0
    # body tiles: i tile read once, j tiles streamed per i block
    hbm_rd = (ni * bi * 16.0) + ni * nj * bj * 16.0
    hbm_wr = ni * bi * 16.0
    # (BI, BJ) intermediates (dx/dy/dz/r2/s) round-trip VMEM between VPU ops
    # unless kept fused; unrolling improves fusion of the streamed variant
    n_tmp = 5.0 if keep else 8.0 * (1.0 + 0.6 / max(unroll, 1))
    vmem_rd = pairs * 4.0 * n_tmp
    vmem_wr = ni * nj * bi * 16.0 + pairs * 4.0 * n_tmp * 0.5
    ws = (bi * 16.0 + bj * 16.0) * 2.0 + bi * 16.0 \
        + (bi * bj * 4.0 * 4.0 if keep else bi * bj * 4.0) \
        + bi * bj * 4.0 * 0.25 * (unroll - 1)

    # pairwise tiles are (BI, BJ) on the VPU: (8, 128) alignment + edge waste
    tile_eff = (bi / round_up(bi, 8)) * (bj / round_up(bj, 128))
    edge_eff = (n / (ni * bi)) * (n / (nj * bj))

    return {
        C.MXU_FLOPS: 0.0,
        C.VPU_OPS: float(vpu),
        C.TRANS_OPS: float(trans),
        C.ISSUE_OPS: float(vpu + trans),
        C.HBM_RD: float(hbm_rd),
        C.HBM_WR: float(hbm_wr),
        C.VMEM_RD: float(vmem_rd),
        C.VMEM_WR: float(vmem_wr),
        C.CMEM_RD: 0.0,
        C.GRID: float(ni),
        C.VMEM_WS: float(ws),
        "LANE_E_HINT": tile_eff * edge_eff,
    }
