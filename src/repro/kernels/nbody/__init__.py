import jax.numpy as jnp
import numpy as np

from repro.kernels.nbody.kernel import nbody
from repro.kernels.nbody.ref import nbody_ref
from repro.kernels.nbody.space import make_space, workload_fn, DEFAULT_INPUT
from repro.kernels.registry import KernelBenchmark, register_benchmark


def _make_args(inp, rng):
    b = rng.standard_normal((inp.n, 4)).astype(np.float32)
    b[:, 3] = np.abs(b[:, 3]) + 0.1
    return (jnp.asarray(b),)


@register_benchmark("nbody")
def _benchmark() -> KernelBenchmark:
    from repro.kernels.nbody import ops, space

    return KernelBenchmark(
        name="nbody",
        make_space=space.make_space,
        workload_fn=space.workload_fn,
        default_input=space.DEFAULT_INPUT,
        inputs={
            "16k": space.DEFAULT_INPUT,
            "131k": space.LARGE_INPUT,
        },
        make_args=_make_args, run=ops.run, ref=nbody_ref,
    )
