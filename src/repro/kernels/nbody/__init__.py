from repro.kernels.nbody.kernel import nbody
from repro.kernels.nbody.ref import nbody_ref
from repro.kernels.nbody.space import make_space, workload_fn, DEFAULT_INPUT
