"""Pure-jnp oracle for the N-body acceleration kernel."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("softening",))
def nbody_ref(bodies: jax.Array, *, softening: float = 1e-3) -> jax.Array:
    pos, mass = bodies[:, :3], bodies[:, 3]
    d = pos[None, :, :] - pos[:, None, :]           # (N, N, 3)
    r2 = jnp.sum(d * d, axis=-1) + softening        # (N, N)
    inv_r = jax.lax.rsqrt(r2)
    s = mass[None, :] * inv_r * inv_r * inv_r
    acc = jnp.sum(s[:, :, None] * d, axis=1)        # (N, 3)
    return jnp.concatenate([acc, jnp.zeros((bodies.shape[0], 1))], axis=1)
