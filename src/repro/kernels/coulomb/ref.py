"""Pure-jnp oracle for Direct Coulomb Summation (paper Eq. 1)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("grid_size", "spacing"))
def coulomb_ref(atoms: jax.Array, *, grid_size: int,
                spacing: float = 0.5) -> jax.Array:
    gs = grid_size
    zs = jnp.arange(gs, dtype=jnp.float32) * spacing
    ys = jnp.arange(gs, dtype=jnp.float32) * spacing
    xs = jnp.arange(gs, dtype=jnp.float32) * spacing
    fz, fy, fx = jnp.meshgrid(zs, ys, xs, indexing="ij")

    def body(carry, atom):
        ax, ay, az, w = atom[0], atom[1], atom[2], atom[3]
        r2 = (fx - ax) ** 2 + (fy - ay) ** 2 + (fz - az) ** 2
        return carry + w * jax.lax.rsqrt(jnp.maximum(r2, 1e-12)), None

    out, _ = jax.lax.scan(body, jnp.zeros((gs, gs, gs), jnp.float32), atoms)
    return out
