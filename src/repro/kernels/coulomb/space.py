"""Coulomb summation tuning space + portable workload model (paper §2).

The space mirrors the paper's 7-dimensional Coulomb 3D space in character:
z-coarsening (the worked example's Z_ITERATIONS), block shape, atom chunking,
and a binary scalar-memory placement for the atom table (the constant-memory
analog from §3.4.1's example — modeled in counters; the TPU kernel always
streams atom tiles, placement changes which port the traffic hits).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import counters as C
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.kernels.common import cdiv, round_up


@dataclasses.dataclass(frozen=True)
class CoulombInput:
    grid_size: int
    n_atoms: int

    @property
    def tag(self) -> str:
        return f"g{self.grid_size}_a{self.n_atoms}"


DEFAULT_INPUT = CoulombInput(256, 256)
LARGE_GRID = CoulombInput(256, 64)
SMALL_GRID = CoulombInput(32, 4096)


def make_space() -> TuningSpace:
    params = [
        TuningParameter("Z_IT", (1, 2, 4, 8, 16, 32, 64)),
        TuningParameter("BY", (4, 8, 16, 32, 64)),
        TuningParameter("BX", (64, 128, 256, 512, 1024)),
        TuningParameter("ATOM_CHUNK", (4, 16, 64, 256)),
        TuningParameter("ATOMS_IN_SMEM", (0, 1)),
    ]

    def block_fits_grid(cfg: Config) -> bool:
        # expert pruning: z-coarsening cannot exceed typical grid extents
        return cfg["Z_IT"] * cfg["BY"] <= 512

    return TuningSpace(params, constraints=[block_fits_grid], name="coulomb")


def workload_fn(cfg: Config, inp: CoulombInput = DEFAULT_INPUT) -> Dict[str, float]:
    gs, na = inp.grid_size, inp.n_atoms
    z, by, bx = cfg["Z_IT"], cfg["BY"], cfg["BX"]
    chunk = cfg["ATOM_CHUNK"]
    smem = cfg["ATOMS_IN_SMEM"]

    nz, ny, nx = cdiv(gs, z), cdiv(gs, by), cdiv(gs, bx)
    progs = nz * ny * nx
    pts_padded = (nz * z) * (ny * by) * (nx * bx)  # padded grid points

    # per point-atom pair: dz/r2 (4 ops) + w*rinv accumulate (2 ops);
    # dx,dy invariant across the z loop — amortized by coarsening (paper §2.2)
    vpu = pts_padded * na * 6.0 + pts_padded * na * 5.0 / z
    trans = pts_padded * na * 1.0  # rsqrt
    # atom table re-read once per program per chunk pass
    atom_bytes = progs * round_up(na, chunk) * 16.0
    hbm_rd = 0.0 if smem else atom_bytes
    cmem_rd = atom_bytes if smem else 0.0
    hbm_wr = pts_padded * 4.0
    # atom broadcast into the point tile re-reads the atom VMEM tile once per
    # z-group (register locality — the paper's texture-cache-traffic analog)
    # + (chunk, Z, BY, BX) intermediates round-tripping VMEM
    vmem_rd = atom_bytes + pts_padded * na * (8.0 + 16.0 / z)
    vmem_wr = pts_padded * 4.0 * cdiv(na, chunk)  # accumulator writeback/chunk

    ws = 2.0 * z * by * bx * 4.0 + chunk * 16.0 + 3.0 * z * by * bx * 4.0

    # lane efficiency: (BY, BX) maps to (8, 128) VREG tiling; grid-edge waste
    tile_eff = (by / round_up(by, 8)) * (bx / round_up(bx, 128))
    edge_eff = (gs / (nz * z)) * (gs / (ny * by)) * (gs / (nx * bx))
    lane_e = tile_eff * edge_eff

    return {
        C.MXU_FLOPS: 0.0,
        C.VPU_OPS: float(vpu),
        C.TRANS_OPS: float(trans),
        C.ISSUE_OPS: float(vpu + trans),
        C.HBM_RD: float(hbm_rd),
        C.HBM_WR: float(hbm_wr),
        C.VMEM_RD: float(vmem_rd),
        C.VMEM_WR: float(vmem_wr),
        C.CMEM_RD: float(cmem_rd),
        C.GRID: float(progs),
        C.VMEM_WS: float(ws),
        "LANE_E_HINT": lane_e,
    }
