"""Direct Coulomb Summation Pallas TPU kernel (paper §2 running example).

Electrostatic potential on a regular 3D grid: V_i = Σ_j w_j / r_ij.
One program computes a (Z_IT, BY, BX) block of grid points — Z_IT is the
thread-coarsening tuning parameter from the paper's Listing 1, mapped to TPU
grid-point coarsening along z (the register-locality trade-off is identical:
larger Z_IT reuses each atom across more grid points but grows the VMEM
accumulator and reduces program-level parallelism).

Atoms are processed in (ATOM_CHUNK, 4) tiles via a sequential grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import cdiv


def _coulomb_kernel(
    atoms_ref, out_ref, acc_ref, *,
    a_steps: int, n_atoms: int, atom_chunk: int,
    z_it: int, by: int, bx: int, spacing: float,
):
    z0 = pl.program_id(0) * z_it
    y0 = pl.program_id(1) * by
    x0 = pl.program_id(2) * bx

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # real-space coordinates of this block of grid points: (Z, BY, BX)
    fz = (z0 + jax.lax.broadcasted_iota(jnp.float32, (z_it, by, bx), 0)) * spacing
    fy = (y0 + jax.lax.broadcasted_iota(jnp.float32, (z_it, by, bx), 1)) * spacing
    fx = (x0 + jax.lax.broadcasted_iota(jnp.float32, (z_it, by, bx), 2)) * spacing

    # mask the whole atom-count tail tile: padded rows hold undefined values
    # (NaN in interpret mode) and would poison w * rinv even with w == 0
    a_idx = pl.program_id(3) * atom_chunk + jax.lax.broadcasted_iota(
        jnp.int32, (atom_chunk,), 0
    )
    atoms = jnp.where((a_idx < n_atoms)[:, None], atoms_ref[...], 0.0)
    w = atoms[:, 3]

    # broadcast (A, 1, 1, 1) against (Z, BY, BX): contributions (A, Z, BY, BX)
    dx = fx[None] - atoms[:, 0][:, None, None, None]
    dy = fy[None] - atoms[:, 1][:, None, None, None]
    dz = fz[None] - atoms[:, 2][:, None, None, None]
    r2 = dx * dx + dy * dy + dz * dz
    rinv = jax.lax.rsqrt(jnp.maximum(r2, 1e-12))
    acc_ref[...] += jnp.sum(w[:, None, None, None] * rinv, axis=0)

    @pl.when(pl.program_id(3) == a_steps - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("grid_size", "z_it", "by", "bx", "atom_chunk",
                     "spacing", "interpret"),
)
def coulomb(
    atoms: jax.Array,  # (n_atoms, 4) float32: x, y, z, w
    *,
    grid_size: int,
    z_it: int = 4,
    by: int = 8,
    bx: int = 128,
    atom_chunk: int = 32,
    spacing: float = 0.5,
    interpret: bool = False,
) -> jax.Array:
    n_atoms = atoms.shape[0]
    a_steps = cdiv(n_atoms, atom_chunk)
    gs = grid_size
    grid = (cdiv(gs, z_it), cdiv(gs, by), cdiv(gs, bx), a_steps)
    return pl.pallas_call(
        functools.partial(
            _coulomb_kernel, a_steps=a_steps, n_atoms=n_atoms,
            atom_chunk=atom_chunk, z_it=z_it, by=by, bx=bx, spacing=spacing,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((atom_chunk, 4), lambda z, y, x, a: (a, 0)),
        ],
        out_specs=pl.BlockSpec(
            (z_it, by, bx), lambda z, y, x, a: (z, y, x)
        ),
        out_shape=jax.ShapeDtypeStruct((gs, gs, gs), jnp.float32),
        scratch_shapes=[pltpu.VMEM((z_it, by, bx), jnp.float32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(atoms)
