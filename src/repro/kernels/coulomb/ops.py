"""Jit'd wrapper: tuning-config dict -> Coulomb kernel invocation."""
from repro.kernels.coulomb.kernel import coulomb


def run(cfg, atoms, *, grid_size: int, interpret: bool = True):
    return coulomb(atoms, grid_size=grid_size, z_it=cfg["Z_IT"],
                   by=cfg["BY"], bx=cfg["BX"], atom_chunk=cfg["ATOM_CHUNK"],
                   interpret=interpret)
