from repro.kernels.coulomb.kernel import coulomb
from repro.kernels.coulomb.ref import coulomb_ref
from repro.kernels.coulomb.space import make_space, workload_fn, DEFAULT_INPUT
