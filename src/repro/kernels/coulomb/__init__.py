import jax.numpy as jnp
import numpy as np

from repro.kernels.coulomb.kernel import coulomb
from repro.kernels.coulomb.ref import coulomb_ref
from repro.kernels.coulomb.space import make_space, workload_fn, DEFAULT_INPUT
from repro.kernels.registry import KernelBenchmark, register_benchmark


def _make_args(inp, rng):
    atoms = rng.uniform(0.0, inp.grid_size * 0.5,
                        (inp.n_atoms, 4)).astype(np.float32)
    atoms[:, 3] = rng.uniform(0.1, 1.0, inp.n_atoms)
    return (jnp.asarray(atoms),)


@register_benchmark("coulomb")
def _benchmark() -> KernelBenchmark:
    from repro.kernels.coulomb import ops, space

    return KernelBenchmark(
        name="coulomb",
        make_space=space.make_space,
        workload_fn=space.workload_fn,
        default_input=space.DEFAULT_INPUT,
        inputs={
            "default": space.DEFAULT_INPUT,
            "large_grid": space.LARGE_GRID,
            "small_grid": space.SMALL_GRID,
        },
        make_args=_make_args, run=ops.run, ref=coulomb_ref,
    )
