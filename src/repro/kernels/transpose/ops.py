"""Jit'd wrapper: tuning-config dict -> transpose kernel invocation."""
from repro.kernels.transpose.kernel import transpose


def run(cfg, x, interpret: bool = True):
    return transpose(x, block_m=cfg["BLOCK_M"], block_n=cfg["BLOCK_N"],
                     interpret=interpret)
