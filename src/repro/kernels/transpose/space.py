"""Transpose tuning space + portable workload model."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import counters as C
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.kernels.common import cdiv, round_up


@dataclasses.dataclass(frozen=True)
class TransposeInput:
    m: int
    n: int
    dtype_bytes: int = 4

    @property
    def tag(self) -> str:
        return f"{self.m}x{self.n}"


DEFAULT_INPUT = TransposeInput(8192, 8192)


def make_space() -> TuningSpace:
    params = [
        TuningParameter("BLOCK_M", (8, 16, 32, 64, 128, 256, 512, 1024)),
        TuningParameter("BLOCK_N", (8, 16, 32, 64, 128, 256, 512, 1024)),
        # staging the write tile through a second VMEM buffer (layout fixup)
        TuningParameter("STAGE_OUT", (0, 1)),
    ]
    return TuningSpace(params, name="transpose")


def workload_fn(cfg: Config, inp: TransposeInput = DEFAULT_INPUT) -> Dict[str, float]:
    m, n, db = inp.m, inp.n, inp.dtype_bytes
    bm, bn = cfg["BLOCK_M"], cfg["BLOCK_N"]
    nm, nn = cdiv(m, bm), cdiv(n, bn)
    stage = cfg["STAGE_OUT"]

    hbm = nm * nn * bm * bn * db  # padded tiles move padded bytes
    vmem = 2.0 * hbm + (hbm if stage else 0.0)
    # transpose itself runs on the VPU as sublane/lane shuffles; unaligned
    # tiles cost extra shuffle passes
    shuffle_passes = 1.0
    if bm % 8 or bn % 128:
        shuffle_passes = 2.0
    vpu = nm * nn * bm * bn * shuffle_passes
    ws = (2.0 + (1.0 if stage else 0.0)) * bm * bn * db

    # lane efficiency: both the read tile (bm, bn) and the write tile (bn, bm)
    # must map to the (8, 128) register tiling
    read_eff = (bm / round_up(bm, 8)) * (bn / round_up(bn, 128))
    write_eff = (bn / round_up(bn, 8)) * (bm / round_up(bm, 128))
    edge_eff = (m / round_up(m, bm)) * (n / round_up(n, bn))
    lane_e = min(read_eff, write_eff) * edge_eff

    return {
        C.MXU_FLOPS: 0.0,
        C.VPU_OPS: float(vpu),
        C.TRANS_OPS: 0.0,
        C.ISSUE_OPS: float(vpu),
        C.HBM_RD: float(hbm),
        C.HBM_WR: float(hbm),
        C.VMEM_RD: float(vmem),
        C.VMEM_WR: float(vmem),
        C.CMEM_RD: 0.0,
        C.GRID: float(nm * nn),
        C.VMEM_WS: float(ws),
        "LANE_E_HINT": lane_e,
    }
