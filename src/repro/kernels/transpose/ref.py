"""Pure-jnp oracle for the transpose kernel."""
import jax


@jax.jit
def transpose_ref(x: jax.Array) -> jax.Array:
    return x.T
