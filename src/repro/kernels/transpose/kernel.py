"""Tiled matrix transpose Pallas TPU kernel (paper benchmark: Transpose).

Memory-bound: each program stages a (BM, BN) tile through VMEM and writes the
transposed (BN, BM) tile.  The GPU original tunes shared-memory tiles and
padding (bank conflicts); the TPU analog tunes VMEM tile shape — sublane/lane
alignment of *both* the read and the write tile is the performance axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import cdiv


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def transpose(
    x: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    m, n = x.shape
    grid = (cdiv(m, block_m), cdiv(n, block_n))
    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x)
