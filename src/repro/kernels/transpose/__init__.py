from repro.kernels.transpose.kernel import transpose
from repro.kernels.transpose.ref import transpose_ref
from repro.kernels.transpose.space import make_space, workload_fn, DEFAULT_INPUT
