import jax.numpy as jnp
import numpy as np

from repro.kernels.transpose.kernel import transpose
from repro.kernels.transpose.ref import transpose_ref
from repro.kernels.transpose.space import make_space, workload_fn, DEFAULT_INPUT
from repro.kernels.registry import KernelBenchmark, register_benchmark


def _make_args(inp, rng):
    return (jnp.asarray(rng.standard_normal((inp.m, inp.n), dtype=np.float32)),)


@register_benchmark("transpose")
def _benchmark() -> KernelBenchmark:
    from repro.kernels.transpose import ops, space

    return KernelBenchmark(
        name="transpose",
        make_space=space.make_space,
        workload_fn=space.workload_fn,
        default_input=space.DEFAULT_INPUT,
        inputs={"8192": space.DEFAULT_INPUT},
        make_args=_make_args, run=ops.run, ref=transpose_ref,
    )
