"""Pallas TPU kernels for the paper's five benchmarks + flash attention.

Each subpackage: kernel.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
ops.py (config-dict dispatch wrapper), ref.py (pure-jnp oracle), space.py
(tuning space + portable workload counter model g(TP, I)).
"""
