"""2D convolution Pallas TPU kernel (paper benchmark: Convolution).

Stencil with halo: BlockSpec tiling cannot express overlapping reads, so the
input stays in HBM (``memory_space=ANY``) and each program DMAs its
(BY + F - 1, BX + F - 1) halo tile into VMEM scratch explicitly
(``pltpu.make_async_copy``) — the production TPU pattern for halo exchange.
The F×F filter is unrolled statically into shifted multiply-accumulates on
the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import cdiv


def _conv2d_kernel(
    img_ref,    # (H + F - 1, W + F - 1) in HBM/ANY — pre-padded by wrapper
    flt_ref,    # (F, F) in VMEM
    out_ref,    # (BY, BX) block in VMEM
    tile_ref,   # scratch: (BY + F - 1, BX + F - 1) VMEM
    sem,        # DMA semaphore
    *, by: int, bx: int, f: int, unroll_taps: bool,
):
    i, j = pl.program_id(0), pl.program_id(1)
    halo = f - 1
    copy = pltpu.make_async_copy(
        img_ref.at[pl.ds(i * by, by + halo), pl.ds(j * bx, bx + halo)],
        tile_ref,
        sem,
    )
    copy.start()
    copy.wait()

    if unroll_taps:
        acc = jnp.zeros((by, bx), jnp.float32)
        for dy in range(f):
            for dx in range(f):
                acc += flt_ref[dy, dx] * tile_ref[dy:dy + by, dx:dx + bx]
    else:
        def tap(t, acc):
            dy, dx = t // f, t % f
            w = flt_ref[dy, dx]
            patch = pl.load(
                tile_ref, (pl.ds(dy, by), pl.ds(dx, bx))
            )
            return acc + w * patch
        acc = jax.lax.fori_loop(0, f * f, tap, jnp.zeros((by, bx), jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("by", "bx", "unroll_taps", "interpret")
)
def conv2d(
    img: jax.Array,   # (H, W) float32
    flt: jax.Array,   # (F, F) float32, F odd
    *,
    by: int = 128,
    bx: int = 256,
    unroll_taps: bool = True,
    interpret: bool = False,
) -> jax.Array:
    h, w = img.shape
    f = flt.shape[0]
    assert flt.shape == (f, f) and f % 2 == 1
    halo = f - 1
    # pre-pad so every halo tile read is in bounds ("same" convolution)
    img_p = jnp.pad(img, ((halo // 2, cdiv(h, by) * by - h + halo // 2),
                          (halo // 2, cdiv(w, bx) * bx - w + halo // 2)))
    grid = (cdiv(h, by), cdiv(w, bx))
    return pl.pallas_call(
        functools.partial(_conv2d_kernel, by=by, bx=bx, f=f,
                          unroll_taps=unroll_taps),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),   # stays in HBM
            pl.BlockSpec((f, f), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((by, bx), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((by + halo, bx + halo), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(img_p, flt)
