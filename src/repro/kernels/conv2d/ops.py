"""Jit'd wrapper: tuning-config dict -> conv2d kernel invocation."""
from repro.kernels.conv2d.kernel import conv2d


def run(cfg, img, flt, interpret: bool = True):
    return conv2d(img, flt, by=cfg["BY"], bx=cfg["BX"],
                  unroll_taps=bool(cfg["UNROLL_TAPS"]), interpret=interpret)
