import jax.numpy as jnp
import numpy as np

from repro.kernels.conv2d.kernel import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.conv2d.space import make_space, workload_fn, DEFAULT_INPUT
from repro.kernels.registry import KernelBenchmark, register_benchmark


def _make_args(inp, rng):
    img = jnp.asarray(rng.standard_normal((inp.h, inp.w), dtype=np.float32))
    flt = jnp.asarray(rng.standard_normal((inp.f, inp.f), dtype=np.float32))
    return (img, flt)


@register_benchmark("conv2d")
def _benchmark() -> KernelBenchmark:
    from repro.kernels.conv2d import ops, space

    return KernelBenchmark(
        name="conv2d",
        make_space=space.make_space,
        workload_fn=space.workload_fn,
        default_input=space.DEFAULT_INPUT,
        inputs={"4096": space.DEFAULT_INPUT},
        make_args=_make_args, run=ops.run, ref=conv2d_ref,
    )
