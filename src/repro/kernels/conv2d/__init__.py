from repro.kernels.conv2d.kernel import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.conv2d.space import make_space, workload_fn, DEFAULT_INPUT
