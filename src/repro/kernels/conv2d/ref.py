"""Pure-jnp oracle for the 2D convolution kernel ("same" correlation)."""
import jax
import jax.numpy as jnp


@jax.jit
def conv2d_ref(img: jax.Array, flt: jax.Array) -> jax.Array:
    out = jax.lax.conv_general_dilated(
        img[None, None], flt[None, None],
        window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0, 0]
