"""Convolution tuning space + portable workload model."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import counters as C
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.kernels.common import cdiv, round_up


@dataclasses.dataclass(frozen=True)
class ConvInput:
    h: int
    w: int
    f: int = 5

    @property
    def tag(self) -> str:
        return f"{self.h}x{self.w}_f{self.f}"


DEFAULT_INPUT = ConvInput(4096, 4096)


def make_space() -> TuningSpace:
    params = [
        TuningParameter("BY", (8, 16, 32, 64, 128, 256, 512)),
        TuningParameter("BX", (128, 256, 512, 1024)),
        TuningParameter("UNROLL_TAPS", (0, 1)),
        # filter placement: VMEM-resident vs scalar-memory broadcast
        TuningParameter("FILTER_SMEM", (0, 1)),
        TuningParameter("DMA_DEPTH", (1, 2, 4)),
    ]
    return TuningSpace(params, name="conv2d")


def workload_fn(cfg: Config, inp: ConvInput = DEFAULT_INPUT) -> Dict[str, float]:
    h, w, f = inp.h, inp.w, inp.f
    by, bx = cfg["BY"], cfg["BX"]
    unroll, fsmem, depth = cfg["UNROLL_TAPS"], cfg["FILTER_SMEM"], cfg["DMA_DEPTH"]
    ny, nx = cdiv(h, by), cdiv(w, bx)
    progs = ny * nx
    halo = f - 1
    pts = progs * by * bx

    # halo tiles re-read the overlap region: DMA bytes per program
    tile_bytes = (by + halo) * (bx + halo) * 4.0
    hbm_rd = progs * tile_bytes + (0.0 if fsmem else progs * f * f * 4.0)
    cmem_rd = progs * f * f * 4.0 * by if fsmem else 0.0  # scalar broadcast/row
    hbm_wr = pts * 4.0
    vpu = pts * f * f * 2.0
    if not unroll:
        vpu += pts * f * f * 05e-1  # loop-control overhead on the tap loop
    vmem_rd = pts * f * f * 4.0 + progs * tile_bytes
    vmem_wr = pts * 4.0
    ws = tile_bytes * depth + by * bx * 4.0 * 2.0 + f * f * 4.0

    tile_eff = (by / round_up(by, 8)) * (bx / round_up(bx, 128))
    edge_eff = (h / (ny * by)) * (w / (nx * bx))

    return {
        C.MXU_FLOPS: 0.0,
        C.VPU_OPS: float(vpu),
        C.TRANS_OPS: 0.0,
        C.ISSUE_OPS: float(vpu),
        C.HBM_RD: float(hbm_rd),
        C.HBM_WR: float(hbm_wr),
        C.VMEM_RD: float(vmem_rd),
        C.VMEM_WR: float(vmem_wr),
        C.CMEM_RD: float(cmem_rd),
        C.GRID: float(progs),
        C.VMEM_WS: float(ws),
        "LANE_E_HINT": tile_eff * edge_eff,
    }
