"""Flash-attention Pallas TPU kernel (framework hot path, 6th tuning space).

Online-softmax blockwise attention for one (S, D) head: grid (q_blocks,
kv_blocks) with the kv dimension sequential; running max/denominator and the
output accumulator live in VMEM scratch.  Batch/head dims are vmapped by the
wrapper in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import cdiv

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
    kv_steps: int, block_q: int, block_k: int, seq_len: int,
    sm_scale: float, causal: bool,
):
    qi, ki = pl.program_id(0), pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[...]  # (BQ, D)
        k = k_ref[...]  # (BK, D)
        v = v_ref[...]  # (BK, D)
        if seq_len % block_k != 0:
            # zero the kv tail: OOB block rows are undefined (NaN in
            # interpret mode) and 0-probability × NaN would poison the acc
            kv_valid = (ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_k,), 0)) < seq_len
            k = jnp.where(kv_valid[:, None], k, 0)
            v = jnp.where(kv_valid[:, None], v, 0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

        # mask: kv-tail padding + causal upper triangle
        k_idx = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        mask = k_idx < seq_len
        if causal:
            q_idx = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            mask &= k_idx <= q_idx
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                      # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                   # (BQ, BK)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # skip fully-masked kv blocks above the diagonal
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(body)
    else:
        body()

    @pl.when(ki == kv_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "causal", "sm_scale", "interpret"),
)
def flash_attention_single_head(
    q: jax.Array,  # (S, D)
    k: jax.Array,  # (S, D)
    v: jax.Array,  # (S, D)
    *,
    block_q: int = 256,
    block_k: int = 256,
    causal: bool = True,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    s, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kv_steps = cdiv(s, block_k)
    grid = (cdiv(s, block_q), kv_steps)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, kv_steps=kv_steps, block_q=block_q,
            block_k=block_k, seq_len=s, sm_scale=sm_scale, causal=causal,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)


def flash_attention(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,
    v: jax.Array,
    **kw,
) -> jax.Array:
    f = functools.partial(flash_attention_single_head, **kw)
    return jax.vmap(jax.vmap(f))(q, k, v)
