"""Flash-attention tuning space + portable workload model."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import counters as C
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.kernels.common import cdiv, round_up


@dataclasses.dataclass(frozen=True)
class AttentionInput:
    batch: int
    heads: int
    seq: int
    head_dim: int
    causal: bool = True
    dtype_bytes: int = 2

    @property
    def tag(self) -> str:
        return f"b{self.batch}h{self.heads}s{self.seq}d{self.head_dim}"


DEFAULT_INPUT = AttentionInput(4, 16, 4096, 128)


def make_space() -> TuningSpace:
    params = [
        TuningParameter("BLOCK_Q", (128, 256, 512, 1024)),
        TuningParameter("BLOCK_K", (128, 256, 512, 1024)),
        # keep p=exp(s) resident vs recompute on the PV matmul
        TuningParameter("KEEP_P", (0, 1)),
        TuningParameter("Q_PREFETCH", (1, 2)),
    ]
    return TuningSpace(params, name="attention")


def workload_fn(cfg: Config, inp: AttentionInput = DEFAULT_INPUT) -> Dict[str, float]:
    b, h, s, d, db = inp.batch, inp.heads, inp.seq, inp.head_dim, inp.dtype_bytes
    bq, bk = cfg["BLOCK_Q"], cfg["BLOCK_K"]
    keep_p, depth = cfg["KEEP_P"], cfg["Q_PREFETCH"]
    nq, nk = cdiv(s, bq), cdiv(s, bk)
    heads = b * h
    causal_f = 0.5 if inp.causal else 1.0

    visited = heads * nq * nk * causal_f + heads * nq * 0.5  # diagonal blocks
    flops = visited * (2.0 * bq * bk * d) * 2.0              # QK^T + PV
    trans = visited * bq * bk                                 # exp
    vpu = visited * bq * bk * 6.0                             # max/sum/scale
    hbm_rd = heads * (s * d * db + nq * (2.0 * nk * causal_f + 1) * bk * d * db)
    hbm_wr = heads * s * d * db
    vmem_rd = visited * (bq * d + 2 * bk * d + bq * bk * (2 if keep_p else 3)) * db
    vmem_wr = visited * (bq * bk + bq * d) * 4.0
    ws = (bq * d * db * depth + 2 * bk * d * db * 2
          + bq * d * 4.0 + (bq * bk * 4.0 if keep_p else 0.0) + bq * 8.0)

    tile_eff = (bq / round_up(bq, 8)) * (bk / round_up(bk, 128))
    edge_eff = (s / (nq * bq)) * (s / (nk * bk))

    return {
        C.MXU_FLOPS: float(flops),
        C.VPU_OPS: float(vpu),
        C.TRANS_OPS: float(trans),
        C.ISSUE_OPS: float(flops + vpu + trans),
        C.HBM_RD: float(hbm_rd),
        C.HBM_WR: float(hbm_wr),
        C.VMEM_RD: float(vmem_rd),
        C.VMEM_WR: float(vmem_wr),
        C.CMEM_RD: 0.0,
        C.GRID: float(heads * nq),
        C.VMEM_WS: float(ws),
        "LANE_E_HINT": tile_eff * edge_eff,
    }
