"""Pure-jnp oracle for flash attention."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal",))
def attention_ref(q, k, v, *, causal: bool = True):
    """q, k, v: (..., S, D)."""
    d = q.shape[-1]
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) / (d ** 0.5)
    if causal:
        sl = q.shape[-2]
        mask = jnp.tril(jnp.ones((sl, sl), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("...qk,...kd->...qd", p, v)
