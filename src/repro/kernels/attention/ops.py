"""Jit'd wrapper: tuning-config dict -> flash attention invocation."""
from repro.kernels.attention.kernel import flash_attention


def run(cfg, q, k, v, interpret: bool = True):
    return flash_attention(q, k, v, block_q=cfg["BLOCK_Q"],
                           block_k=cfg["BLOCK_K"], interpret=interpret)
