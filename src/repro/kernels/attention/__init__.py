from repro.kernels.attention.kernel import flash_attention, flash_attention_single_head
from repro.kernels.attention.ref import attention_ref
from repro.kernels.attention.space import make_space, workload_fn, DEFAULT_INPUT
