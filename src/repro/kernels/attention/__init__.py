import jax.numpy as jnp
import numpy as np

from repro.kernels.attention.kernel import flash_attention, flash_attention_single_head
from repro.kernels.attention.ref import attention_ref
from repro.kernels.attention.space import make_space, workload_fn, DEFAULT_INPUT
from repro.kernels.registry import KernelBenchmark, register_benchmark


def _make_args(inp, rng):
    shape = (inp.batch, inp.heads, inp.seq, inp.head_dim)
    mk = lambda: jnp.asarray(
        rng.standard_normal(shape, dtype=np.float32) * 0.3)
    return (mk(), mk(), mk())


@register_benchmark("attention")
def _benchmark() -> KernelBenchmark:
    from repro.kernels.attention import ops, space

    return KernelBenchmark(
        name="attention",
        make_space=space.make_space,
        workload_fn=space.workload_fn,
        default_input=space.DEFAULT_INPUT,
        inputs={"default": space.DEFAULT_INPUT},
        make_args=_make_args, run=ops.run, ref=attention_ref,
    )
