"""Kernel benchmark registry: uniform access to the paper's five benchmarks
(+ flash attention) for tests, benchmarks and examples.

Each entry binds: tuning space, config→kernel-kwargs dispatch, the jnp oracle,
the portable workload model g(TP, I), and a catalog of inputs (the paper's
input-portability experiments need several per benchmark).

Registration is decorator-based and lives with each kernel package: a
package's ``__init__`` declares

    @register_benchmark("matmul")
    def _benchmark() -> KernelBenchmark: ...

and ``BENCHMARKS`` discovers the packages lazily on first access (so plain
``import repro.kernels.matmul`` stays cheap and adding a kernel package
never touches this module).
"""
from __future__ import annotations

import dataclasses
import importlib
import pkgutil
from typing import Any, Callable, Dict, Iterator, Mapping, Tuple

from repro.core.tuning_space import Config, TuningSpace


@dataclasses.dataclass
class KernelBenchmark:
    name: str
    make_space: Callable[[], TuningSpace]
    workload_fn: Callable[[Config, Any], Dict[str, float]]
    default_input: Any
    inputs: Dict[str, Any]
    make_args: Callable[[Any, Any], Tuple]
    run: Callable[..., Any]       # run(cfg, *args, interpret=...)
    ref: Callable[..., Any]       # ref(*args)
    _space: TuningSpace = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def space(self) -> TuningSpace:
        """Memoized ``make_space()``.

        A registry space is deterministic and treated as read-only by
        every consumer (its feature matrix is literally frozen), but
        materializing one enumerates the whole constrained cross
        product — ~1ms for the larger kernels.  Hot paths that build a
        job per request (the service daemon's submit path) would
        otherwise pay that on every submit; callers that need a private
        mutable space can still call ``make_space()`` directly.
        """
        if self._space is None:
            self._space = self.make_space()
        return self._space


_FACTORIES: Dict[str, Callable[[], KernelBenchmark]] = {}


def register_benchmark(name: str):
    """Decorator for a zero-arg factory returning a ``KernelBenchmark``.

    Applied inside each kernel package's ``__init__``; the factory is built
    lazily on first registry access and cached.
    """

    def deco(factory: Callable[[], KernelBenchmark]):
        if name in _FACTORIES:
            raise ValueError(f"benchmark {name!r} registered twice")
        _FACTORIES[name] = factory
        return factory

    return deco


class _BenchmarkRegistry(Mapping):
    """Lazy name → KernelBenchmark mapping over the registered factories."""

    def __init__(self) -> None:
        self._built: Dict[str, KernelBenchmark] = {}
        self._discovered = False

    def _discover(self) -> None:
        """Import every repro.kernels subpackage so decorators run."""
        if self._discovered:
            return
        import repro.kernels as pkg

        for mod in pkgutil.iter_modules(pkg.__path__):
            if mod.ispkg:
                importlib.import_module(f"repro.kernels.{mod.name}")
        # only after every package imported cleanly — a failed import must
        # surface again on the next access, not a half-populated registry
        self._discovered = True

    def __getitem__(self, name: str) -> KernelBenchmark:
        self._discover()
        if name not in self._built:
            if name not in _FACTORIES:
                raise KeyError(
                    f"unknown benchmark {name!r}; "
                    f"registered: {sorted(_FACTORIES)}")
            bench = _FACTORIES[name]()
            if bench.name != name:
                raise ValueError(
                    f"benchmark factory for {name!r} returned name "
                    f"{bench.name!r}")
            self._built[name] = bench
        return self._built[name]

    def __iter__(self) -> Iterator[str]:
        self._discover()
        return iter(sorted(_FACTORIES))

    def __len__(self) -> int:
        self._discover()
        return len(_FACTORIES)


BENCHMARKS: Mapping[str, KernelBenchmark] = _BenchmarkRegistry()


def GEMM_FULL_SPACE() -> TuningSpace:
    """GEMM-full: the CLTune-like larger space sharing matmul's workload
    model — used for the small-space-model → big-space-search experiment
    (Fig. 8)."""
    from repro.kernels.matmul import space as matmul_space

    return matmul_space.make_full_space()
