"""Kernel benchmark registry: uniform access to the paper's five benchmarks
(+ flash attention) for tests, benchmarks and examples.

Each entry binds: tuning space, config→kernel-kwargs dispatch, the jnp oracle,
the portable workload model g(TP, I), and a catalog of inputs (the paper's
input-portability experiments need several per benchmark).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.tuning_space import Config, TuningSpace
from repro.kernels.attention import ops as attention_ops
from repro.kernels.attention import space as attention_space
from repro.kernels.attention.kernel import flash_attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.conv2d import ops as conv2d_ops
from repro.kernels.conv2d import space as conv2d_space
from repro.kernels.conv2d.kernel import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.coulomb import ops as coulomb_ops
from repro.kernels.coulomb import space as coulomb_space
from repro.kernels.coulomb.kernel import coulomb
from repro.kernels.coulomb.ref import coulomb_ref
from repro.kernels.matmul import ops as matmul_ops
from repro.kernels.matmul import space as matmul_space
from repro.kernels.matmul.kernel import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.nbody import ops as nbody_ops
from repro.kernels.nbody import space as nbody_space
from repro.kernels.nbody.kernel import nbody
from repro.kernels.nbody.ref import nbody_ref
from repro.kernels.transpose import ops as transpose_ops
from repro.kernels.transpose import space as transpose_space
from repro.kernels.transpose.kernel import transpose
from repro.kernels.transpose.ref import transpose_ref


@dataclasses.dataclass
class KernelBenchmark:
    name: str
    make_space: Callable[[], TuningSpace]
    workload_fn: Callable[[Config, Any], Dict[str, float]]
    default_input: Any
    inputs: Dict[str, Any]
    make_args: Callable[[Any, np.random.Generator], Tuple]
    run: Callable[..., Any]       # run(cfg, *args, interpret=...)
    ref: Callable[..., Any]       # ref(*args)


def _matmul_args(inp, rng):
    a = jnp.asarray(rng.standard_normal((inp.m, inp.k), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((inp.k, inp.n), dtype=np.float32))
    return (a, b)




def _transpose_args(inp, rng):
    return (jnp.asarray(rng.standard_normal((inp.m, inp.n), dtype=np.float32)),)




def _coulomb_args(inp, rng):
    atoms = rng.uniform(0.0, inp.grid_size * 0.5,
                        (inp.n_atoms, 4)).astype(np.float32)
    atoms[:, 3] = rng.uniform(0.1, 1.0, inp.n_atoms)
    return (jnp.asarray(atoms),)




def _nbody_args(inp, rng):
    b = rng.standard_normal((inp.n, 4)).astype(np.float32)
    b[:, 3] = np.abs(b[:, 3]) + 0.1
    return (jnp.asarray(b),)




def _conv_args(inp, rng):
    img = jnp.asarray(rng.standard_normal((inp.h, inp.w), dtype=np.float32))
    flt = jnp.asarray(rng.standard_normal((inp.f, inp.f), dtype=np.float32))
    return (img, flt)




def _attn_args(inp, rng):
    shape = (inp.batch, inp.heads, inp.seq, inp.head_dim)
    mk = lambda: jnp.asarray(
        rng.standard_normal(shape, dtype=np.float32) * 0.3)
    return (mk(), mk(), mk())




BENCHMARKS: Dict[str, KernelBenchmark] = {
    "matmul": KernelBenchmark(
        name="matmul",
        make_space=matmul_space.make_space,
        workload_fn=matmul_space.workload_fn,
        default_input=matmul_space.DEFAULT_INPUT,
        inputs={
            "2048": matmul_space.DEFAULT_INPUT,
            "128": matmul_space.SQUARE_SMALL,
            "16x4096": matmul_space.RECT_TALL,
            "4096x16": matmul_space.RECT_WIDE,
        },
        make_args=_matmul_args, run=matmul_ops.run, ref=matmul_ref,
    ),
    "transpose": KernelBenchmark(
        name="transpose",
        make_space=transpose_space.make_space,
        workload_fn=transpose_space.workload_fn,
        default_input=transpose_space.DEFAULT_INPUT,
        inputs={"8192": transpose_space.DEFAULT_INPUT},
        make_args=_transpose_args, run=transpose_ops.run, ref=transpose_ref,
    ),
    "coulomb": KernelBenchmark(
        name="coulomb",
        make_space=coulomb_space.make_space,
        workload_fn=coulomb_space.workload_fn,
        default_input=coulomb_space.DEFAULT_INPUT,
        inputs={
            "default": coulomb_space.DEFAULT_INPUT,
            "large_grid": coulomb_space.LARGE_GRID,
            "small_grid": coulomb_space.SMALL_GRID,
        },
        make_args=_coulomb_args, run=coulomb_ops.run, ref=coulomb_ref,
    ),
    "nbody": KernelBenchmark(
        name="nbody",
        make_space=nbody_space.make_space,
        workload_fn=nbody_space.workload_fn,
        default_input=nbody_space.DEFAULT_INPUT,
        inputs={
            "16k": nbody_space.DEFAULT_INPUT,
            "131k": nbody_space.LARGE_INPUT,
        },
        make_args=_nbody_args, run=nbody_ops.run, ref=nbody_ref,
    ),
    "conv2d": KernelBenchmark(
        name="conv2d",
        make_space=conv2d_space.make_space,
        workload_fn=conv2d_space.workload_fn,
        default_input=conv2d_space.DEFAULT_INPUT,
        inputs={"4096": conv2d_space.DEFAULT_INPUT},
        make_args=_conv_args, run=conv2d_ops.run, ref=conv2d_ref,
    ),
    "attention": KernelBenchmark(
        name="attention",
        make_space=attention_space.make_space,
        workload_fn=attention_space.workload_fn,
        default_input=attention_space.DEFAULT_INPUT,
        inputs={"default": attention_space.DEFAULT_INPUT},
        make_args=_attn_args, run=attention_ops.run, ref=attention_ref,
    ),
}

# GEMM-full: the CLTune-like larger space sharing matmul's workload model —
# used for the small-space-model -> big-space-search experiment (Fig. 8).
GEMM_FULL_SPACE = matmul_space.make_full_space
