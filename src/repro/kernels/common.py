"""Shared helpers for Pallas TPU kernels."""
from __future__ import annotations

import math

from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams (<= 0.5) to CompilerParams (>= 0.6); resolve
# whichever this jax ships so kernels work across the range.
CompilerParams = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def lane_efficiency_2d(bm: int, bn: int, m: int, n: int) -> float:
    """Useful-lane fraction for (bm, bn) tiles over an (m, n) problem.

    Two waste sources on TPU: sublane/lane padding of the tile to the (8, 128)
    register tiling, and edge-tile padding when the block does not divide the
    problem.  This is the warp-execution-efficiency analog (DESIGN.md §2).
    """
    tile_eff = (bm / round_up(bm, 8)) * (bn / round_up(bn, 128))
    edge_eff = (m / round_up(m, bm)) * (n / round_up(n, bn))
    return tile_eff * edge_eff
