from repro.kernels.matmul.kernel import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.matmul.space import make_space, workload_fn, DEFAULT_INPUT
