import jax.numpy as jnp
import numpy as np

from repro.kernels.matmul.kernel import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.matmul.space import make_space, workload_fn, DEFAULT_INPUT
from repro.kernels.registry import KernelBenchmark, register_benchmark


def _make_args(inp, rng):
    a = jnp.asarray(rng.standard_normal((inp.m, inp.k), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((inp.k, inp.n), dtype=np.float32))
    return (a, b)


@register_benchmark("matmul")
def _benchmark() -> KernelBenchmark:
    from repro.kernels.matmul import ops, space

    return KernelBenchmark(
        name="matmul",
        make_space=space.make_space,
        workload_fn=space.workload_fn,
        default_input=space.DEFAULT_INPUT,
        inputs={
            "2048": space.DEFAULT_INPUT,
            "128": space.SQUARE_SMALL,
            "16x4096": space.RECT_TALL,
            "4096x16": space.RECT_WIDE,
        },
        make_args=_make_args, run=ops.run, ref=matmul_ref,
    )
