"""Tiled GEMM Pallas TPU kernel (paper benchmark: GEMM / GEMM-full).

Grid (m, n, k) with k innermost-sequential; fp32 accumulator lives in VMEM
scratch across the k steps (standard MXU blocking: HBM→VMEM tiles sized by
BlockSpec, MXU consumes (BM, BK) x (BK, BN)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import cdiv


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                   k_total: int, block_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if k_total % block_k != 0:
        # mask the K tail: the last tile reads past the array bound and the
        # pad contents are undefined (NaN in interpret mode)
        k_idx = pl.program_id(2) * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k,), 0
        )
        valid = k_idx < k_total
        a = jnp.where(valid[None, :], a, 0)
        b = jnp.where(valid[:, None], b, 0)

    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "loop_order", "interpret"),
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    loop_order: str = "mnk",
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with explicit VMEM tiling.

    loop_order 'mnk' iterates m outermost (better A reuse when N is small);
    'nmk' iterates n outermost (better B reuse when M is small).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    k_steps = cdiv(k, block_k)

    if loop_order == "mnk":
        grid = (cdiv(m, block_m), cdiv(n, block_n), k_steps)
        a_map = lambda i, j, kk: (i, kk)
        b_map = lambda i, j, kk: (kk, j)
        o_map = lambda i, j, kk: (i, j)
    elif loop_order == "nmk":
        grid = (cdiv(n, block_n), cdiv(m, block_m), k_steps)
        a_map = lambda j, i, kk: (i, kk)
        b_map = lambda j, i, kk: (kk, j)
        o_map = lambda j, i, kk: (i, j)
    else:
        raise ValueError(loop_order)

    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps, k_total=k,
                          block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), a_map),
            pl.BlockSpec((block_k, block_n), b_map),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
