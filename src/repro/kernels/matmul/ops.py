"""Jit'd wrapper: tuning-config dict -> GEMM kernel invocation."""
from repro.kernels.matmul.kernel import matmul


def run(cfg, a, b, interpret: bool = True):
    return matmul(a, b, block_m=cfg["BLOCK_M"], block_n=cfg["BLOCK_N"],
                  block_k=cfg["BLOCK_K"], loop_order=cfg["LOOP_ORDER"],
                  interpret=interpret)
