"""GEMM tuning space + portable workload model g(TP, I) → PC_ops.

Space character follows CLBlast's reduced GEMM space (paper Table 2: 10 dims,
5,788 configs there; ours is the TPU-meaningful subset).  ``make_full_space``
is the CLTune-like larger space (GEMM-full analog) used for the
small-space-model → big-space-search experiment (§4.6.2 / Fig. 8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import counters as C
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.kernels.common import cdiv, lane_efficiency_2d, round_up


@dataclasses.dataclass(frozen=True)
class GemmInput:
    m: int
    n: int
    k: int
    dtype_bytes: int = 4

    @property
    def tag(self) -> str:
        return f"{self.m}x{self.n}x{self.k}"


DEFAULT_INPUT = GemmInput(2048, 2048, 2048)
SQUARE_SMALL = GemmInput(128, 128, 128)
RECT_TALL = GemmInput(16, 4096, 4096)     # 16 x 4096 (memory bound)
RECT_WIDE = GemmInput(4096, 16, 4096)     # 4096 x 16 (memory bound)


def make_space(inp: "GemmInput" = None) -> TuningSpace:
    """Reduced (CLBlast-like) GEMM space.

    ``inp`` enables the expert input-aware pruning the paper's spaces have
    (§4.2: no obviously-absurd configurations — e.g. tiles several times
    larger than the matrix, the sub-warp-block analog).
    """
    params = [
        TuningParameter("BLOCK_M", (64, 128, 256, 512)),
        TuningParameter("BLOCK_N", (64, 128, 256, 512)),
        TuningParameter("BLOCK_K", (128, 256, 512, 1024)),
        TuningParameter("LOOP_ORDER", ("mnk", "nmk")),
        TuningParameter("ACC_F32", (0, 1)),
    ]
    # VMEM footprint guard: expert-designed spaces exclude absurd configs
    # (paper §4.2 note) but deliberately keep the spill cliff inside.
    def fits_rough(cfg: Config) -> bool:
        ws = _working_set(cfg, dtype_bytes=4)
        return ws <= 512 * 2**20  # drop only absurd configs

    constraints = [fits_rough]
    if inp is not None:
        def not_absurd(cfg: Config) -> bool:
            return (cfg["BLOCK_M"] <= max(64, 2 * inp.m)
                    and cfg["BLOCK_N"] <= max(64, 2 * inp.n)
                    and cfg["BLOCK_K"] <= max(128, 2 * inp.k))
        constraints.append(not_absurd)

    return TuningSpace(params, constraints=constraints, name="gemm")


def make_full_space() -> TuningSpace:
    """CLTune-like larger space (GEMM-full analog): more dims and values."""
    params = [
        TuningParameter("BLOCK_M", (32, 64, 128, 256, 512)),
        TuningParameter("BLOCK_N", (32, 64, 128, 256, 512)),
        TuningParameter("BLOCK_K", (64, 128, 256, 512, 1024)),
        TuningParameter("LOOP_ORDER", ("mnk", "nmk")),
        TuningParameter("ACC_F32", (0, 1)),
        TuningParameter("OUT_SWIZZLE", (0, 1)),
        TuningParameter("K_UNROLL", (1, 2, 4)),
        TuningParameter("PREFETCH_DEPTH", (1, 2, 3)),
    ]

    def fits_rough(cfg: Config) -> bool:
        return _working_set(cfg, dtype_bytes=4) <= 512 * 2**20

    return TuningSpace(params, constraints=[fits_rough], name="gemm_full")


def _working_set(cfg: Config, dtype_bytes: int) -> float:
    bm, bn, bk = cfg["BLOCK_M"], cfg["BLOCK_N"], cfg["BLOCK_K"]
    acc_bytes = 4 if cfg.get("ACC_F32", 1) else dtype_bytes
    depth = cfg.get("PREFETCH_DEPTH", 1)
    # A tile + B tile (x prefetch depth) + accumulator + out tile
    return (bm * bk + bk * bn) * dtype_bytes * depth + bm * bn * (
        acc_bytes + dtype_bytes
    )


def workload_fn(cfg: Config, inp: GemmInput = DEFAULT_INPUT) -> Dict[str, float]:
    """g: TP × I → PC_ops (hardware-independent operation counts)."""
    m, n, k, db = inp.m, inp.n, inp.k, inp.dtype_bytes
    bm, bn, bk = cfg["BLOCK_M"], cfg["BLOCK_N"], cfg["BLOCK_K"]
    nm, nn, nk = cdiv(m, bm), cdiv(n, bn), cdiv(k, bk)
    unroll = cfg.get("K_UNROLL", 1)
    swizzle = cfg.get("OUT_SWIZZLE", 0)

    # HBM traffic: A re-read per n-tile, B re-read per m-tile, C written once.
    hbm_rd = (nm * nn * nk) * (bm * bk + bk * bn) * db
    hbm_wr = nm * nn * bm * bn * db
    # MXU flops on padded tiles (padding waste captured by LANE_E hint too)
    flops = 2.0 * (nm * bm) * (nn * bn) * (nk * bk)
    # VMEM<->VREG traffic feeding the MXU + accumulator read-modify-write
    acc_bytes = 4 if cfg.get("ACC_F32", 1) else db
    vmem_rd = (nm * nn * nk) * (bm * bk + bk * bn) * db \
        + (nm * nn * nk) * bm * bn * acc_bytes
    vmem_wr = (nm * nn * nk) * bm * bn * acc_bytes
    # swizzled store does one extra VMEM pass over the out tile
    if swizzle:
        vmem_rd += nm * nn * bm * bn * db
        vmem_wr += nm * nn * bm * bn * db
    # unrolling reduces loop-control issue ops, slightly raises VMEM_WS
    vpu = nm * nn * nk * bm * bn / max(unroll, 1) * 0.05
    ws = _working_set(cfg, db) * (1.0 + 0.08 * (unroll - 1))

    lane_e = lane_efficiency_2d(bm, bn, m, n)
    # k-padding waste also burns MXU cycles
    lane_e *= k / round_up(k, bk)

    return {
        C.MXU_FLOPS: flops,
        C.VPU_OPS: vpu,
        C.TRANS_OPS: 0.0,
        C.ISSUE_OPS: flops + vpu,
        C.HBM_RD: float(hbm_rd),
        C.HBM_WR: float(hbm_wr),
        C.VMEM_RD: float(vmem_rd),
        C.VMEM_WR: float(vmem_wr),
        C.CMEM_RD: 0.0,
        C.GRID: float(nm * nn),  # k dim is sequential within a program
        C.VMEM_WS: float(ws),
        "LANE_E_HINT": lane_e,
    }
