"""Pure-jnp oracle for the GEMM kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
