"""End-to-end training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 50 --batch 8 --seq 256 --smoke --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (CPU smoke → full pod; the mesh adapts).
Fault tolerance: resumes from the latest complete checkpoint; a per-step
watchdog aborts wedged steps so the supervisor (launch/supervisor.py or any
process manager) can re-exec the job, which then restores and continues —
the standard large-pod failure model.  The data pipeline is step-indexed,
so restarts replay the exact batch sequence.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro.configs import ARCHS, SMOKES
from repro.data.pipeline import DataConfig, make_batch
from repro.distributed.api import activation_sharding
from repro.distributed.sharding import (batch_shardings, default_rules,
                                        make_act_resolver, param_shardings)
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim.adamw import AdamW, warmup_cosine
from repro.train.train_step import (StepConfig, TrainState, init_train_state,
                                    make_train_step)
from repro.checkpoint.checkpointer import Checkpointer

from jax.sharding import NamedSharding, PartitionSpec as P


class StepWatchdog:
    """Aborts the process if a step wedges (straggler/deadlock mitigation).

    On a real pod a wedged collective blocks forever; the watchdog converts
    that into a fast failure so the supervisor restarts from the last
    checkpoint instead of burning pod-hours.
    """

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._timer = None

    def arm(self):
        self.disarm()
        self._timer = threading.Timer(self.timeout_s, self._abort)
        self._timer.daemon = True
        self._timer.start()

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    @staticmethod
    def _abort():
        import os
        print("[watchdog] step exceeded timeout — aborting for restart")
        os._exit(42)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="nothing_saveable")
    ap.add_argument("--step-timeout", type=float, default=600.0)
    ap.add_argument("--data-model", type=int, nargs=2, default=(1, 1),
                    help="mesh shape (data, model)")
    args = ap.parse_args()

    arch = (SMOKES if args.smoke else ARCHS)[args.arch]
    model = build_model(arch)
    mesh = make_host_mesh(*args.data_model)
    rules = default_rules(multi_pod=False)
    optimizer = AdamW(lr=warmup_cosine(args.lr, max(args.steps // 10, 1),
                                       args.steps))
    scfg = StepConfig(remat=args.remat, microbatches=args.microbatches,
                      loss_chunks=1)
    step_fn = make_train_step(model, optimizer, scfg)

    dcfg = DataConfig(
        vocab_size=arch.vocab_size, seq_len=args.seq,
        global_batch=args.batch,
        frontend=arch.frontend, frontend_len=arch.frontend_len,
        frontend_dim=arch.frontend_dim,
    )

    resolver = make_act_resolver(mesh, rules)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    watchdog = StepWatchdog(args.step_timeout)

    with mesh:
        with activation_sharding(resolver):
            state = init_train_state(model, optimizer, jax.random.PRNGKey(0))
            specs = model.specs()
            p_sh = param_shardings(mesh, rules, specs, state.params)
            state = TrainState(
                params=jax.tree.map(jax.device_put, state.params, p_sh),
                opt=state.opt, step=state.step)
            start = 0
            if ckpt is not None:
                got = ckpt.restore_latest(state)
                if got[0] is not None:
                    start, state = got
                    print(f"[train] restored checkpoint at step {start}")

            jit_step = jax.jit(step_fn, donate_argnums=(0,))
            t0 = time.time()
            for step in range(start, args.steps):
                batch = {k: jax.device_put(v)
                         for k, v in make_batch(dcfg, step).items()}
                watchdog.arm()
                state, metrics = jit_step(state, batch)
                loss = float(metrics["loss"])
                watchdog.disarm()
                if step % 5 == 0 or step == args.steps - 1:
                    dt = time.time() - t0
                    print(f"[train] step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"({dt:.1f}s)")
                if ckpt is not None and (step + 1) % args.ckpt_every == 0:
                    ckpt.save(step + 1, state)
            if ckpt is not None:
                ckpt.save(args.steps, state)
                ckpt.wait()
            print(f"[train] done: final loss {loss:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
