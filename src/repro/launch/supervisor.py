"""Fault-tolerant training supervisor: re-exec on failure, resume from the
last checkpoint.  The production failure unit on TPU pods is the whole job
(a dead host wedges collectives); the watchdog inside train.py converts
wedges into exits, and this loop restarts bounded-many times.

    PYTHONPATH=src python -m repro.launch.supervisor -- \
        --arch qwen1.5-0.5b --smoke --steps 100 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--backoff", type=float, default=5.0)
    ap.add_argument("train_args", nargs=argparse.REMAINDER)
    args = ap.parse_args()
    train_args = [a for a in args.train_args if a != "--"]

    for attempt in range(args.max_restarts + 1):
        cmd = [sys.executable, "-m", "repro.launch.train"] + train_args
        print(f"[supervisor] attempt {attempt}: {' '.join(cmd)}")
        rc = subprocess.call(cmd)
        if rc == 0:
            print("[supervisor] training completed")
            return 0
        print(f"[supervisor] exited rc={rc}; restarting from checkpoint "
              f"in {args.backoff}s")
        time.sleep(args.backoff)
    print("[supervisor] restart budget exhausted")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
