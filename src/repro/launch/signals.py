"""Graceful-drain signal handling shared by the long-running CLIs.

Both the fleet CLI and the service daemon want the same SIGINT/SIGTERM
contract: the FIRST signal requests a drain (stop filling, let in-flight
empirical tests finish, publish/report what completed), a SECOND signal
gives up and restores the default handler so the third one kills the
process the ordinary way.  ``install_drain_handlers`` encodes exactly
that; the drain callback must be safe to call from a signal handler
(set a flag / call ``FleetTuner.stop()`` / ``TuningDaemon.shutdown``,
which only flip events — never block there).
"""
from __future__ import annotations

import signal
import sys
from typing import Callable, Iterable

DRAIN_SIGNALS = (signal.SIGINT, signal.SIGTERM)


def install_drain_handlers(drain: Callable[[], None],
                           signals: Iterable[int] = DRAIN_SIGNALS,
                           verbose: bool = True) -> Callable[[], bool]:
    """Route ``signals`` to ``drain()`` (once); return a ``draining()`` probe.

    The first delivery calls ``drain`` and keeps running; the second
    restores ``SIG_DFL`` for all registered signals — so a stuck drain
    can still be interrupted — and re-raises the default behavior on the
    next delivery.  Returns a zero-arg callable reporting whether a
    drain was requested (CLIs use it to annotate their reports).
    """
    state = {"drains": 0}
    sigs = tuple(signals)

    def handler(signum, frame):
        state["drains"] += 1
        if state["drains"] == 1:
            if verbose:
                print(f"\n[signal] {signal.Signals(signum).name}: draining "
                      f"in-flight work (signal again to force quit)",
                      file=sys.stderr)
            drain()
            return
        if verbose:
            print(f"\n[signal] {signal.Signals(signum).name} again: "
                  f"restoring default handlers", file=sys.stderr)
        for s in sigs:
            signal.signal(s, signal.SIG_DFL)

    for s in sigs:
        signal.signal(s, handler)
    return lambda: state["drains"] > 0
