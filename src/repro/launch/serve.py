"""Serving driver: load (or init) a model and run batched generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 8 --max-new 16

``--autotune`` serves through the online shape-bucketed tuner instead of a
fixed configuration: requests are bucketed by (prompt length, max-new)
deciles, the dominant bucket's configuration comes from the ``ConfigStore``
(``--store``; zero live trials on a hit) or from a handful of live
warm-started trials on a miss, and freshly tuned configs persist for the
next run.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, SMOKES
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine, tune_engine_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--tune-batch", action="store_true",
                    help="pick batch size by timed trials through the "
                         "ask-tell tuning API before serving")
    ap.add_argument("--autotune", action="store_true",
                    help="serve through the online shape-bucketed tuner "
                         "(drift-triggered live trials, store-backed reuse)")
    ap.add_argument("--store", default=None,
                    help="ConfigStore JSON path for --autotune (tuned "
                         "configs/models persist across runs; default: "
                         "in-memory)")
    ap.add_argument("--live-trials", type=int, default=8,
                    help="max live trials per drift event for --autotune")
    ap.add_argument("--service", default=None,
                    help="tuning-daemon address (host:port) for --autotune: "
                         "drift retunes route through the shared tuning "
                         "service and fall back in-process when it is "
                         "unreachable (start one with "
                         "python -m repro.launch.daemon)")
    args = ap.parse_args()

    arch = (SMOKES if args.smoke else ARCHS)[args.arch]
    model = build_model(arch)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, arch.vocab_size,
                                        size=int(rng.integers(4, 16))),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    if args.autotune:
        from repro.serve.autotune import (EngineBackend, OnlineAutotuner,
                                          ShapeBucketer, serve_space,
                                          stats_from_model)
        from repro.tuning.store import ConfigStore

        backend = EngineBackend(model, rng=jax.random.PRNGKey(0))
        tuner = OnlineAutotuner(
            backend,
            store=ConfigStore(args.store),
            bucketer=ShapeBucketer(max_prompt=args.max_seq,
                                   max_new=max(1, args.max_new)),
            space=serve_space(max_seqs=tuple(sorted(
                {args.max_seq, args.max_seq // 2, 2 * args.max_seq}))),
            stats=stats_from_model(model),
            max_live_trials=args.live_trials,
            hardware_name=jax.default_backend(),
            service=args.service,
        )
        t0 = time.time()
        out, rep = tuner.serve(reqs)
        dt = time.time() - t0
        n = sum(len(v) for v in out.values())
        if rep is not None:
            how = ("reused stored config" if rep.reused
                   else "tuned via service" if rep.via_service
                   else "tuned live")
            print(f"[serve] bucket={rep.bucket} {how} "
                  f"(trials={rep.live_trials}) -> {rep.config}")
        print(f"[serve] {len(reqs)} requests, {n} tokens in {dt:.1f}s "
              f"({n/max(dt, 1e-9):.1f} tok/s)")
        return 0

    batch = args.batch
    if args.tune_batch:
        params = model.init(jax.random.PRNGKey(0))  # one copy for all trials
        factory = lambda b: ServeEngine(model, batch_size=b,
                                        max_seq=args.max_seq, params=params)
        batch, best_s, hist = tune_engine_batch(factory, reqs)
        print(f"[serve] tuned batch_size={batch} "
              f"({best_s:.2f}s best of {len(hist)} trials)")
    engine = ServeEngine(model, batch_size=batch, max_seq=args.max_seq,
                         rng=jax.random.PRNGKey(0))
    t0 = time.time()
    out = engine.generate(reqs)
    dt = time.time() - t0
    n = sum(len(v) for v in out.values())
    print(f"[serve] {len(reqs)} requests, {n} tokens in {dt:.1f}s "
          f"({n/dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
