import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Step-config tuning driver over the public ``repro.tuning`` API.

Tunes the distributed train-step configuration (microbatches, remat, loss
chunking, attention chunk, FSDP) of an architecture against REAL compiles,
with the paper's two-phase flow made operational:

  train + save:   --save-model step_tppc.json  (train TP->PC model here)
  load + tune:    --load-model step_tppc.json  (skip the training compiles —
                  the artifact may come from a DIFFERENT machine)

    PYTHONPATH=src python -m repro.launch.tune --arch qwen2.5-3b \
        [--searcher profile] [--budget 10] [--save-model step_tppc.json]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

from repro.core.step_tuner import CompiledStepEvaluator  # noqa: E402
from repro.tuning import SEARCHERS, TuningSession        # noqa: E402


def _tune_problem(args) -> int:
    """``--problem kind:name`` mode: tune one registered ``TuningProblem``
    through the fleet machinery (problem evaluator or cost-model replay)."""
    from repro.fleet import FleetTuner, VirtualWorkerPool, job_from_problem
    from repro.tuning import ConfigStore
    from repro.tuning.problem import parse_problem

    try:
        problem = parse_problem(args.problem)
    except (KeyError, ValueError) as exc:
        raise SystemExit(f"--problem: {exc}")
    t0 = time.time()
    job = job_from_problem(problem, args.hw, budget=args.budget,
                           seed=args.seed, searcher=args.searcher)
    store = ConfigStore(args.store)
    pool = VirtualWorkerPool(workers=1)
    try:
        report = FleetTuner([job], pool, store=store,
                            transfer=args.transfer,
                            transfer_threshold=args.transfer_threshold).run()
    finally:
        pool.close()
    r = report.results[0]
    warm = ""
    if r.transfer_from is not None:
        warm = (f", transfer from {r.transfer_from} "
                f"(similarity {r.transfer_similarity:.3f})")
    elif r.warm_started:
        warm = ", warm"
    print(f"[tune] {problem.spec} on {args.hw} ({r.searcher}{warm}): "
          f"best {r.best_runtime*1e3:.3f}ms after {r.trials} tests")
    print(f"[tune] best config: {r.best_config}")
    if args.store:
        print(f"[tune] store -> {args.store} ({len(store)} entries)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"problem": problem.spec, "hardware": args.hw,
                       "searcher": r.searcher,
                       "best_ms": r.best_runtime * 1e3,
                       "best_config": r.best_config, "trials": r.trials,
                       "history": r.history,
                       "seconds": time.time() - t0}, f, indent=2)
        print(f"[tune] -> {args.out}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--problem", default=None,
                    help="tune a registered problem 'kind:name' instead of "
                    "the compiled train step (e.g. kernel:matmul/128, "
                    "sharding:qwen2.5-3b/train_4k, serve:p9n9); see "
                    "repro.tuning.problem_kinds()")
    ap.add_argument("--hw", default="tpu_v5e",
                    help="hardware target for --problem mode")
    ap.add_argument("--store", default=None,
                    help="ConfigStore path for --problem mode artifacts")
    from repro.tuning.signature import DEFAULT_TRANSFER_THRESHOLD
    ap.add_argument("--transfer", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="--problem mode: when every exact-space stored "
                    "model misses, warm-start from the most structurally "
                    "similar same-kind space's model (--no-transfer pins "
                    "the legacy exact-space ladder)")
    ap.add_argument("--transfer-threshold", type=float,
                    default=DEFAULT_TRANSFER_THRESHOLD,
                    help="minimum structural similarity (counter Jaccard "
                    "x parameter overlap, in [0,1]) a cross-space model "
                    "must clear to be used")
    ap.add_argument("--searcher", default=None,
                    choices=sorted(SEARCHERS))
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--in-flight", type=int, default=1,
                    help="outstanding empirical tests to keep submitted "
                    "(the compile evaluator is thread-safe; >1 only pays "
                    "off with an async evaluation backend)")
    ap.add_argument("--train-samples", type=int, default=14)
    ap.add_argument("--save-model", default=None)
    ap.add_argument("--load-model", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.problem:
        # fleet-auto searcher when unset: warm_start on store hit, else cold
        return _tune_problem(args)
    if args.searcher is None:
        args.searcher = "profile"

    t0 = time.time()
    ev = CompiledStepEvaluator(args.arch, args.shape)
    session = TuningSession(ev.space, seed=args.seed)

    needs_model = args.searcher in ("profile", "profile_local")
    if args.load_model:
        session.load_model(args.load_model)
        print(f"[tune] loaded model artifact {args.load_model}")
    elif needs_model:
        print(f"[tune] training phase: <= {args.train_samples} compiles")
        session.train_on_evaluator(ev, values_per_param=2,
                                   max_samples=args.train_samples)
        print(f"[tune] model trained ({ev.compile_seconds:.0f}s compiles)")
    if args.save_model and session.model is not None:
        session.save_model(args.save_model)
        print(f"[tune] model artifact -> {args.save_model}")

    # fresh evaluator for the tuning phase (training already spent steps on
    # ev's account); share the compile cache so repeats stay free
    ev_tune = CompiledStepEvaluator(args.arch, args.shape)
    ev_tune._cache.update(ev._cache)
    extra = {"n": 3} if needs_model else {}
    result = session.tune(budget=args.budget, searcher=args.searcher,
                          evaluator=ev_tune, in_flight=args.in_flight,
                          **extra)
    print(f"[tune] {args.searcher}: best {result.best_runtime*1e3:.1f}ms "
          f"after {result.steps} empirical tests")
    print(f"[tune] best config: {result.best_config}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"arch": args.arch, "shape": args.shape,
                       "searcher": args.searcher,
                       "best_ms": result.best_runtime * 1e3,
                       "best_config": result.best_config,
                       "steps": result.steps,
                       "history": result.history,
                       "seconds": time.time() - t0}, f, indent=2)
        print(f"[tune] -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
