import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init).  Run as

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k --mesh single --out results.jsonl

or with --all to sweep every live cell sequentially.  Each cell prints
``memory_analysis()`` (proof it fits) and ``cost_analysis()`` FLOPs/bytes
(roofline inputs), and appends a JSON record.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS                                   # noqa: E402
from repro.distributed.api import activation_sharding             # noqa: E402
from repro.distributed.sharding import (batch_shardings,          # noqa: E402
                                        cache_shardings,
                                        default_rules,
                                        make_act_resolver,
                                        param_shardings)
from repro.launch.mesh import make_production_mesh                # noqa: E402
from repro.models.config import SHAPES, shape_applicable          # noqa: E402
from repro.models.registry import build_model                     # noqa: E402
from repro.optim.adamw import AdamW, warmup_cosine                # noqa: E402
from repro.roofline import analysis as roofline                   # noqa: E402
from repro.train.train_step import (StepConfig,                   # noqa: E402
                                    abstract_train_state,
                                    make_train_step)

from jax.sharding import NamedSharding, PartitionSpec as P        # noqa: E402


# Per-(arch, shape) step-config overrides: microbatches bound the live
# activation footprint; loss_chunks bound the (tokens, vocab) logits buffer.
def step_config_for(arch_name: str, shape_name: str,
                    overrides=None) -> StepConfig:
    big = arch_name in ("deepseek-v2-236b", "command-r-plus-104b",
                        "internvl2-76b", "llama4-scout-17b-a16e")
    cfg = dict(
        remat="nothing_saveable",
        microbatches=8 if big else 2,
        loss_chunks=8,
        kv_chunk=2048,
    )
    if overrides:
        cfg.update(overrides)
    return StepConfig(**cfg)


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               step_overrides=None, rules_overrides=None,
               verbose: bool = True):
    arch = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = default_rules(multi_pod=multi_pod)
    if rules_overrides:
        rules = rules.replace(**rules_overrides)
    model = build_model(arch)
    resolver = make_act_resolver(mesh, rules)

    t0 = time.time()
    with mesh:
        with activation_sharding(resolver):
            if shape.kind == "train":
                scfg = step_config_for(arch_name, shape_name, step_overrides)
                optimizer = AdamW(lr=warmup_cosine(3e-4, 2000, 100000))
                step = make_train_step(model, optimizer, scfg)
                state_abs = abstract_train_state(model, optimizer)
                state_sh = jax.tree.map(
                    lambda _: None, state_abs,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
                # params/opt follow logical specs; step counter replicated
                specs = model.specs()
                p_sh = param_shardings(mesh, rules, specs, state_abs.params)
                m_sh = param_shardings(mesh, rules, specs, state_abs.opt.m)
                v_sh = param_shardings(mesh, rules, specs, state_abs.opt.v)
                rep = NamedSharding(mesh, P())
                state_sh = type(state_abs)(
                    params=p_sh,
                    opt=type(state_abs.opt)(m=m_sh, v=v_sh, count=rep),
                    step=rep)
                batch_abs = model.input_specs(shape)
                b_sh = batch_shardings(mesh, rules, batch_abs)
                lowered = jax.jit(
                    step,
                    in_shardings=(state_sh, b_sh),
                    out_shardings=(state_sh, None),
                    donate_argnums=(0,),
                ).lower(state_abs, batch_abs)
                tokens = shape.global_batch * shape.seq_len
                mflops = roofline.model_flops_train(
                    model.active_param_count(), tokens)
            elif shape.kind == "prefill":
                batch_abs = model.input_specs(shape)
                b_sh = batch_shardings(mesh, rules, batch_abs)
                params_abs = model.abstract()
                p_sh = param_shardings(mesh, rules, model.specs(), params_abs)

                def serve_prefill(params, batch):
                    return model.prefill(params, batch,
                                         max_seq=shape.seq_len)

                lowered = jax.jit(
                    serve_prefill, in_shardings=(p_sh, b_sh),
                ).lower(params_abs, batch_abs)
                tokens = shape.global_batch * shape.seq_len
                mflops = roofline.model_flops_decode(
                    model.active_param_count(), tokens)
            else:  # decode
                batch_abs = model.input_specs(shape)
                b_sh = batch_shardings(mesh, rules, batch_abs)
                params_abs = model.abstract()
                p_sh = param_shardings(mesh, rules, model.specs(), params_abs)
                cache_abs = model.cache_specs(shape.global_batch,
                                              shape.seq_len)
                c_sh = cache_shardings(mesh, rules, cache_abs,
                                       shape.global_batch, shape.seq_len)

                def serve_step(params, cache, batch):
                    return model.decode(params, cache, batch)

                lowered = jax.jit(
                    serve_step, in_shardings=(p_sh, c_sh, b_sh),
                    donate_argnums=(1,),
                ).lower(params_abs, cache_abs, batch_abs)
                tokens = shape.global_batch
                mflops = roofline.model_flops_decode(
                    model.active_param_count(), tokens)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    rf = roofline.analyze_compiled(compiled, chips=chips,
                                   model_flops=mflops)
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "roofline": rf.summary(),
    }
    if verbose:
        print(f"[dryrun] {arch_name} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={rec['memory']['argument_bytes']/2**30:.2f}GiB "
              f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
              f"(per device)")
        print(f"  cost_analysis: flops={rf.flops:.3e} bytes={rf.hbm_bytes:.3e} "
              f"coll={rf.collective_bytes:.3e}B")
        print(f"  roofline: compute={rf.compute_s*1e3:.2f}ms "
              f"memory={rf.memory_s*1e3:.2f}ms "
              f"collective={rf.collective_s*1e3:.2f}ms "
              f"-> {rf.dominant}-bound; useful={rf.useful_flops_ratio:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in meshes:
                    cells.append((arch, shape, mp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    out = open(args.out, "a") if args.out else None
    failures = 0
    for arch, shape, mp in cells:
        try:
            rec = lower_cell(arch, shape, mp)
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "mesh": "multi" if mp else "single",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        if out:
            out.write(json.dumps(rec) + "\n")
            out.flush()
    if out:
        out.close()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
