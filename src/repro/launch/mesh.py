"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))
