"""Fleet tuning CLI: many (kernel × input × hardware) jobs, one pool.

Builds ``TuningJob``s from the kernel registry for every requested
(kernel, hardware) pair, runs them through a ``FleetTuner`` over the
chosen worker backend, and persists tuned configs + portable model
artifacts into a shared ``ConfigStore`` — so re-running with more hardware
(or more shapes) warm-starts from what the fleet already learned.

    PYTHONPATH=src python -m repro.launch.fleet \
        --kernels matmul,transpose --hw tpu_v4,tpu_v5e \
        --store fleet_store.json --workers 4 --budget 25

    # subprocess lanes, each with its own 2-device jax host runtime
    PYTHONPATH=src python -m repro.launch.fleet --backend subprocess \
        --workers 2 --devices-per-worker 2 --kernels matmul --hw tpu_v5e
"""
from __future__ import annotations

import argparse
import json
import time


def build_pool(backend: str, workers: int, devices_per_worker: int):
    from repro.fleet import (SubprocessWorkerPool, ThreadWorkerPool,
                             VirtualWorkerPool)

    if backend == "virtual":
        return VirtualWorkerPool(workers=workers)
    if backend == "thread":
        return ThreadWorkerPool(workers=workers)
    if backend == "subprocess":
        return SubprocessWorkerPool(workers=workers,
                                    devices_per_worker=devices_per_worker)
    raise ValueError(f"unknown backend {backend!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--kernels", default="matmul,transpose",
                    help="comma-separated registry kernel names")
    ap.add_argument("--inputs", default=None,
                    help="comma-separated input keys, one per kernel "
                    "(default: each kernel's default input)")
    ap.add_argument("--hw", default="tpu_v4,tpu_v5e",
                    help="comma-separated hardware names (naming drift ok: "
                    "TPUv4 == tpu_v4)")
    ap.add_argument("--backend", default="virtual",
                    choices=("virtual", "thread", "subprocess"))
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--devices-per-worker", type=int, default=0,
                    help="subprocess backend: jax host devices per worker")
    ap.add_argument("--in-flight", type=int, default=None,
                    help="outstanding tests pool-wide (default: --workers)")
    ap.add_argument("--budget", type=int, default=25,
                    help="empirical-test budget per job")
    ap.add_argument("--searcher", default=None,
                    help="force one searcher for every job (default: "
                    "warm_start on store hit, random cold)")
    ap.add_argument("--store", default=None,
                    help="shared ConfigStore path (default: in-memory)")
    ap.add_argument("--no-publish", action="store_true",
                    help="do not train/publish missing model artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write a JSON report here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.fleet import FleetTuner, job_from_registry
    from repro.kernels.registry import BENCHMARKS
    from repro.tuning import ConfigStore

    kernels = [k.strip() for k in args.kernels.split(",") if k.strip()]
    hws = [h.strip() for h in args.hw.split(",") if h.strip()]
    if args.inputs is not None:
        inputs = [i.strip() for i in args.inputs.split(",")]
        if len(inputs) != len(kernels):
            raise SystemExit("--inputs must list one key per --kernels entry")
    else:
        inputs = []
        for k in kernels:
            bm = BENCHMARKS[k]
            inputs.append(next(key for key, v in bm.inputs.items()
                               if v is bm.default_input))

    jobs = [job_from_registry(k, inp, hw, budget=args.budget,
                              seed=args.seed, searcher=args.searcher)
            for k, inp in zip(kernels, inputs) for hw in hws]
    store = ConfigStore(args.store)
    pool = build_pool(args.backend, args.workers, args.devices_per_worker)
    t0 = time.time()
    try:
        report = FleetTuner(jobs, pool, store=store,
                            in_flight=args.in_flight,
                            publish_models=not args.no_publish,
                            verbose=args.verbose).run()
    finally:
        pool.close()
    wall = time.time() - t0

    print(f"[fleet] {len(jobs)} jobs on {args.backend} backend "
          f"({pool.workers} workers, in_flight={report.in_flight})")
    for r in sorted(report.results, key=lambda r: r.job):
        print(f"  {r.job:40s} {'warm' if r.warm_started else 'cold':4s} "
              f"{r.trials:3d} trials  best {r.best_runtime*1e3:9.3f}ms  "
              f"{r.best_config}")
    print(f"[fleet] pool clock {report.elapsed:.3f}s for "
          f"{report.busy:.3f} worker-seconds of measurement "
          f"(x{report.busy / max(report.elapsed, 1e-12):.2f} concurrency); "
          f"host wall {wall:.1f}s")
    if args.store:
        print(f"[fleet] store -> {args.store} ({len(store)} entries)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "backend": args.backend, "workers": pool.workers,
                "in_flight": report.in_flight,
                "pool_elapsed_s": report.elapsed, "busy_s": report.busy,
                "host_wall_s": wall,
                "jobs": [{
                    "job": r.job, "bucket": r.bucket, "hardware": r.hardware,
                    "searcher": r.searcher, "warm_started": r.warm_started,
                    "trials": r.trials, "best_runtime_s": r.best_runtime,
                    "best_config": r.best_config,
                } for r in report.results],
            }, f, indent=2)
        print(f"[fleet] -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
