"""Fleet tuning CLI: many (kernel × input × hardware) jobs, one pool.

Builds ``TuningJob``s from the kernel registry for every requested
(kernel, hardware) pair, runs them through a ``FleetTuner`` over the
chosen worker backend, and persists tuned configs + portable model
artifacts into a shared ``ConfigStore`` — so re-running with more hardware
(or more shapes) warm-starts from what the fleet already learned.

    PYTHONPATH=src python -m repro.launch.fleet \
        --kernels matmul,transpose --hw tpu_v4,tpu_v5e \
        --store fleet_store.json --workers 4 --budget 25

    # subprocess lanes, each with its own 2-device jax host runtime
    PYTHONPATH=src python -m repro.launch.fleet --backend subprocess \
        --workers 2 --devices-per-worker 2 --kernels matmul --hw tpu_v5e

    # whole-system mode: kernel tiles + train-step sharding + serve
    # geometry for one model-zoo entry, one fleet, one store
    PYTHONPATH=src python -m repro.launch.fleet --system qwen2.5-3b \
        --hw tpu_v5e --store system_store.json

    # or cherry-pick registered problems by kind:name spec
    PYTHONPATH=src python -m repro.launch.fleet \
        --problem sharding:qwen2.5-3b/train_4k --problem serve:p9n9
"""
from __future__ import annotations

import argparse
import json
import time


def build_pool(backend: str, workers: int, devices_per_worker: int):
    from repro.fleet import (SubprocessWorkerPool, ThreadWorkerPool,
                             VirtualWorkerPool)

    if backend == "virtual":
        return VirtualWorkerPool(workers=workers)
    if backend == "thread":
        return ThreadWorkerPool(workers=workers)
    if backend == "subprocess":
        return SubprocessWorkerPool(workers=workers,
                                    devices_per_worker=devices_per_worker)
    raise ValueError(f"unknown backend {backend!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--kernels", default="matmul,transpose",
                    help="comma-separated registry kernel names")
    ap.add_argument("--inputs", default=None,
                    help="comma-separated input keys, one per kernel "
                    "(default: each kernel's default input)")
    ap.add_argument("--problem", action="append", default=None,
                    help="tune registered problems 'kind:name' instead of "
                    "--kernels (repeatable / comma-separated), e.g. "
                    "kernel:matmul/128, sharding:qwen2.5-3b/train_4k, "
                    "serve:p9n9")
    ap.add_argument("--system", default=None,
                    help="whole-system mode: one invocation tunes kernel "
                    "tiles + train-step sharding + serve geometry for this "
                    "model-zoo entry through one fleet and one store "
                    "(overrides --kernels/--problem)")
    ap.add_argument("--hw", default="tpu_v4,tpu_v5e",
                    help="comma-separated hardware names (naming drift ok: "
                    "TPUv4 == tpu_v4)")
    ap.add_argument("--backend", default="virtual",
                    choices=("virtual", "thread", "subprocess"))
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--devices-per-worker", type=int, default=0,
                    help="subprocess backend: jax host devices per worker")
    ap.add_argument("--in-flight", type=int, default=None,
                    help="outstanding tests pool-wide (default: --workers)")
    ap.add_argument("--in-flight-max", type=int, default=None,
                    help="make in_flight ELASTIC between [--in-flight, "
                    "this]: the driver grows/shrinks outstanding work from "
                    "pool backpressure (live lanes, measurement variance)")
    ap.add_argument("--retries", type=int, default=2,
                    help="max resubmissions per failed test on another "
                    "lane (default: 2)")
    ap.add_argument("--known-bad-after", type=int, default=2,
                    help="mark a config known-bad after this many "
                    "failures of its own measurement (default: 2)")
    ap.add_argument("--straggler-factor", type=float, default=None,
                    help="time out tests outstanding longer than this "
                    "factor times the job's rolling cost estimate and "
                    "resubmit them elsewhere (default: disabled)")
    ap.add_argument("--park-factor", type=float, default=None,
                    help="park model-backed jobs whose measured best is "
                    "already within this factor of their predicted best "
                    "runtime (default: disabled)")
    ap.add_argument("--budget", type=int, default=25,
                    help="empirical-test budget per job")
    ap.add_argument("--searcher", default=None,
                    help="force one searcher for every job (default: "
                    "warm_start on store hit, random cold)")
    ap.add_argument("--store", default=None,
                    help="shared ConfigStore path (default: in-memory)")
    ap.add_argument("--no-publish", action="store_true",
                    help="do not train/publish missing model artifacts")
    ap.add_argument("--transfer", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="when every exact-space stored model misses a "
                    "job, warm-start it from the most structurally "
                    "similar same-kind space's model (--no-transfer pins "
                    "the legacy exact-space ladder)")
    ap.add_argument("--transfer-threshold", type=float, default=None,
                    help="minimum structural similarity (counter Jaccard "
                    "x parameter overlap, in [0,1]) a cross-space model "
                    "must clear to be used (default: the library's "
                    "conservative threshold)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write a JSON report here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.fleet import (FleetTuner, job_from_problem,
                             job_from_registry)
    from repro.kernels.registry import BENCHMARKS
    from repro.tuning import ConfigStore

    hws = [h.strip() for h in args.hw.split(",") if h.strip()]
    if args.system is not None:
        from repro.tuning.problem import system_problems
        try:
            problems = system_problems(args.system)
        except KeyError as exc:
            raise SystemExit(f"--system: {exc}")
        jobs = [job_from_problem(p, hw, budget=args.budget,
                                 seed=args.seed, searcher=args.searcher)
                for p in problems for hw in hws]
    elif args.problem:
        from repro.tuning.problem import parse_problem
        specs = [s.strip() for chunk in args.problem
                 for s in chunk.split(",") if s.strip()]
        problems = []
        for spec in specs:
            try:
                problems.append(parse_problem(spec))
            except (KeyError, ValueError) as exc:
                raise SystemExit(f"--problem {spec!r}: {exc}")
        jobs = [job_from_problem(p, hw, budget=args.budget,
                                 seed=args.seed, searcher=args.searcher)
                for p in problems for hw in hws]
    else:
        kernels = [k.strip() for k in args.kernels.split(",") if k.strip()]
        if args.inputs is not None:
            inputs = [i.strip() for i in args.inputs.split(",")]
            if len(inputs) != len(kernels):
                raise SystemExit(
                    "--inputs must list one key per --kernels entry")
        else:
            inputs = []
            for k in kernels:
                bm = BENCHMARKS[k]
                inputs.append(next(key for key, v in bm.inputs.items()
                                   if v is bm.default_input))
        jobs = [job_from_registry(k, inp, hw, budget=args.budget,
                                  seed=args.seed, searcher=args.searcher)
                for k, inp in zip(kernels, inputs) for hw in hws]
    store = ConfigStore(args.store)
    pool = build_pool(args.backend, args.workers, args.devices_per_worker)
    t0 = time.time()
    tuner = FleetTuner(jobs, pool, store=store,
                       in_flight=args.in_flight,
                       in_flight_max=args.in_flight_max,
                       retries=args.retries,
                       known_bad_after=args.known_bad_after,
                       straggler_factor=args.straggler_factor,
                       park_factor=args.park_factor,
                       publish_models=not args.no_publish,
                       transfer=args.transfer,
                       transfer_threshold=args.transfer_threshold,
                       verbose=args.verbose)
    # SIGINT/SIGTERM drain: stop filling, collect what is in flight,
    # publish/report the completed jobs (same contract as the daemon)
    from repro.launch.signals import install_drain_handlers

    draining = install_drain_handlers(tuner.stop)
    try:
        tuner.begin()
        while tuner.step(max_wait=0.5):
            pass
        report = tuner.finish()
    finally:
        pool.close()
    wall = time.time() - t0

    print(f"[fleet] {len(jobs)} jobs on {args.backend} backend "
          f"({pool.workers} workers, in_flight={report.in_flight})"
          + ("  [DRAINED EARLY]" if draining() else ""))
    for r in sorted(report.results, key=lambda r: r.job):
        mark = " [cancelled]" if r.cancelled else ""
        if r.transfer_from is not None:
            mark += (f" [transfer {r.transfer_from} "
                     f"~{r.transfer_similarity:.2f}]")
        print(f"  {r.job:40s} {'warm' if r.warm_started else 'cold':4s} "
              f"{r.trials:3d} trials  best {r.best_runtime*1e3:9.3f}ms  "
              f"{r.best_config}{mark}")
    print(f"[fleet] pool clock {report.elapsed:.3f}s for "
          f"{report.busy:.3f} worker-seconds of measurement "
          f"(x{report.busy / max(report.elapsed, 1e-12):.2f} concurrency); "
          f"host wall {wall:.1f}s")
    if report.failures or report.timeouts or report.parked:
        print(f"[fleet] faults: {report.failures} failed attempts "
              f"({report.known_bad} known-bad configs), "
              f"{report.timeouts} stragglers timed out, "
              f"{report.abandoned:.3f}s abandoned work charged to busy, "
              f"{report.parked} jobs parked, max retries used "
              f"{report.max_retries_used}")
    if args.store:
        print(f"[fleet] store -> {args.store} ({len(store)} entries)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "backend": args.backend, "workers": pool.workers,
                "in_flight": report.in_flight,
                "pool_elapsed_s": report.elapsed, "busy_s": report.busy,
                "host_wall_s": wall,
                "failures": report.failures,
                "timeouts": report.timeouts,
                "known_bad": report.known_bad,
                "abandoned_s": report.abandoned,
                "parked": report.parked,
                "drained": draining(),
                "jobs": [{
                    "job": r.job, "bucket": r.bucket, "hardware": r.hardware,
                    "searcher": r.searcher, "warm_started": r.warm_started,
                    "trials": r.trials, "best_runtime_s": r.best_runtime,
                    "best_config": r.best_config,
                    "failures": r.failures, "known_bad": r.known_bad,
                    "parked": r.parked, "cancelled": r.cancelled,
                    "transfer_from": r.transfer_from,
                    "transfer_similarity": r.transfer_similarity,
                } for r in report.results],
            }, f, indent=2)
        print(f"[fleet] -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
