"""Tuning-service daemon CLI: run a multi-tenant fleet behind a socket.

Starts a ``TuningDaemon`` — one worker pool, one elastic fleet, one
shared (optionally sharded) config/model corpus — listening for
JSON-lines tuning requests on localhost.  SIGINT/SIGTERM drain
gracefully: in-flight empirical tests finish, unfinished jobs resolve as
cancelled partials, the store is flushed.

    # sharded corpus, 4 thread workers, ephemeral port printed on start
    PYTHONPATH=src python -m repro.launch.daemon \
        --store-dir corpus/ --backend thread --workers 4 --port 7421

    # talk to it
    python -m repro.launch.serve --autotune --ticks 40 \
        --service 127.0.0.1:7421

Per-tenant worker-seconds budgets arrive with the requests themselves
(``tenant_budget_s`` on submit); ``--default-tenant-budget`` applies one
to tenants that never declare any.
"""
from __future__ import annotations

import argparse
import json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0: bind an ephemeral port and print it")
    ap.add_argument("--backend", default="thread",
                    choices=("virtual", "thread", "subprocess"))
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--devices-per-worker", type=int, default=0)
    ap.add_argument("--store-dir", default=None,
                    help="sharded corpus directory (the default)")
    ap.add_argument("--store", default=None,
                    help="single-file ConfigStore path instead of a "
                    "sharded corpus")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count when creating a new --store-dir")
    ap.add_argument("--budget", type=int, default=16,
                    help="default per-request trial budget")
    ap.add_argument("--max-active-jobs", type=int, default=32)
    ap.add_argument("--max-tenants", type=int, default=64)
    ap.add_argument("--max-active-per-tenant", type=int, default=4)
    ap.add_argument("--max-queued-per-tenant", type=int, default=16)
    ap.add_argument("--default-tenant-budget", type=float, default=None,
                    help="worker-seconds budget for tenants that never "
                    "declare one (default: unlimited)")
    ap.add_argument("--in-flight", type=int, default=None)
    ap.add_argument("--in-flight-max", type=int, default=None)
    ap.add_argument("--retries", type=int, default=2)
    ap.add_argument("--straggler-factor", type=float, default=None)
    ap.add_argument("--park-factor", type=float, default=None)
    ap.add_argument("--no-publish", action="store_true",
                    help="do not train/publish missing model artifacts")
    ap.add_argument("--gc-keep-hardware", default=None,
                    help="comma-separated hardware keys to KEEP on "
                    "periodic store GC (default: GC disabled)")
    ap.add_argument("--gc-every", type=float, default=60.0,
                    help="pool-seconds between GC passes")
    ap.add_argument("--journal", default=None,
                    help="write-ahead request journal path (default: "
                    "<store-dir>/journal.jsonl when using a store dir)")
    ap.add_argument("--no-journal", action="store_true",
                    help="disable the request journal entirely")
    ap.add_argument("--fsync", default="batch",
                    choices=("always", "batch", "off"),
                    help="journal durability mode: 'always' fsyncs every "
                    "record inline, 'batch' (default) group-commits — "
                    "acks still wait for the fsync covering their "
                    "records, but one flush covers a whole burst — "
                    "'off' never fsyncs (machine-crash unsafe, "
                    "process-kill safe)")
    ap.add_argument("--recover", action="store_true",
                    help="replay the journal on startup: restore "
                    "resolved requests, resubmit interrupted ones with "
                    "their remaining budget, restore tenant spend")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.launch.fleet import build_pool
    from repro.launch.signals import install_drain_handlers
    from repro.service import ShardedConfigStore, TuningDaemon
    from repro.service.journal import RequestJournal
    from repro.service.tenants import TenantManager
    from repro.tuning import ConfigStore

    import os
    if args.store is not None:
        store = ConfigStore(args.store)
        store_root = os.path.dirname(os.path.abspath(args.store))
    else:
        store_root = args.store_dir or "tuning_corpus"
        store = ShardedConfigStore(store_root, n_shards=args.shards)
    journal = None
    if not args.no_journal:
        journal = RequestJournal(
            args.journal or os.path.join(store_root, "journal.jsonl"),
            mode=args.fsync)
    if args.recover and journal is None:
        ap.error("--recover requires a journal (drop --no-journal)")
    pool = build_pool(args.backend, args.workers, args.devices_per_worker)
    gc_keep = None
    if args.gc_keep_hardware:
        gc_keep = {"keep_hardware": [h.strip() for h in
                                     args.gc_keep_hardware.split(",")
                                     if h.strip()]}
    daemon = TuningDaemon(
        pool, store, host=args.host, port=args.port,
        tenants=TenantManager(
            max_tenants=args.max_tenants,
            max_active_per_tenant=args.max_active_per_tenant,
            max_queued_per_tenant=args.max_queued_per_tenant,
            default_budget_s=args.default_tenant_budget),
        default_trial_budget=args.budget,
        max_active_jobs=args.max_active_jobs,
        gc_keep=gc_keep, gc_every_s=args.gc_every,
        journal=journal, recover=args.recover,
        verbose=args.verbose,
        in_flight=args.in_flight, in_flight_max=args.in_flight_max,
        retries=args.retries, straggler_factor=args.straggler_factor,
        park_factor=args.park_factor,
        publish_models=not args.no_publish)
    host, port = daemon.start()
    if daemon.recovery is not None:
        print(f"[daemon] recovered: {json.dumps(daemon.recovery)}",
              flush=True)
    print(f"[daemon] tuning service on {host}:{port} "
          f"({args.backend} backend, {pool.workers} workers, "
          f"store={store.path})", flush=True)
    install_drain_handlers(daemon.shutdown)
    try:
        daemon.wait()
    finally:
        pool.close()
    if daemon.final_report is not None:
        rep = daemon.final_report
        print(f"[daemon] drained: {len(rep.results)} jobs, "
              f"{rep.busy:.3f} worker-seconds on the pool clock")
    print(json.dumps({"tenants": daemon.tenants.snapshot()}, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
