"""Mixture-of-Experts feed-forward with GShard-style grouped einsum dispatch.

Tokens are split into routing groups of ``group_size``; each group routes its
tokens into per-expert capacity buckets via a (G, Tg, E, C) dispatch one-hot.
Dispatch/combine einsums keep the all-to-all pattern visible to GSPMD, and the
dispatch tensor stays O(T · k · cf · Tg) — bounded by the group size, not the
global token count.  Expert tensors carry the "expert" logical axis (EP over
the mesh model axis).  Supports shared (always-on) experts (DeepSeek-V2) and
top-1 routing (Llama-4-Scout style).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, Params, dense, mlp_apply, mlp_defs


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0         # always-active shared experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    group_size: int = 256     # routing-group tokens (bounds dispatch tensor)


def moe_defs(cfg: MoEConfig) -> Dict[str, ParamDef]:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    defs = {
        "router": ParamDef((d, e), ("embed", None), scale=0.02),
        "w_gate": ParamDef((e, d, f), ("expert", "embed", "mlp")),
        "w_up": ParamDef((e, d, f), ("expert", "embed", "mlp")),
        "w_down": ParamDef((e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.n_shared:
        defs["shared"] = mlp_defs(d, f * cfg.n_shared, gated=True)
    return defs


def moe_apply(p: Params, cfg: MoEConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    tg = min(cfg.group_size, t)
    assert t % tg == 0, (t, tg)
    g = t // tg
    xg = x.reshape(g, tg, d)

    logits = dense(xg, p["router"]).astype(jnp.float32)        # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style), over all tokens
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    capacity = int(cfg.capacity_factor * tg * k / e) + 1

    # bucket position of each (token, choice) within its expert, per group
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)      # (G, Tg, k, E)
    flat = onehot.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (G, Tg*k, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(g, tg, k)
    keep = pos < capacity

    # combine tensor: (G, Tg, E, C) = Σ_k gate · onehot(expert) ⊗ onehot(pos)
    combine = jnp.einsum(
        "gtke,gtkc->gtec",
        (gate_vals * keep).astype(x.dtype)[..., None]
        * jax.nn.one_hot(gate_idx, e, dtype=x.dtype),
        jax.nn.one_hot(pos, capacity, dtype=x.dtype),
    )
    dispatch = (combine > 0).astype(x.dtype)

    # expert inputs: (E, G, C, D)
    xin = jnp.einsum("gtec,gtd->egcd", dispatch, xg)
    h = jax.nn.silu(
        jnp.einsum("egcd,edf->egcf", xin, p["w_gate"])
    ) * jnp.einsum("egcd,edf->egcf", xin, p["w_up"])
    yout = jnp.einsum("egcf,efd->egcd", h, p["w_down"])        # (E, G, C, D)

    yg = jnp.einsum("gtec,egcd->gtd", combine, yout)

    out = yg.reshape(b, s, d)
    if cfg.n_shared:
        out = out + mlp_apply(p["shared"], x)
    return out, aux


def moe_apply_dropless(p: Params, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """Inference dispatch: exact, dropless, sorted-by-expert ragged matmuls.

    Serving paths must be prefill/decode consistent; capacity-bucket drops
    (acceptable statistical noise in training) would break that, so serving
    uses argsort dispatch + ``jax.lax.ragged_dot`` — the TPU-native grouped
    GEMM (vLLM/MegaBlocks-style dropless MoE).
    """
    b, sq, d = x.shape
    t = b * sq
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = dense(xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    flat_expert = gate_idx.reshape(-1)                        # (T*k,)
    order = jnp.argsort(flat_expert)
    tok_of = order // k                                       # source token
    xs = jnp.take(xt, tok_of, axis=0)                         # (T*k, D)
    group_sizes = jnp.bincount(flat_expert, length=e)

    h = jax.nn.silu(
        jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    ) * jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    ys = jax.lax.ragged_dot(h, p["w_down"], group_sizes)      # (T*k, D)

    g = jnp.take(gate_vals.reshape(-1), order)                # (T*k,)
    out = jnp.zeros((t, d), ys.dtype).at[tok_of].add(
        ys * g[:, None].astype(ys.dtype))
    out = out.reshape(b, sq, d).astype(x.dtype)
    if cfg.n_shared:
        out = out + mlp_apply(p["shared"], x)
    return out
