"""Decoder-only transformer LM: dense GQA, MLA, and MoE variants, with
optional vision/audio embedding prefix (VLM stub per assignment).

Layers are scanned (stacked params, ``jax.lax.scan``) to keep HLO size
O(1) in depth — essential for 512-device SPMD compiles.  Remat is applied
per-layer via ``jax.checkpoint`` with a configurable policy.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import shard_act
from repro.models.attention import (AttnConfig, gqa_apply, gqa_defs,
                                    gqa_init_cache, mla_apply, mla_defs,
                                    mla_init_cache)
from repro.models.common import (ParamDef, Params, cross_entropy_from_hidden,
                                 dense, init_params, logical_specs, mlp_apply,
                                 mlp_defs, rms_norm, stack_defs)
from repro.models.config import ArchConfig
from repro.models.moe import (MoEConfig, moe_apply,
                              moe_apply_dropless, moe_defs)


def attn_config(cfg: ArchConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.eff_head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        kv_lora_rank=cfg.kv_lora_rank,
        qk_rope_dim=cfg.qk_rope_dim,
        v_head_dim=cfg.v_head_dim,
    )


def moe_config(cfg: ArchConfig) -> MoEConfig:
    return MoEConfig(
        d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff or cfg.d_ff,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        n_shared=cfg.n_shared_experts,
    )


# =============================================================================
# Parameter definitions
# =============================================================================
def block_defs(cfg: ArchConfig) -> Dict[str, Any]:
    acfg = attn_config(cfg)
    defs: Dict[str, Any] = {
        "ln1": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "ln2": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "attn": mla_defs(acfg) if cfg.kv_lora_rank else gqa_defs(acfg),
    }
    if cfg.n_experts:
        defs["moe"] = moe_defs(moe_config(cfg))
    else:
        defs["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, gated=True)
    return defs


def lm_defs(cfg: ArchConfig) -> Dict[str, Any]:
    v = cfg.padded_vocab
    defs: Dict[str, Any] = {
        "embed": ParamDef((v, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "blocks": stack_defs(block_defs(cfg), cfg.n_layers),
        "final_ln": ParamDef((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, v), ("embed", "vocab"),
                                   scale=0.02)
    if cfg.frontend == "vision":
        defs["vis_proj"] = ParamDef((cfg.frontend_dim, cfg.d_model),
                                    (None, "embed"))
    return defs


# =============================================================================
# Forward
# =============================================================================
def _block_apply(cfg: ArchConfig, lp: Params, x: jax.Array,
                 kv_chunk: int) -> Tuple[jax.Array, jax.Array]:
    acfg = attn_config(cfg)
    h = rms_norm(x, lp["ln1"])
    if cfg.kv_lora_rank:
        h, _ = mla_apply(lp["attn"], acfg, h, kv_chunk=kv_chunk)
    else:
        h, _ = gqa_apply(lp["attn"], acfg, h, kv_chunk=kv_chunk)
    x = x + h
    x = shard_act(x, ("batch", None, None))
    h = rms_norm(x, lp["ln2"])
    if cfg.n_experts:
        h, aux = moe_apply(lp["moe"], moe_config(cfg), h)
    else:
        h, aux = mlp_apply(lp["mlp"], h, cfg.activation), jnp.float32(0.0)
    x = x + h
    return shard_act(x, ("batch", None, None)), aux


def embed_inputs(cfg: ArchConfig, params: Params, batch: Dict) -> jax.Array:
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        vis = dense(batch["patch_embeds"].astype(x.dtype), params["vis_proj"])
        x = jnp.concatenate([vis, x], axis=1)
    return shard_act(x, ("batch", None, None))


def forward_hidden(
    cfg: ArchConfig, params: Params, batch: Dict,
    remat: str = "nothing_saveable", kv_chunk: int = 1024,
) -> Tuple[jax.Array, jax.Array]:
    """Token (+prefix) embeddings -> final hidden states, scanning layers."""
    x = embed_inputs(cfg, params, batch)

    def body(carry, lp):
        x, aux = carry
        x, a = _block_apply(cfg, lp, x, kv_chunk)
        return (x, aux + a), None

    body_fn = body
    if remat != "none":
        policy = {
            "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
            "dots_saveable": jax.checkpoint_policies.dots_saveable,
            "dots_with_no_batch_dims": (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable),
        }[remat]
        body_fn = jax.checkpoint(body, policy=policy)

    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["blocks"])
    return rms_norm(x, params["final_ln"]), aux


def lm_loss(
    cfg: ArchConfig, params: Params, batch: Dict,
    remat: str = "nothing_saveable", kv_chunk: int = 1024,
    loss_chunks: int = 1,
) -> jax.Array:
    hidden, aux = forward_hidden(cfg, params, batch, remat, kv_chunk)
    w_out = params.get("lm_head")
    if w_out is None:
        w_out = params["embed"].T
    labels = batch["labels"]
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        hidden = hidden[:, batch["patch_embeds"].shape[1]:]
    ce = cross_entropy_from_hidden(hidden, w_out, labels,
                                   seq_chunks=loss_chunks)
    return ce + aux


# =============================================================================
# Serving: prefill + decode
# =============================================================================
def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    acfg = attn_config(cfg)
    one = (mla_init_cache(acfg, batch, max_seq, dtype) if cfg.kv_lora_rank
           else gqa_init_cache(acfg, batch, max_seq, dtype))
    # stack along layers for scan: every leaf gets a leading L axis
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one
    )


def decode_step(
    cfg: ArchConfig, params: Params, cache: Dict, batch: Dict,
) -> Tuple[jax.Array, Dict]:
    """One-token decode: batch["tokens"]: (B, 1) -> (logits, new cache).

    Layers are scanned; each layer emits only the NEW token's K/V.  The
    stacked cache is updated ONCE after the scan (a single in-place
    token-slot write instead of per-layer full-buffer rewrites).
    """
    acfg = attn_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)

    def body(x, scanned):
        lp, cache_l = scanned
        h = rms_norm(x, lp["ln1"])
        if cfg.kv_lora_rank:
            h, new_c = mla_apply(lp["attn"], acfg, h, cache=cache_l)
        else:
            h, new_c = gqa_apply(lp["attn"], acfg, h, cache=cache_l)
        x = x + h
        h = rms_norm(x, lp["ln2"])
        if cfg.n_experts:
            h = moe_apply_dropless(lp["moe"], moe_config(cfg), h)
        else:
            h = mlp_apply(lp["mlp"], h, cfg.activation)
        return x + h, new_c

    x, new_kv = jax.lax.scan(body, x, (params["blocks"], cache))
    new_cache = update_stacked_cache(cfg, cache, new_kv)
    x = rms_norm(x, params["final_ln"])
    w_out = params.get("lm_head")
    if w_out is None:
        w_out = params["embed"].T
    logits = dense(x, w_out)
    return logits, new_cache


def update_stacked_cache(cfg: ArchConfig, cache: Dict, new_kv: Dict) -> Dict:
    """Write all layers' new-token K/V into the stacked cache at pos."""
    pos = cache["pos"][0]
    if cfg.kv_lora_rank:
        c_kv = jax.lax.dynamic_update_slice(
            cache["c_kv"], new_kv["c_kv_new"], (0, 0, pos, 0))
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], new_kv["k_rope_new"], (0, 0, pos, 0))
        return {"c_kv": c_kv, "k_rope": k_rope, "pos": cache["pos"] + 1}
    k = jax.lax.dynamic_update_slice(
        cache["k"], new_kv["k_new"], (0, 0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], new_kv["v_new"], (0, 0, pos, 0, 0))
    return {"k": k, "v": v, "pos": cache["pos"] + 1}


def prefill(
    cfg: ArchConfig, params: Params, batch: Dict, max_seq: int,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Dict]:
    """Process the prompt, building the KV cache; returns last-pos logits.

    Implemented as forward_hidden for the hidden states plus cache
    construction per layer (recomputing K/V projections — cheap relative to
    attention itself and keeps the scan carry small).
    """
    acfg = attn_config(cfg)
    x = embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    pad = max_seq - s

    def body(x, lp):
        h = rms_norm(x, lp["ln1"])
        if cfg.kv_lora_rank:
            c_kv = dense(h, lp["attn"]["w_dkv"])
            from repro.models.common import apply_rope
            k_rope = apply_rope(
                dense(h, lp["attn"]["w_kr"])[:, :, None, :],
                jnp.arange(s)[None, :], cfg.rope_theta)[:, :, 0, :]
            cache_l = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
                "pos": jnp.int32(s),
            }
            h, _ = mla_apply(lp["attn"], acfg, h)
        else:
            hk, hd = acfg.n_kv_heads, acfg.head_dim
            from repro.models.common import apply_rope
            k = dense(h, lp["attn"]["wk"], lp["attn"].get("bk")).reshape(
                b, s, hk, hd)
            k = apply_rope(k, jnp.arange(s)[None, :], cfg.rope_theta)
            v = dense(h, lp["attn"]["wv"], lp["attn"].get("bv")).reshape(
                b, s, hk, hd)
            cache_l = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "pos": jnp.int32(s),
            }
            h, _ = gqa_apply(lp["attn"], acfg, h)
        x = x + h
        h = rms_norm(x, lp["ln2"])
        if cfg.n_experts:
            # §Perf: global argsort dispatch all-gathers the full token set
            # across the data axis — at large-T prefill the grouped-capacity
            # einsum dispatch keeps routing local to each shard (the
            # collective-bound fix for llama4-scout prefill_32k); dropless
            # stays for small T where exactness is cheap
            if b * s > 65536:
                h, _ = moe_apply(lp["moe"], moe_config(cfg), h)
            else:
                h = moe_apply_dropless(lp["moe"], moe_config(cfg), h)
        else:
            h = mlp_apply(lp["mlp"], h, cfg.activation)
        return x + h, cache_l

    x, cache = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x[:, -1:], params["final_ln"])
    w_out = params.get("lm_head")
    if w_out is None:
        w_out = params["embed"].T
    return dense(x, w_out), cache
