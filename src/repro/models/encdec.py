"""Encoder-decoder transformer (Seamless-M4T backbone, audio frontend stub).

Per the assignment, the speech frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, T_enc, frontend_dim); the encoder is a
bidirectional transformer over their projection, the decoder a causal
transformer with cross-attention.  "24L" is realized as 24 encoder + 24
decoder layers (seamless-large sizing; noted in DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import shard_act
from repro.models.attention import (AttnConfig, _chunked_attention, gqa_apply,
                                    gqa_defs, gqa_init_cache)
from repro.models.common import (ParamDef, Params, apply_rope,
                                 cross_entropy_from_hidden, dense,
                                 init_params, mlp_apply, mlp_defs, rms_norm,
                                 stack_defs)
from repro.models.config import ArchConfig
from repro.models.transformer import attn_config


def _xattn_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, h, hk = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.eff_head_dim
    return {
        "wq": ParamDef((d, h * hd), ("embed", "heads")),
        "wk": ParamDef((d, hk * hd), ("embed", "kv")),
        "wv": ParamDef((d, hk * hd), ("embed", "kv")),
        "wo": ParamDef((h * hd, d), ("heads", "embed")),
    }


def encdec_defs(cfg: ArchConfig) -> Dict[str, Any]:
    enc_block = {
        "ln1": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "attn": gqa_defs(attn_config(cfg)),
        "ln2": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff, gated=True),
    }
    dec_block = {
        "ln1": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "attn": gqa_defs(attn_config(cfg)),
        "ln_x": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "xattn": _xattn_defs(cfg),
        "ln2": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff, gated=True),
    }
    v = cfg.padded_vocab
    return {
        "frontend_proj": ParamDef((cfg.frontend_dim, cfg.d_model),
                                  (None, "embed")),
        "embed": ParamDef((v, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "enc": stack_defs(enc_block, cfg.enc_layers),
        "enc_ln": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "dec": stack_defs(dec_block, cfg.dec_layers),
        "final_ln": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "lm_head": ParamDef((cfg.d_model, v), ("embed", "vocab"), scale=0.02),
    }


def _encode(cfg: ArchConfig, params: Params, frames: jax.Array,
            remat: str = "nothing_saveable") -> jax.Array:
    acfg = attn_config(cfg)._replace(causal=False)
    x = dense(frames.astype(jnp.bfloat16 if cfg.dtype == "bfloat16"
                            else jnp.float32), params["frontend_proj"])
    x = shard_act(x, ("batch", None, None))

    def body(x, lp):
        h, _ = gqa_apply(lp["attn"], acfg, rms_norm(x, lp["ln1"]))
        x = x + h
        x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"]), cfg.activation)
        return shard_act(x, ("batch", None, None)), None

    body_fn = body if remat == "none" else jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    return rms_norm(x, params["enc_ln"])


def _cross_attend(cfg: ArchConfig, xp: Params, x: jax.Array,
                  enc_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    b, s, _ = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.eff_head_dim
    q = dense(x, xp["wq"]).reshape(b, s, h, hd)
    k, v = enc_kv
    out = _chunked_attention(q, k, v, causal=False)
    return dense(out.reshape(b, s, h * hd).astype(x.dtype), xp["wo"])


def _enc_kv(cfg: ArchConfig, xp: Params, enc_out: jax.Array):
    b, t, _ = enc_out.shape
    hk, hd = cfg.n_kv_heads, cfg.eff_head_dim
    k = dense(enc_out, xp["wk"]).reshape(b, t, hk, hd)
    v = dense(enc_out, xp["wv"]).reshape(b, t, hk, hd)
    return k, v


def encdec_loss(cfg: ArchConfig, params: Params, batch: Dict,
                remat: str = "nothing_saveable", loss_chunks: int = 1,
                **_) -> jax.Array:
    enc_out = _encode(cfg, params, batch["frames"], remat)
    acfg = attn_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = shard_act(x, ("batch", None, None))

    def body(x, lp):
        h, _ = gqa_apply(lp["attn"], acfg, rms_norm(x, lp["ln1"]))
        x = x + h
        kv = _enc_kv(cfg, lp["xattn"], enc_out)
        x = x + _cross_attend(cfg, lp["xattn"], rms_norm(x, lp["ln_x"]), kv)
        x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"]), cfg.activation)
        return shard_act(x, ("batch", None, None)), None

    body_fn = body if remat == "none" else jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    hidden = rms_norm(x, params["final_ln"])
    return cross_entropy_from_hidden(hidden, params["lm_head"],
                                     batch["labels"], seq_chunks=loss_chunks)


def encdec_init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype):
    a1 = gqa_init_cache(attn_config(cfg), batch, max_seq, dtype)
    self_c = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.dec_layers,) + a.shape).copy(), a1)
    hk, hd = cfg.n_kv_heads, cfg.eff_head_dim
    t_enc = cfg.frontend_len
    cross = {
        "k": jnp.zeros((cfg.dec_layers, batch, t_enc, hk, hd), dtype),
        "v": jnp.zeros((cfg.dec_layers, batch, t_enc, hk, hd), dtype),
    }
    return {"self": self_c, "cross": cross}


def encdec_prefill(cfg: ArchConfig, params: Params, batch: Dict,
                   max_seq: int, **_) -> Tuple[jax.Array, Dict]:
    enc_out = _encode(cfg, params, batch["frames"])
    acfg = attn_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    b, s, _ = x.shape
    pad = max_seq - s
    hk, hd = cfg.n_kv_heads, cfg.eff_head_dim

    def body(x, lp):
        h_in = rms_norm(x, lp["ln1"])
        k = dense(h_in, lp["attn"]["wk"]).reshape(b, s, hk, hd)
        k = apply_rope(k, jnp.arange(s)[None, :], cfg.rope_theta)
        v = dense(h_in, lp["attn"]["wv"]).reshape(b, s, hk, hd)
        self_c = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "pos": jnp.int32(s),
        }
        h, _ = gqa_apply(lp["attn"], acfg, h_in)
        x = x + h
        kv = _enc_kv(cfg, lp["xattn"], enc_out)
        x = x + _cross_attend(cfg, lp["xattn"], rms_norm(x, lp["ln_x"]), kv)
        x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"]), cfg.activation)
        return x, (self_c, {"k": kv[0], "v": kv[1]})

    x, (self_c, cross_c) = jax.lax.scan(body, x, params["dec"])
    hidden = rms_norm(x[:, -1:], params["final_ln"])
    return dense(hidden, params["lm_head"]), {"self": self_c,
                                              "cross": cross_c}


def encdec_decode(cfg: ArchConfig, params: Params, cache: Dict, batch: Dict
                  ) -> Tuple[jax.Array, Dict]:
    acfg = attn_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)

    def body(x, scanned):
        lp, self_c, cross_c = scanned
        h, new_kv = gqa_apply(lp["attn"], acfg, rms_norm(x, lp["ln1"]),
                              cache=self_c)
        x = x + h
        x = x + _cross_attend(cfg, lp["xattn"], rms_norm(x, lp["ln_x"]),
                              (cross_c["k"], cross_c["v"]))
        x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"]), cfg.activation)
        return x, new_kv

    x, new_kv = jax.lax.scan(body, x,
                             (params["dec"], cache["self"], cache["cross"]))
    sc = cache["self"]
    pos = sc["pos"][0]
    new_self = {
        "k": jax.lax.dynamic_update_slice(
            sc["k"], new_kv["k_new"], (0, 0, pos, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            sc["v"], new_kv["v_new"], (0, 0, pos, 0, 0)),
        "pos": sc["pos"] + 1,
    }
    hidden = rms_norm(x, params["final_ln"])
    logits = dense(hidden, params["lm_head"])
    return logits, {"self": new_self, "cross": cache["cross"]}
