"""Model substrate: parameter definitions with logical sharding axes,
norms, linear layers, RoPE, MLP variants, and the chunked cross-entropy.

Params are nested dicts of arrays.  Every leaf is declared via ``ParamDef``
(shape + logical axes + initializer) so shapes and shardings can never drift
apart; ``init_params`` materializes arrays and ``logical_specs`` extracts the
logical-axis tree consumed by distributed/sharding.py.

Logical axes used across the zoo:
    "layers"  — scan-over-layers stacking dim (never sharded)
    "embed"   — d_model dim          (FSDP: sharded over the data axis)
    "heads"   — attention head-dim product (TP: sharded over model axis)
    "kv"      — kv head-dim product  (TP when divisible)
    "mlp"     — feed-forward hidden  (TP)
    "vocab"   — vocabulary           (TP)
    "expert"  — MoE expert dim       (EP over the model axis)
    None      — replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    scale: Optional[float] = None  # stddev; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.spec), (self.shape, self.spec)


def _tree_map_defs(fn: Callable, defs):
    if isinstance(defs, ParamDef):
        return fn(defs)
    return {k: _tree_map_defs(fn, v) for k, v in defs.items()}


def init_params(rng: jax.Array, defs, dtype=jnp.float32) -> Params:
    """Materialize arrays for a ParamDef tree (deterministic per-leaf keys)."""
    leaves = []

    def collect(d, path):
        if isinstance(d, ParamDef):
            leaves.append((path, d))
        else:
            for k in sorted(d):
                collect(d[k], path + (k,))

    collect(defs, ())
    keys = jax.random.split(rng, max(len(leaves), 1))

    out: Params = {}
    for (path, d), key in zip(leaves, keys):
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(fan_in)
            arr = (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = arr
    return out


def logical_specs(defs):
    """ParamDef tree -> tree of logical-axis tuples (mirrors init_params)."""
    return _tree_map_defs(lambda d: d.spec, defs)


def abstract_params(defs, dtype=jnp.float32):
    """ParamDef tree -> ShapeDtypeStruct tree (dry-run, no allocation)."""
    return _tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs
    )


def stack_defs(defs, n: int):
    """Prepend a scan-over-layers axis to every leaf."""
    return _tree_map_defs(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.spec, d.init,
                           d.scale),
        defs,
    )


# =============================================================================
# Elementary layers (pure functions over param dicts)
# =============================================================================
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * gamma + beta).astype(dt)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y


# --- rotary position embeddings ----------------------------------------------
def rope_freqs(head_dim: int, max_pos: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(t, inv)          # (max_pos, head_dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]   # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --- gated MLPs ----------------------------------------------------------------
def mlp_defs(d_model: int, d_ff: int, gated: bool = True) -> Dict[str, ParamDef]:
    defs = {
        "w_up": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w_down": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }
    if gated:
        defs["w_gate"] = ParamDef((d_model, d_ff), ("embed", "mlp"))
    return defs


def mlp_apply(p: Params, x: jax.Array, activation: str = "silu") -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
           "relu": jax.nn.relu}[activation]
    up = dense(x, p["w_up"])
    if "w_gate" in p:
        up = act(dense(x, p["w_gate"])) * up
    else:
        up = act(up)
    return dense(up, p["w_down"])


# =============================================================================
# Loss: cross-entropy, optionally chunked along sequence to bound the
# (tokens, vocab) logits working set (beyond-paper memory optimization).
# =============================================================================
def cross_entropy_from_hidden(
    hidden: jax.Array,        # (B, S, D)
    w_out: jax.Array,         # (D, V)
    labels: jax.Array,        # (B, S) int32
    seq_chunks: int = 1,
) -> jax.Array:
    b, s, d = hidden.shape
    v = w_out.shape[-1]
    if seq_chunks <= 1:
        logits = jnp.einsum("bsd,dv->bsv", hidden, w_out).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    assert s % seq_chunks == 0, (s, seq_chunks)
    cs = s // seq_chunks
    h = hidden.reshape(b, seq_chunks, cs, d).swapaxes(0, 1)   # (C, B, cs, D)
    y = labels.reshape(b, seq_chunks, cs).swapaxes(0, 1)      # (C, B, cs)

    def chunk_loss(carry, hy):
        hc, yc = hy
        logits = jnp.einsum("bsd,dv->bsv", hc, w_out).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (h, y))
    return total / (b * s)
