"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly recurrent with chunk-level rematerialization).

mLSTM is linear-attention-like: C_t = f_t·C_{t-1} + i_t·v_t k_tᵀ,
y_t = (C_t q_t) / max(|n_t·q_t|, 1).  The chunkwise form mirrors the SSD
decomposition in ssm.py — intra-chunk decay-masked attention + a small
recurrent (H, Pv, Pk) state across chunks, which is the TPU-native way to
run it (MXU matmuls instead of a per-token scan).

sLSTM keeps per-feature scalar state with a block-diagonal recurrent matrix —
inherently sequential, scanned over time with jax.checkpoint per chunk to
bound saved residuals.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, Params, dense, rms_norm

_CLIP = 15.0


class XLSTMConfig(NamedTuple):
    d_model: int
    n_heads: int = 4
    chunk: int = 128
    proj_factor: float = 2.0   # mLSTM up-projection

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_up(self) -> int:
        return int(self.d_model * self.proj_factor)


# =============================================================================
# mLSTM
# =============================================================================
def mlstm_defs(cfg: XLSTMConfig) -> Dict[str, ParamDef]:
    d, du, h = cfg.d_model, cfg.d_up, cfg.n_heads
    hd = du // h
    return {
        "w_up": ParamDef((d, 2 * du), ("embed", "mlp")),      # x branch + gate
        "wq": ParamDef((du, du), ("mlp", "heads")),
        "wk": ParamDef((du, du), ("mlp", "heads")),
        "wv": ParamDef((du, du), ("mlp", "heads")),
        "w_if": ParamDef((du, 2 * h), ("mlp", None), scale=0.02),
        "b_if": ParamDef((2 * h,), (None,), init="zeros"),
        "norm_g": ParamDef((du,), ("mlp",), init="ones"),
        "w_out": ParamDef((du, d), ("mlp", "embed")),
    }


def mlstm_apply(
    p: Params,
    cfg: XLSTMConfig,
    x: jax.Array,                        # (B, S, D)
    cache: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s, _ = x.shape
    h = cfg.n_heads
    du = cfg.d_up
    hd = du // h

    up = dense(x, p["w_up"])
    xb, gate = up[..., :du], up[..., du:]
    q = dense(xb, p["wq"]).reshape(b, s, h, hd)
    k = dense(xb, p["wk"]).reshape(b, s, h, hd) / (hd ** 0.5)
    v = dense(xb, p["wv"]).reshape(b, s, h, hd)
    gates = dense(xb, p["w_if"]) + p["b_if"]
    logi = jnp.clip(gates[..., :h].astype(jnp.float32), -_CLIP, _CLIP)
    logf = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32))  # ≤ 0

    if cache is not None:
        return _mlstm_decode(p, cfg, x, q, k, v, logi, logf, gate, cache)

    L = min(cfg.chunk, s)
    assert s % L == 0
    nc = s // L
    qc = q.reshape(b, nc, L, h, hd).astype(jnp.float32)
    kc = k.reshape(b, nc, L, h, hd).astype(jnp.float32)
    vc = v.reshape(b, nc, L, h, hd).astype(jnp.float32)
    li = logi.reshape(b, nc, L, h)
    lf = logf.reshape(b, nc, L, h)
    cum = jnp.cumsum(lf, axis=2)                          # (B, C#, L, H)

    # intra-chunk: D[t,s] = exp(cum_t - cum_s + logi_s), s <= t
    ldecay = jnp.clip(
        cum[:, :, :, None, :] - cum[:, :, None, :, :]
        + li[:, :, None, :, :], -_CLIP, _CLIP
    )
    tri = jnp.tril(jnp.ones((L, L), bool))
    dmat = jnp.where(tri[None, None, :, :, None], jnp.exp(ldecay), 0.0)
    sqk = jnp.einsum("bcthd,bcshd->bctsh", qc, kc)
    num_intra = jnp.einsum("bctsh,bcshd->bcthd", sqk * dmat, vc)
    den_intra = jnp.einsum("bctsh->bcth", sqk * dmat)

    # chunk-boundary states: C_end = Σ_s exp(cum_L - cum_s + logi_s) v_s k_sᵀ
    w_end = jnp.exp(jnp.clip(
        cum[:, :, -1:, :] - cum + li, -_CLIP, _CLIP))     # (B,C#,L,H)
    c_end = jnp.einsum("bcsh,bcshd,bcshe->bchde", w_end, vc, kc)
    n_end = jnp.einsum("bcsh,bcshd->bchd", w_end, kc)

    def carry(carry_in, inp):
        c_prev, n_prev = carry_in
        c_e, n_e, dec = inp
        c_new = c_prev * dec[:, :, None, None] + c_e
        n_new = n_prev * dec[:, :, None] + n_e
        return (c_new, n_new), (c_prev, n_prev)

    dec_end = jnp.exp(cum[:, :, -1, :])                   # (B, C#, H)
    c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    (_, _), (c_in, n_in) = jax.lax.scan(
        carry, (c0, n0),
        (jnp.moveaxis(c_end, 1, 0), jnp.moveaxis(n_end, 1, 0),
         jnp.moveaxis(dec_end, 1, 0)),
    )
    c_in = jnp.moveaxis(c_in, 0, 1)                       # (B, C#, H, Pv, Pk)
    n_in = jnp.moveaxis(n_in, 0, 1)                       # (B, C#, H, Pk)

    scale_t = jnp.exp(cum)                                # (B, C#, L, H)
    num = num_intra + jnp.einsum("bcthe,bchde->bcthd", qc,
                                 c_in) * scale_t[..., None]
    den = den_intra + jnp.einsum("bcthe,bche->bcth", qc, n_in) * scale_t
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = y.reshape(b, s, du).astype(x.dtype)
    y = rms_norm(y, p["norm_g"]) * jax.nn.silu(gate)
    return dense(y, p["w_out"]), None


def _mlstm_decode(p, cfg, x, q, k, v, logi, logf, gate, cache):
    b = x.shape[0]
    h, du = cfg.n_heads, cfg.d_up
    hd = du // h
    qf = q[:, 0].astype(jnp.float32)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    i1 = jnp.exp(logi[:, 0])                              # (B, H)
    f1 = jnp.exp(logf[:, 0])
    c_new = cache["c"] * f1[:, :, None, None] + jnp.einsum(
        "bhd,bhe->bhde", i1[..., None] * vf, kf)
    n_new = cache["n"] * f1[:, :, None] + i1[..., None] * kf
    num = jnp.einsum("bhe,bhde->bhd", qf, c_new)
    den = jnp.einsum("bhe,bhe->bh", qf, n_new)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = y.reshape(b, 1, du).astype(x.dtype)
    y = rms_norm(y, p["norm_g"]) * jax.nn.silu(gate)
    new_cache = {"c": c_new, "n": n_new, "pos": cache["pos"] + 1}
    return dense(y, p["w_out"]), new_cache


def mlstm_init_cache(cfg: XLSTMConfig, batch: int):
    h = cfg.n_heads
    hd = cfg.d_up // h
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "pos": jnp.int32(0),
    }


# =============================================================================
# sLSTM
# =============================================================================
def slstm_defs(cfg: XLSTMConfig) -> Dict[str, ParamDef]:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    return {
        # input projections for z, i, f, o gates
        "w_x": ParamDef((d, 4 * d), ("embed", "mlp")),
        # block-diagonal recurrent weights: per head (hd, 4*hd)
        "w_r": ParamDef((h, hd, 4 * hd), (None, None, None), scale=0.02),
        "b": ParamDef((4 * d,), (None,), init="zeros"),
        "norm_g": ParamDef((d,), ("embed",), init="ones"),
    }


def _slstm_step(p, cfg, carry, xt):
    """One recurrent step; xt: (B, 4*D) pre-activation from the input proj."""
    h_prev, c_prev, n_prev, m_prev = carry
    b = h_prev.shape[0]
    hh, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    rec = jnp.einsum("bhd,hde->bhe", h_prev.reshape(b, hh, hd),
                     p["w_r"]).reshape(b, 4 * cfg.d_model)
    pre = xt + rec + p["b"]
    z, i_pre, f_pre, o = jnp.split(pre, 4, axis=-1)
    logi = jnp.clip(i_pre.astype(jnp.float32), -_CLIP, _CLIP)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m_new = jnp.maximum(logf + m_prev, logi)
    i_s = jnp.exp(logi - m_new)
    f_s = jnp.exp(logf + m_prev - m_new)
    c_new = f_s * c_prev + i_s * jnp.tanh(z.astype(jnp.float32))
    n_new = f_s * n_prev + i_s
    h_new = jax.nn.sigmoid(o.astype(jnp.float32)) * c_new / jnp.maximum(
        n_new, 1.0)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_apply(
    p: Params,
    cfg: XLSTMConfig,
    x: jax.Array,
    cache: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s, d = x.shape
    xp = dense(x, p["w_x"])                                # (B, S, 4D)

    if cache is not None:
        carry = (cache["h"], cache["c"], cache["n"], cache["m"])
        carry, h = _slstm_step(p, cfg, carry, xp[:, 0])
        y = rms_norm(h[:, None, :].astype(x.dtype), p["norm_g"])
        new_cache = {"h": carry[0], "c": carry[1], "n": carry[2],
                     "m": carry[3], "pos": cache["pos"] + 1}
        return y, new_cache

    L = min(cfg.chunk, s)
    assert s % L == 0
    nc = s // L
    xc = xp.reshape(b, nc, L, 4 * d).swapaxes(0, 1)        # (C#, B, L, 4D)

    @jax.checkpoint
    def chunk_fn(carry, xch):
        def step(cr, xt):
            return _slstm_step(p, cfg, cr, xt)
        carry, hs = jax.lax.scan(step, carry, xch.swapaxes(0, 1))
        return carry, hs.swapaxes(0, 1)                    # (B, L, D)

    zero = jnp.zeros((b, d), jnp.float32)
    carry0 = (zero, zero, zero, zero - _CLIP)
    _, hs = jax.lax.scan(chunk_fn, carry0, xc)             # (C#, B, L, D)
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    return rms_norm(y, p["norm_g"]), None


def slstm_init_cache(cfg: XLSTMConfig, batch: int):
    zero = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return {"h": zero, "c": zero, "n": zero, "m": zero - _CLIP,
            "pos": jnp.int32(0)}
