"""Unified architecture configuration covering the 10 assigned families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qkv_bias: bool = False
    activation: str = "silu"     # "gelu" => GeGLU-style gated GELU
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert hidden (0 => d_ff)
    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    v_head_dim: int = 0
    # hybrid / SSM
    ssm_state: int = 0
    mamba_per_attn: int = 0      # zamba2: mamba layers per shared-attn block
    xlstm: bool = False
    # encoder-decoder (audio)
    enc_layers: int = 0
    dec_layers: int = 0
    # modality frontend stub (assignment: precomputed embeddings)
    frontend: str = ""           # "" | "vision" | "audio"
    frontend_dim: int = 0
    frontend_len: int = 0
    # capabilities
    sub_quadratic: bool = False  # may run long_500k
    has_decoder: bool = True
    # numerics
    dtype: str = "bfloat16"

    @property
    def eff_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for clean TP sharding."""
        return -(-self.vocab_size // 256) * 256

    def scaled(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k needs sub-quadratic attention; decode
    shapes need a decoder."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: long_500k skipped (see DESIGN.md)"
    if shape.kind == "decode" and not arch.has_decoder:
        return False, "encoder-only arch: no decode step"
    return True, ""
