"""Model registry: ArchConfig -> Model (init/loss/prefill/decode/input_specs).

``input_specs`` returns ShapeDtypeStruct stand-ins only (weak-type-correct,
shardable, no allocation) — the dry-run contract.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer
from repro.models.common import (abstract_params, init_params, logical_specs)
from repro.models.config import ArchConfig, ShapeConfig


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    defs: Any

    # --- parameters -----------------------------------------------------------
    def init(self, rng: jax.Array):
        return init_params(rng, self.defs, _dtype(self.cfg))

    def abstract(self):
        return abstract_params(self.defs, _dtype(self.cfg))

    def specs(self):
        return logical_specs(self.defs)

    # --- compute --------------------------------------------------------------
    def loss(self, params, batch, **opts) -> jax.Array:
        cfg = self.cfg
        if cfg.xlstm:
            return hybrid.xlstm_loss(cfg, params, batch, **opts)
        if cfg.mamba_per_attn:
            return hybrid.zamba2_loss(cfg, params, batch, **opts)
        if cfg.enc_layers:
            return encdec.encdec_loss(cfg, params, batch, **opts)
        return transformer.lm_loss(cfg, params, batch, **opts)

    def prefill(self, params, batch, max_seq: int, **opts):
        cfg = self.cfg
        if cfg.xlstm:
            return hybrid.xlstm_prefill(cfg, params, batch, max_seq, **opts)
        if cfg.mamba_per_attn:
            return hybrid.zamba2_prefill(cfg, params, batch, max_seq, **opts)
        if cfg.enc_layers:
            return encdec.encdec_prefill(cfg, params, batch, max_seq, **opts)
        return transformer.prefill(cfg, params, batch, max_seq, **opts)

    def decode(self, params, cache, batch):
        cfg = self.cfg
        if cfg.xlstm:
            return hybrid.xlstm_decode(cfg, params, cache, batch)
        if cfg.mamba_per_attn:
            return hybrid.zamba2_decode(cfg, params, cache, batch)
        if cfg.enc_layers:
            return encdec.encdec_decode(cfg, params, cache, batch)
        return transformer.decode_step(cfg, params, cache, batch)

    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        if cfg.xlstm:
            return hybrid.xlstm_init_cache(cfg, batch, max_seq, dt)
        if cfg.mamba_per_attn:
            return hybrid.zamba2_init_cache(cfg, batch, max_seq, dt)
        if cfg.enc_layers:
            return encdec.encdec_init_cache(cfg, batch, max_seq, dt)
        return transformer.init_cache(cfg, batch, max_seq, dt)

    def cache_specs(self, batch: int, max_seq: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq))

    # --- dry-run inputs ---------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f32 = jnp.float32
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        elif shape.kind == "prefill":
            # modality prefixes count toward the sequence budget
            s_text = s - (cfg.frontend_len if cfg.frontend == "vision" else 0)
            specs = {"tokens": jax.ShapeDtypeStruct((b, s_text), i32)}
        else:  # decode: one new token against a cache of length s
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.frontend == "vision" and shape.kind != "decode":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.frontend_dim), f32)
        if cfg.frontend == "audio" and shape.kind != "decode":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.frontend_dim), f32)
        return specs

    # --- bookkeeping --------------------------------------------------------------
    def param_count(self) -> int:
        total = 0

        def walk(d):
            nonlocal total
            if hasattr(d, "shape"):
                n = 1
                for x in d.shape:
                    n *= x
                total += n
            else:
                for v in d.values():
                    walk(v)

        walk(self.defs)
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (shared + top_k of routed)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.n_experts:
            return total
        f = cfg.moe_d_ff or cfg.d_ff
        per_expert = 3 * cfg.d_model * f
        routed_all = cfg.n_layers * cfg.n_experts * per_expert
        routed_active = cfg.n_layers * cfg.top_k * per_expert
        return total - routed_all + routed_active


def build_model(cfg: ArchConfig) -> Model:
    if cfg.xlstm:
        defs = hybrid.xlstm_defs(cfg)
    elif cfg.mamba_per_attn:
        defs = hybrid.zamba2_defs(cfg)
    elif cfg.enc_layers:
        defs = encdec.encdec_defs(cfg)
    else:
        defs = transformer.lm_defs(cfg)
    return Model(cfg=cfg, defs=defs)
