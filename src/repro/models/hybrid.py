"""Hybrid and SSM language models: Zamba2 (Mamba2 + shared attention) and
xLSTM (alternating mLSTM/sLSTM blocks).

Zamba2: the depth is organized into superblocks of ``mamba_per_attn`` Mamba2
layers followed by ONE shared transformer block (single parameter set reused
at every superblock — Zamba's signature parameter saving).  Superblocks are
scanned; the shared block rides along as a closure constant.

xLSTM: layers alternate mLSTM (chunkwise-parallel, linear attention-like)
and sLSTM (recurrent); pairs are scanned.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import shard_act
from repro.models.attention import AttnConfig, gqa_apply, gqa_defs, gqa_init_cache
from repro.models.common import (ParamDef, Params, cross_entropy_from_hidden,
                                 dense, mlp_apply, mlp_defs, rms_norm,
                                 stack_defs)
from repro.models.config import ArchConfig
from repro.models.ssm import (Mamba2Config, mamba2_apply, mamba2_defs,
                              mamba2_init_cache)
from repro.models.transformer import attn_config
from repro.models.xlstm import (XLSTMConfig, mlstm_apply, mlstm_defs,
                                mlstm_init_cache, slstm_apply, slstm_defs,
                                slstm_init_cache)


# =============================================================================
# Zamba2
# =============================================================================
def mamba_config(cfg: ArchConfig) -> Mamba2Config:
    return Mamba2Config(d_model=cfg.d_model, d_state=cfg.ssm_state)


def zamba2_defs(cfg: ArchConfig) -> Dict[str, Any]:
    per = cfg.mamba_per_attn
    assert cfg.n_layers % per == 0
    n_super = cfg.n_layers // per
    mcfg = mamba_config(cfg)
    mamba_block = {
        "ln": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "mamba": mamba2_defs(mcfg),
    }
    shared_block = {
        "ln1": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "ln2": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "attn": gqa_defs(attn_config(cfg)),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff, gated=True),
    }
    v = cfg.padded_vocab
    return {
        "embed": ParamDef((v, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "supers": stack_defs(stack_defs(mamba_block, per), n_super),
        "shared": shared_block,
        "final_ln": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "lm_head": ParamDef((cfg.d_model, v), ("embed", "vocab"), scale=0.02),
    }


def _zamba_shared_apply(cfg: ArchConfig, sp: Params, x, cache=None):
    acfg = attn_config(cfg)
    h, new_c = gqa_apply(sp["attn"], acfg, rms_norm(x, sp["ln1"]),
                         cache=cache)
    x = x + h
    x = x + mlp_apply(sp["mlp"], rms_norm(x, sp["ln2"]), cfg.activation)
    return x, new_c


def zamba2_loss(cfg: ArchConfig, params: Params, batch: Dict,
                remat: str = "nothing_saveable", loss_chunks: int = 1,
                **_) -> jax.Array:
    mcfg = mamba_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = shard_act(x, ("batch", None, None))

    def super_body(x, sb):
        def mamba_body(x, lp):
            h, _ = mamba2_apply(lp["mamba"], mcfg, rms_norm(x, lp["ln"]))
            return x + h, None

        inner = mamba_body if remat == "none" else jax.checkpoint(
            mamba_body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(inner, x, sb)
        x, _ = _zamba_shared_apply(cfg, params["shared"], x)
        return shard_act(x, ("batch", None, None)), None

    body = super_body if remat == "none" else jax.checkpoint(
        super_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["supers"])
    hidden = rms_norm(x, params["final_ln"])
    return cross_entropy_from_hidden(hidden, params["lm_head"],
                                     batch["labels"], seq_chunks=loss_chunks)


def zamba2_init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype):
    per = cfg.mamba_per_attn
    n_super = cfg.n_layers // per
    mcfg = mamba_config(cfg)
    m1 = mamba2_init_cache(mcfg, batch, dtype)
    mcache = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super, per) + a.shape).copy(), m1)
    a1 = gqa_init_cache(attn_config(cfg), batch, max_seq, dtype)
    acache = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super,) + a.shape).copy(), a1)
    return {"mamba": mcache, "attn": acache}


def zamba2_decode(cfg: ArchConfig, params: Params, cache: Dict, batch: Dict
                  ) -> Tuple[jax.Array, Dict]:
    mcfg = mamba_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)

    def super_body(x, scanned):
        sb, mcache_s, acache_s = scanned

        def mamba_body(x, inner):
            lp, mc = inner
            h, nc = mamba2_apply(lp["mamba"], mcfg, rms_norm(x, lp["ln"]),
                                 cache=mc)
            return x + h, nc

        x, new_m = jax.lax.scan(mamba_body, x, (sb, mcache_s))
        x, new_kv = _zamba_shared_apply(cfg, params["shared"], x,
                                        cache=acache_s)
        return x, (new_m, new_kv)

    x, (new_m, new_kv) = jax.lax.scan(
        super_body, x, (params["supers"], cache["mamba"], cache["attn"]))
    # one in-place token-slot write for all shared-attn cache layers
    pos = cache["attn"]["pos"][0]
    ac = cache["attn"]
    new_attn = {
        "k": jax.lax.dynamic_update_slice(
            ac["k"], new_kv["k_new"], (0, 0, pos, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            ac["v"], new_kv["v_new"], (0, 0, pos, 0, 0)),
        "pos": ac["pos"] + 1,
    }
    hidden = rms_norm(x, params["final_ln"])
    logits = dense(hidden, params["lm_head"])
    return logits, {"mamba": new_m, "attn": new_attn}


def zamba2_prefill(cfg: ArchConfig, params: Params, batch: Dict,
                   max_seq: int, **_) -> Tuple[jax.Array, Dict]:
    """Prefill via repeated decode is O(S²) — instead run the parallel form
    while accumulating caches per layer (recompute-based, like transformer
    prefill)."""
    mcfg = mamba_config(cfg)
    acfg = attn_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    b, s, _ = x.shape
    pad = max_seq - s

    def super_body(x, sb):
        def mamba_body(x, lp):
            h = rms_norm(x, lp["ln"])
            out, _ = mamba2_apply(lp["mamba"], mcfg, h)
            # final SSM state: run the chunked form once more on the last
            # position only is wrong; instead recompute state via decode-free
            # closed form — here we take the cheap route: rerun decode update
            # over the final conv window for the conv state and accept
            # recomputation of h via a single masked pass.
            mc = _mamba_state_from_prefix(lp["mamba"], mcfg, h)
            return x + out, mc

        x, mcaches = jax.lax.scan(mamba_body, x, sb)
        h = rms_norm(x, params["shared"]["ln1"])
        from repro.models.common import apply_rope
        hk, hd = acfg.n_kv_heads, acfg.head_dim
        k = dense(h, params["shared"]["attn"]["wk"]).reshape(b, s, hk, hd)
        k = apply_rope(k, jnp.arange(s)[None, :], cfg.rope_theta)
        v = dense(h, params["shared"]["attn"]["wv"]).reshape(b, s, hk, hd)
        acache = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "pos": jnp.int32(s),
        }
        x, _ = _zamba_shared_apply(cfg, params["shared"], x)
        return x, (mcaches, acache)

    x, (mcache, acache) = jax.lax.scan(super_body, x, params["supers"])
    hidden = rms_norm(x[:, -1:], params["final_ln"])
    logits = dense(hidden, params["lm_head"])
    return logits, {"mamba": mcache, "attn": acache}


def _mamba_state_from_prefix(p: Params, mcfg: Mamba2Config, h: jax.Array):
    """Final (conv, ssm) state after consuming the whole prefix."""
    from repro.models.ssm import _causal_conv, _split_proj
    b, s, _ = h.shape
    di, n, hh, pd = (mcfg.d_inner, mcfg.d_state, mcfg.n_heads, mcfg.head_dim)
    z, xbc, dt = _split_proj(p, mcfg, h)
    conv_tail = xbc[:, s - (mcfg.conv_width - 1):, :]
    xbc_c = _causal_conv(p, mcfg, xbc)
    xs = xbc_c[..., :di].reshape(b, s, hh, pd)
    bm = xbc_c[..., di:di + n]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    ldec = dtv * a
    cum = jnp.cumsum(ldec, axis=1)
    w = jnp.exp(cum[:, -1:, :] - cum) * dtv
    hstate = jnp.einsum("bsh,bshp,bsn->bhpn", w, xs.astype(jnp.float32),
                        bm.astype(jnp.float32))
    return {"conv": conv_tail, "h": hstate, "pos": jnp.int32(s)}


# =============================================================================
# xLSTM LM
# =============================================================================
def xlstm_config(cfg: ArchConfig) -> XLSTMConfig:
    return XLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads)


def xlstm_defs(cfg: ArchConfig) -> Dict[str, Any]:
    assert cfg.n_layers % 2 == 0
    xcfg = xlstm_config(cfg)
    pair = {
        "ln_m": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "mlstm": mlstm_defs(xcfg),
        "ln_s": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "slstm": slstm_defs(xcfg),
    }
    v = cfg.padded_vocab
    return {
        "embed": ParamDef((v, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "pairs": stack_defs(pair, cfg.n_layers // 2),
        "final_ln": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "lm_head": ParamDef((cfg.d_model, v), ("embed", "vocab"), scale=0.02),
    }


def xlstm_loss(cfg: ArchConfig, params: Params, batch: Dict,
               remat: str = "nothing_saveable", loss_chunks: int = 1,
               **_) -> jax.Array:
    xcfg = xlstm_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = shard_act(x, ("batch", None, None))

    def body(x, lp):
        h, _ = mlstm_apply(lp["mlstm"], xcfg, rms_norm(x, lp["ln_m"]))
        x = x + h
        h, _ = slstm_apply(lp["slstm"], xcfg, rms_norm(x, lp["ln_s"]))
        return shard_act(x + h, ("batch", None, None)), None

    body_fn = body if remat == "none" else jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body_fn, x, params["pairs"])
    hidden = rms_norm(x, params["final_ln"])
    return cross_entropy_from_hidden(hidden, params["lm_head"],
                                     batch["labels"], seq_chunks=loss_chunks)


def xlstm_init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype):
    xcfg = xlstm_config(cfg)
    n_pairs = cfg.n_layers // 2
    mc = mlstm_init_cache(xcfg, batch)
    sc = slstm_init_cache(xcfg, batch)
    stack = lambda tree: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_pairs,) + a.shape).copy(), tree)
    return {"mlstm": stack(mc), "slstm": stack(sc)}


def xlstm_decode(cfg: ArchConfig, params: Params, cache: Dict, batch: Dict
                 ) -> Tuple[jax.Array, Dict]:
    xcfg = xlstm_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)

    def body(x, scanned):
        lp, mc, sc = scanned
        h, new_mc = mlstm_apply(lp["mlstm"], xcfg, rms_norm(x, lp["ln_m"]),
                                cache=mc)
        x = x + h
        h, new_sc = slstm_apply(lp["slstm"], xcfg, rms_norm(x, lp["ln_s"]),
                                cache=sc)
        return x + h, (new_mc, new_sc)

    x, (new_mc, new_sc) = jax.lax.scan(
        body, x, (params["pairs"], cache["mlstm"], cache["slstm"]))
    hidden = rms_norm(x, params["final_ln"])
    logits = dense(hidden, params["lm_head"])
    return logits, {"mlstm": new_mc, "slstm": new_sc}


def xlstm_prefill(cfg: ArchConfig, params: Params, batch: Dict,
                  max_seq: int, **_) -> Tuple[jax.Array, Dict]:
    """Run the parallel forms once per layer while extracting final states."""
    xcfg = xlstm_config(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)

    def body(x, lp):
        h_in = rms_norm(x, lp["ln_m"])
        h, _ = mlstm_apply(lp["mlstm"], xcfg, h_in)
        mc = _mlstm_state_from_prefix(lp["mlstm"], xcfg, h_in)
        x = x + h
        h_in = rms_norm(x, lp["ln_s"])
        h, sc = _slstm_full_with_state(lp["slstm"], xcfg, h_in)
        return x + h, (mc, sc)

    x, (mcache, scache) = jax.lax.scan(body, x, params["pairs"])
    hidden = rms_norm(x[:, -1:], params["final_ln"])
    logits = dense(hidden, params["lm_head"])
    return logits, {"mlstm": mcache, "slstm": scache}


def _mlstm_state_from_prefix(p: Params, xcfg: XLSTMConfig, x: jax.Array):
    from repro.models.xlstm import _CLIP
    b, s, _ = x.shape
    h, du = xcfg.n_heads, xcfg.d_up
    hd = du // h
    up = dense(x, p["w_up"])
    xb = up[..., :du]
    k = dense(xb, p["wk"]).reshape(b, s, h, hd).astype(jnp.float32) / (hd ** 0.5)
    v = dense(xb, p["wv"]).reshape(b, s, h, hd).astype(jnp.float32)
    gates = dense(xb, p["w_if"]) + p["b_if"]
    logi = jnp.clip(gates[..., :h].astype(jnp.float32), -_CLIP, _CLIP)
    logf = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32))
    cum = jnp.cumsum(logf, axis=1)
    w = jnp.exp(jnp.clip(cum[:, -1:, :] - cum + logi, -_CLIP, _CLIP))
    c = jnp.einsum("bsh,bshd,bshe->bhde", w, v, k)
    n = jnp.einsum("bsh,bshd->bhd", w, k)
    return {"c": c, "n": n, "pos": jnp.int32(s)}


def _slstm_full_with_state(p: Params, xcfg: XLSTMConfig, x: jax.Array):
    from repro.models.xlstm import _CLIP, _slstm_step
    b, s, d = x.shape
    xp = dense(x, p["w_x"])
    zero = jnp.zeros((b, d), jnp.float32)
    carry0 = (zero, zero, zero, zero - _CLIP)

    def step(cr, xt):
        return _slstm_step(p, xcfg, cr, xt)

    carry, hs = jax.lax.scan(step, carry0, xp.swapaxes(0, 1))
    y = rms_norm(hs.swapaxes(0, 1).astype(x.dtype), p["norm_g"])
    sc = {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3],
          "pos": jnp.int32(s)}
    return y, sc
