"""Attention variants: GQA/MQA (with optional QKV bias) and DeepSeek-style
MLA (multi-head latent attention with compressed KV cache).

Prefill/training uses memory-safe chunked ("flash-style") attention in pure
JAX — the Pallas kernel in repro/kernels/attention is the TPU hot-path
drop-in, validated against the same oracle.  Decode uses a dense matvec over
the KV cache.

Cache layout (GQA):   {"k": (B, S_max, Hkv, D), "v": ..., "pos": int32}
Cache layout (MLA):   {"c_kv": (B, S_max, R), "k_rope": (B, S_max, Dr)}
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, Params, apply_rope, dense

# §Perf switch: causal upper-triangle block skipping in train/prefill
# attention (see _causal_block_attention).  Module-level so experiments can
# A/B the paper-faithful baseline (False) against the optimized path.
CAUSAL_BLOCK_SKIP = True


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    # MLA (deepseek) extras
    kv_lora_rank: int = 0          # 0 => plain GQA
    qk_rope_dim: int = 64
    v_head_dim: int = 0            # defaults to head_dim


# =============================================================================
# GQA
# =============================================================================
def gqa_defs(cfg: AttnConfig) -> Dict[str, ParamDef]:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h * hd), ("embed", "heads")),
        "wk": ParamDef((d, hk * hd), ("embed", "kv")),
        "wv": ParamDef((d, hk * hd), ("embed", "kv")),
        "wo": ParamDef((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h * hd,), ("heads",), init="zeros")
        defs["bk"] = ParamDef((hk * hd,), ("kv",), init="zeros")
        defs["bv"] = ParamDef((hk * hd,), ("kv",), init="zeros")
    return defs


def _causal_block_attention(
    q: jax.Array,   # (B, S, H, D)
    k: jax.Array,   # (B, S, Hkv, D)
    v: jax.Array,   # (B, S, Hkv, Dv)
    chunk: int,
) -> jax.Array:
    """Causal attention with upper-triangle block SKIPPING.

    The kv-chunked form computes every (q, kv) block and masks half of them
    — 2x wasted MXU flops and score traffic at long S.  Here q is ALSO
    chunked (python loop, static shapes) and q-chunk i only touches
    kv[: (i+1)*chunk], so skipped blocks are never materialized: flops and
    bytes become triangular (sum i*c^2 ~ S^2/2).  §Perf iteration for the
    attention-dominated cells; the Pallas flash kernel does the same
    skipping on-chip (kernels/attention).
    """
    b, s, h, d = q.shape
    if s % chunk != 0 or s // chunk <= 1:
        return _chunked_attention(q, k, v, True, chunk=chunk)
    nq = s // chunk
    outs = []
    for i in range(nq):
        qi = q[:, i * chunk:(i + 1) * chunk]
        kv_len = (i + 1) * chunk
        outs.append(_chunked_attention(
            qi, k[:, :kv_len], v[:, :kv_len], True,
            q_offset=i * chunk, chunk=chunk))
    return jnp.concatenate(outs, axis=1)


def _chunked_attention(
    q: jax.Array,   # (B, S, H, D)
    k: jax.Array,   # (B, T, Hkv, D)
    v: jax.Array,   # (B, T, Hkv, Dv)
    causal: bool,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style online-softmax attention chunked over the KV axis."""
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = h // hkv
    scale = 1.0 / math.sqrt(d)
    # operands stay in the model dtype; MXU accumulates f32 via
    # preferred_element_type — no f32 copy of K/V ever hits HBM
    qf = (q * scale).reshape(b, s, hkv, rep, d)

    n_chunks = -(-t // chunk)
    pad_t = n_chunks * chunk
    if pad_t != t:
        k = jnp.pad(k, ((0, 0), (0, pad_t - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t - t), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, hkv, dv), 1, 0)

    q_pos = q_offset + jnp.arange(s)

    def body(carry, ckv):
        m, l, acc, c_idx = carry
        kb, vb = ckv
        sij = jnp.einsum("bshrd,bthd->bhrst", qf, kb,
                         preferred_element_type=jnp.float32)
        kv_pos = c_idx * chunk + jnp.arange(chunk)
        mask = kv_pos[None, :] < t
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        sij = jnp.where(mask[None, None, None], sij, -1e30)
        m_new = jnp.maximum(m, jnp.max(sij, axis=-1))
        p = jnp.exp(sij - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhrst,bthv->bhrsv", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc, c_idx + 1), None

    m0 = jnp.full((b, hkv, rep, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, s, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, 0), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, dv)
    return out


def gqa_apply(
    p: Params,
    cfg: AttnConfig,
    x: jax.Array,                       # (B, S, D)
    positions: Optional[jax.Array] = None,
    cache: Optional[Dict] = None,       # decode: append + attend over cache
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s, _ = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, h, hd)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, s, hk, hd)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, s, hk, hd)

    if cache is None:
        pos = positions if positions is not None else jnp.arange(s)[None, :]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        if cfg.causal and CAUSAL_BLOCK_SKIP:
            out = _causal_block_attention(q, k, v, chunk=kv_chunk)
        else:
            out = _chunked_attention(q, k, v, cfg.causal, chunk=kv_chunk)
        new_cache = None
    else:
        # single-token decode: attend over the stored prefix plus the current
        # token WITHOUT rewriting the cache — the caller batches all layers'
        # new K/V into one stacked cache update (in-place, outside the layer
        # scan), so per-step cache traffic is read + one token-slot write.
        pos = cache["pos"]                          # scalar int32
        q = apply_rope(q, pos[None, None], cfg.rope_theta)
        k = apply_rope(k, pos[None, None], cfg.rope_theta)
        kc, vc = cache["k"], cache["v"]
        t = kc.shape[1]
        rep = h // hk
        scale = 1.0 / math.sqrt(hd)
        qf = (q * scale).reshape(b, 1, hk, rep, hd)
        sij = jnp.einsum("bshrd,bthd->bhrst", qf, kc,
                         preferred_element_type=jnp.float32)
        valid = jnp.arange(t)[None, :] < pos
        sij = jnp.where(valid[None, None, None], sij, -1e30)
        s_self = jnp.einsum("bshrd,bshd->bhrs", qf, k,
                            preferred_element_type=jnp.float32)
        sij = jnp.concatenate([sij, s_self[..., None]], axis=-1)
        pr = jax.nn.softmax(sij, axis=-1)
        out = jnp.einsum("bhrst,bthv->bhrsv",
                         pr[..., :t].astype(vc.dtype), vc,
                         preferred_element_type=jnp.float32)
        # current token's value contribution: (B,Hk,rep,1,1)·(B,Hk,1,1,Dv)
        v_self = v[:, 0][:, :, None, None, :]
        out = out + pr[..., -1][..., None] * v_self.astype(jnp.float32)
        out = jnp.moveaxis(out, 3, 1).reshape(b, 1, h, hd)
        new_cache = {"k_new": k, "v_new": v}

    y = dense(out.reshape(b, s, h * hd).astype(x.dtype), p["wo"])
    return y, new_cache


def gqa_init_cache(cfg: AttnConfig, batch: int, max_seq: int, dtype):
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_seq, hk, hd), dtype),
        "v": jnp.zeros((batch, max_seq, hk, hd), dtype),
        "pos": jnp.int32(0),
    }


# =============================================================================
# MLA (DeepSeek-V2): compressed KV latent + decoupled RoPE key
# =============================================================================
def mla_defs(cfg: AttnConfig) -> Dict[str, ParamDef]:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    dv = cfg.v_head_dim or hd
    return {
        # queries: nope part + rope part per head
        "wq": ParamDef((d, h * (hd + dr)), ("embed", "heads")),
        # KV joint compression to rank r; decompression to K_nope and V
        "w_dkv": ParamDef((d, r), ("embed", None)),
        "w_uk": ParamDef((r, h * hd), (None, "heads")),
        "w_uv": ParamDef((r, h * dv), (None, "heads")),
        # shared (per-token, head-agnostic) rotary key
        "w_kr": ParamDef((d, dr), ("embed", None)),
        "wo": ParamDef((h * dv, d), ("heads", "embed")),
    }


def mla_apply(
    p: Params,
    cfg: AttnConfig,
    x: jax.Array,
    positions: Optional[jax.Array] = None,
    cache: Optional[Dict] = None,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    dv = cfg.v_head_dim or hd

    q = dense(x, p["wq"]).reshape(b, s, h, hd + dr)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    c_kv = dense(x, p["w_dkv"])                     # (B, S, R) — the cache
    k_rope = dense(x, p["w_kr"])                    # (B, S, Dr) shared

    if cache is None:
        pos = positions if positions is not None else jnp.arange(s)[None, :]
        q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
        k_rope_r = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)
        k_nope = dense(c_kv, p["w_uk"]).reshape(b, s, h, hd)
        v = dense(c_kv, p["w_uv"]).reshape(b, s, h, dv)
        # concatenated effective head dims: [nope | rope]
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_r, (b, s, h, dr))], axis=-1
        )
        if cfg.causal and CAUSAL_BLOCK_SKIP:
            out = _causal_block_attention(q_full, k_full, v, chunk=kv_chunk)
        else:
            out = _chunked_attention(q_full, k_full, v, cfg.causal,
                                     chunk=kv_chunk)
        new_cache = None
    else:
        pos = cache["pos"]
        q_rope = apply_rope(q_rope, pos[None, None], cfg.rope_theta)
        k_rope_r = apply_rope(k_rope[:, :, None, :], pos[None, None],
                              cfg.rope_theta)[:, :, 0, :]
        ckv_c, kr_c = cache["c_kv"], cache["k_rope"]
        t = ckv_c.shape[1]
        # absorbed attention: score = q_nope^T W_uk c_kv + q_rope^T k_rope
        wk = p["w_uk"].reshape(r, h, hd)
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, wk,
                           preferred_element_type=jnp.float32)  # (B,1,H,R)
        s_nope = jnp.einsum("bshr,btr->bhst", q_abs.astype(ckv_c.dtype),
                            ckv_c, preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope, kr_c,
                            preferred_element_type=jnp.float32)
        sij = (s_nope + s_rope) / math.sqrt(hd + dr)
        valid = jnp.arange(t)[None, :] < pos
        sij = jnp.where(valid[None, None], sij, -1e30)
        # current token's own score (cache not yet updated)
        s_self = (jnp.einsum("bshr,bsr->bhs", q_abs.astype(c_kv.dtype),
                             c_kv, preferred_element_type=jnp.float32)
                  + jnp.einsum("bshd,bsd->bhs", q_rope, k_rope_r,
                               preferred_element_type=jnp.float32)
                  ) / math.sqrt(hd + dr)
        sij = jnp.concatenate([sij, s_self[..., None]], axis=-1)
        pr = jax.nn.softmax(sij, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr",
                         pr[..., :t].astype(ckv_c.dtype), ckv_c,
                         preferred_element_type=jnp.float32)
        ctx = ctx + jnp.einsum("bhs,bsr->bshr", pr[..., -1],
                               c_kv.astype(jnp.float32))[:, :, :, :]
        wv = p["w_uv"].reshape(r, h, dv)
        out = jnp.einsum("bshr,rhd->bshd", ctx.astype(wv.dtype), wv,
                         preferred_element_type=jnp.float32)
        new_cache = {"c_kv_new": c_kv, "k_rope_new": k_rope_r}

    y = dense(out.reshape(b, s, h * dv).astype(x.dtype), p["wo"])
    return y, new_cache


def mla_init_cache(cfg: AttnConfig, batch: int, max_seq: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype),
        "pos": jnp.int32(0),
    }
