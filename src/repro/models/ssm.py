"""Mamba2 (SSD) block: chunked parallel scan for train/prefill, O(1) decode.

State-space duality form: within a chunk the output is an attention-like
matmul with a decay-masked score matrix; across chunks a small recurrent
state (H, P, N) is carried.  This is the TPU-friendly formulation — both the
intra-chunk part and the state updates are MXU matmuls (DESIGN.md §2:
hardware adaptation of the paper's GPU-centric scan kernels).

Shapes: d_inner = expand * d_model, H = d_inner / head_dim heads, state N.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, Params, dense, rms_norm


class Mamba2Config(NamedTuple):
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        # convolved channels: x plus B and C projections
        return self.d_inner + 2 * self.d_state


def mamba2_defs(cfg: Mamba2Config) -> Dict[str, ParamDef]:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    return {
        # in_proj -> [z (di), xBC (conv_dim), dt (H)]
        "w_in": ParamDef((d, 2 * di + 2 * n + h), ("embed", "mlp")),
        "conv_w": ParamDef((cfg.conv_width, cfg.conv_dim), (None, "mlp"),
                           scale=0.5),
        "conv_b": ParamDef((cfg.conv_dim,), ("mlp",), init="zeros"),
        "a_log": ParamDef((h,), (None,), init="zeros"),
        "dt_bias": ParamDef((h,), (None,), init="zeros"),
        "d_skip": ParamDef((h,), (None,), init="ones"),
        "norm_g": ParamDef((di,), ("mlp",), init="ones"),
        "w_out": ParamDef((di, d), ("mlp", "embed")),
    }


def _split_proj(p: Params, cfg: Mamba2Config, x: jax.Array):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    proj = dense(x, p["w_in"])
    z = proj[..., :di]
    xbc = proj[..., di:di + cfg.conv_dim]
    dt = proj[..., di + cfg.conv_dim:]
    return z, xbc, dt


def _causal_conv(p: Params, cfg: Mamba2Config, xbc: jax.Array) -> jax.Array:
    """Depthwise causal conv over sequence: (B, S, conv_dim)."""
    w = p["conv_w"]                      # (W, conv_dim)
    pad = cfg.conv_width - 1
    xp = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    out = sum(
        xp[:, i:i + xbc.shape[1], :] * w[i]
        for i in range(cfg.conv_width)
    )
    return jax.nn.silu(out + p["conv_b"])


def mamba2_apply(
    p: Params,
    cfg: Mamba2Config,
    x: jax.Array,                       # (B, S, D)
    cache: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    if cache is not None:
        return _mamba2_decode(p, cfg, x, cache)

    b, s, _ = x.shape
    di, n, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    L = min(cfg.chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L

    z, xbc, dt = _split_proj(p, cfg, x)
    xbc = _causal_conv(p, cfg, xbc)
    xs = xbc[..., :di].reshape(b, nc, L, h, pd)
    bm = xbc[..., di:di + n].reshape(b, nc, L, n)        # B_t (G=1)
    cm = xbc[..., di + n:].reshape(b, nc, L, n)          # C_t

    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = dt.reshape(b, nc, L, h)
    ldec = dt * a                                         # log decay ≤ 0
    cum = jnp.cumsum(ldec, axis=2)                        # (B, C#, L, H)

    # --- intra-chunk: decay-masked attention-like matmul --------------------
    # scores[b,c,h,t,s] = exp(cum_t - cum_s) * (C_t · B_s) * dt_s,  s <= t
    cb = jnp.einsum("bctn,bcsn->bcts", cm.astype(jnp.float32),
                    bm.astype(jnp.float32))
    decay = jnp.exp(
        cum[:, :, :, None, :] - cum[:, :, None, :, :]
    )                                                     # (B,C#,t,s,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    scores = jnp.where(
        tri[None, None, :, :, None], cb[..., None] * decay, 0.0
    ) * dt[:, :, None, :, :]                              # weight dt_s
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", scores,
                         xs.astype(jnp.float32))

    # --- chunk boundary states ------------------------------------------------
    # h_end[b,c,h,p,n] = Σ_s exp(cum_L - cum_s) dt_s x_s ⊗ B_s
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dt         # (B,C#,L,H)
    h_end = jnp.einsum("bcsh,bcshp,bcsn->bchpn", w_end,
                       xs.astype(jnp.float32), bm.astype(jnp.float32))

    def carry_fn(hprev, inp):
        h_end_c, decay_end = inp
        hnew = hprev * decay_end[:, :, None, None] + h_end_c
        return hnew, hprev

    decay_end = jnp.exp(cum[:, :, -1, :])                 # (B, C#, H)
    h0 = jnp.zeros((b, h, pd, n), jnp.float32)
    _, h_in = jax.lax.scan(
        carry_fn,
        h0,
        (jnp.moveaxis(h_end, 1, 0), jnp.moveaxis(decay_end, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                       # (B, C#, H, P, N)

    # --- inter-chunk contribution ---------------------------------------------
    y_inter = jnp.einsum("bctn,bchpn->bcthp", cm.astype(jnp.float32),
                         h_in) * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(b, s, di)
    y = y + (xbc[..., :di].astype(jnp.float32)
             * jnp.repeat(p["d_skip"], pd)[None, None, :])
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm_g"])
    return dense(y, p["w_out"]), None


def _mamba2_decode(p: Params, cfg: Mamba2Config, x: jax.Array, cache: Dict):
    """Single-token recurrent step; cache: conv tail + SSM state."""
    b = x.shape[0]
    di, n, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    z, xbc, dt = _split_proj(p, cfg, x)                   # (B, 1, ·)
    conv_in = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, W, conv_dim)
    w = p["conv_w"]
    out = jnp.einsum("bwc,wc->bc", conv_in, w) + p["conv_b"]
    xbc1 = jax.nn.silu(out)                               # (B, conv_dim)
    xs = xbc1[:, :di].reshape(b, h, pd)
    bm = xbc1[:, di:di + n]
    cm = xbc1[:, di + n:]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    decay = jnp.exp(dt1 * a)                              # (B, H)
    hst = cache["h"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt1, xs.astype(jnp.float32),
        bm.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", cm.astype(jnp.float32), hst)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm_g"])
    new_cache = {"conv": conv_in[:, 1:], "h": hst, "pos": cache["pos"] + 1}
    return dense(y, p["w_out"]), new_cache


def mamba2_init_cache(cfg: Mamba2Config, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), dtype),
        "h": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                       jnp.float32),
        "pos": jnp.int32(0),
    }
