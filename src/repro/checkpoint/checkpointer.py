"""Mesh-agnostic checkpointing: atomic, async, keep-last-k, elastic restore.

Checkpoints are written as host numpy arrays keyed by pytree path — no mesh
or sharding information is baked in, so a checkpoint saved on a 16x16 mesh
restores onto 2x16x16, 4x4, or a single host (elastic up/down-scaling).
Writes go to a temp directory and are atomically renamed; a background
thread does the serialization so the train loop only blocks on device→host
transfer of the sharded leaves it owns.

This is the fault-tolerance unit: on failure the launcher re-execs and
``restore_latest`` resumes from the last complete step (see launch/train.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=()) -> List[Tuple[str, Any]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten(tree[k], prefix + (str(k),)))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.extend(_flatten(getattr(tree, k), prefix + (k,)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_flatten(v, prefix + (str(i),)))
    else:
        out.append(("/".join(prefix), tree))
    return out


def _unflatten_into(template, flat: Dict[str, np.ndarray], prefix=()):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, prefix + (str(k),))
                for k in template}
    if hasattr(template, "_fields"):
        return type(template)(*(
            _unflatten_into(getattr(template, k), flat, prefix + (k,))
            for k in template._fields))
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, prefix + (str(i),))
            for i, v in enumerate(template))
    key = "/".join(prefix)
    if key not in flat:
        raise KeyError(f"checkpoint missing leaf {key!r}")
    return flat[key]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -------------------------------------------------------------------
    def save(self, step: int, state) -> None:
        """Device→host transfer now; serialization possibly in background."""
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat}
        self.wait()  # one in-flight write at a time
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: Dict[str, np.ndarray]) -> None:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in host.items()})
        meta = {"step": step, "leaves": sorted(host),
                "format": 1}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None):
        """Restore onto any mesh: ``shardings`` (matching the template tree)
        places each leaf; None keeps host arrays / default placement."""
        path = os.path.join(self.directory, f"step_{step:010d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_latest(self, template, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, template, shardings)
