"""Online shape-bucketed autotuning for the serving engine.

The paper's motivation (ii): autotuning must be *repeated* whenever the
processed-data characteristics change, and a portable TP→PC_ops model makes
each repetition cheap.  A serving engine under load is exactly that scenario:
the live request mix (prompt length × generation length) shifts over time,
and the best (batch size, cache length) engine configuration shifts with it.

This module closes the loop:

* ``ShapeBucketer`` — maps requests into decile buckets of the serving range
  (prompt-length decile × max-new-tokens decile); a bucket is the "input" of
  the paper's ``g : TP × I → PC_ops``.
* ``serve_workload_fn`` — the portable workload model for one serving tick:
  hardware-independent operation counts (weight streaming, KV traffic, MXU
  work, working set) as a function of the engine configuration.
* ``OnlineAutotuner`` — watches the live mix through a sliding window,
  declares **drift** when the dominant bucket leaves the bucket the active
  configuration was tuned for, and then either *reuses* a configuration from
  the persistent ``ConfigStore`` (zero live trials) or *retunes* with a
  handful of live wave-latency trials, warm-started from the portable
  model's predicted-runtime ranking (``warm_start`` searcher +
  ``FunctionEvaluator`` over real wave latencies).  Freshly tuned configs
  and trained model artifacts are written back to the store.
* ``EngineBackend`` / ``SyntheticServeBackend`` — the live measurement
  substrate: a cache of warmed ``ServeEngine``s for real serving, and a
  deterministic cost-model-backed fake (virtual clock, seeded jitter) for
  benchmarks and golden tests.
"""
from __future__ import annotations

import dataclasses
import math
import re
import time
from collections import Counter, deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core import counters as C
from repro.core.evaluate import FunctionEvaluator
from repro.core.hwspec import PRODUCTION, HardwareSpec, hardware_key
from repro.core.searcher import WarmStartSearcher, run_search
from repro.core.tuner import predicted_runtimes
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.serve.engine import Request, ServeEngine
from repro.tuning.problem import TuningProblem
from repro.tuning.session import TuningSession
from repro.tuning.store import ConfigStore, StoreEntry

SPACE_NAME = "serve_online"
# latency charged to configurations that cannot hold the bucket's sequences
INFEASIBLE_S = 1e3


# =============================================================================
# Shape buckets
# =============================================================================
@dataclasses.dataclass(frozen=True)
class Bucket:
    """One input-shape class: (prompt-length decile, max-new decile)."""

    prompt_decile: int
    new_decile: int

    @property
    def key(self) -> str:
        return f"p{self.prompt_decile}n{self.new_decile}"


class ShapeBucketer:
    """Decile bucketing of the serving shape range.

    ``max_prompt`` / ``max_new`` define the range the deciles partition;
    requests beyond the range land in the top decile.  The *representative*
    shape of a bucket is its upper decile edge — the worst case a
    configuration tuned for the bucket must accommodate.
    """

    def __init__(self, max_prompt: int = 96, max_new: int = 32):
        if max_prompt <= 0 or max_new <= 0:
            raise ValueError("bucketer ranges must be positive")
        self.max_prompt = int(max_prompt)
        self.max_new = int(max_new)

    def bucket_of(self, prompt_len: int, max_new_tokens: int) -> Bucket:
        pd = min(9, (10 * max(0, int(prompt_len))) // self.max_prompt)
        nd = min(9, (10 * max(0, int(max_new_tokens))) // self.max_new)
        return Bucket(prompt_decile=pd, new_decile=nd)

    def request_bucket(self, r: Request) -> Bucket:
        return self.bucket_of(len(r.prompt), r.max_new_tokens)

    def rep_shape(self, b: Bucket) -> Tuple[int, int]:
        """(prompt_len, new_tokens) at the bucket's upper decile edge."""
        plen = max(1, math.ceil((b.prompt_decile + 1) * self.max_prompt / 10))
        new = max(1, math.ceil((b.new_decile + 1) * self.max_new / 10))
        return plen, new


# =============================================================================
# The tuning space and the portable workload model
# =============================================================================
def serve_space(batch_sizes: Sequence[int] = (1, 2, 4, 8, 16),
                max_seqs: Sequence[int] = (32, 64, 96, 128, 192),
                name: str = SPACE_NAME) -> TuningSpace:
    """Engine configurations the online tuner searches over."""
    return TuningSpace(
        [TuningParameter("BATCH", tuple(int(b) for b in batch_sizes)),
         TuningParameter("MAX_SEQ", tuple(int(s) for s in max_seqs))],
        name=name)


@dataclasses.dataclass(frozen=True)
class ServeWorkloadStats:
    """Model-architecture constants the serving workload model needs."""

    param_bytes: float = 2e9     # streamed weight bytes per decode step
    d_model: int = 4096
    n_layers: int = 24
    bytes_per_value: int = 2     # bf16

    @property
    def kv_bytes_per_pos(self) -> float:
        """K+V cache bytes per sequence position, all layers."""
        return 2.0 * self.n_layers * self.d_model * self.bytes_per_value


def stats_from_model(model, bytes_per_value: int = 2) -> ServeWorkloadStats:
    """Derive workload stats from a real model-zoo ``Model``."""
    cfg = model.cfg
    return ServeWorkloadStats(
        param_bytes=float(model.param_count()) * bytes_per_value,
        d_model=int(cfg.d_model),
        n_layers=int(cfg.n_layers),
        bytes_per_value=bytes_per_value)


def serve_workload_fn(n_requests: int, prompt_len: int, new_tokens: int,
                      stats: ServeWorkloadStats
                      ) -> Callable[[Config], Dict[str, float]]:
    """``g : TP × I → PC_ops`` for one serving tick (hardware-independent).

    The input ``I`` is the shape bucket (``prompt_len``/``new_tokens`` at the
    bucket's representative edge) plus the tick size.  The counters capture
    the first-order serving physics: every decode step streams the weights
    once per wave and touches the KV prefix (so fewer waves — bigger BATCH —
    amortize weight reads, while an oversized MAX_SEQ inflates cache
    traffic), and the per-program working set grows with BATCH × MAX_SEQ
    (so the cost model's spill/double-buffer logic penalizes configurations
    that oversubscribe this hardware's VMEM — the cache-capacity effect that
    makes the best config hardware-dependent).
    """
    n = max(1, int(n_requests))
    plen = max(1, int(prompt_len))
    steps = max(1, int(new_tokens))
    flops_per_tok = 2.0 * stats.param_bytes / stats.bytes_per_value
    kv_pos = stats.kv_bytes_per_pos

    def wl(cfg: Config) -> Dict[str, float]:
        b = int(cfg["BATCH"])
        ms = int(cfg["MAX_SEQ"])
        waves = math.ceil(n / b)
        tok_total = n * (plen + steps)
        hbm_rd = waves * steps * (stats.param_bytes + 0.5 * b * ms * kv_pos)
        hbm_wr = waves * (plen + steps) * b * kv_pos / max(1, stats.n_layers)
        mxu = tok_total * flops_per_tok
        vpu = tok_total * 24.0 * stats.d_model * stats.n_layers
        issue = mxu / 128.0 + vpu
        ws = (2.0 * stats.d_model * stats.d_model * stats.bytes_per_value
              + b * ms * kv_pos / stats.n_layers * 8.0)
        return {
            C.HBM_RD: float(hbm_rd),
            C.HBM_WR: float(hbm_wr),
            C.VMEM_RD: float(2.0 * hbm_rd),
            C.VMEM_WR: float(2.0 * hbm_wr),
            C.MXU_FLOPS: float(mxu),
            C.VPU_OPS: float(vpu),
            C.ISSUE_OPS: float(issue),
            C.GRID: float(b * stats.n_layers),
            C.VMEM_WS: float(ws),
        }

    return wl


# =============================================================================
# The serve problem (registry kind "serve")
# =============================================================================
class ServeProblem(TuningProblem):
    """Serving wave geometry (BATCH × MAX_SEQ) for one shape bucket.

    The problem name is the bucket key (``"p9n9"``): prompt-length decile
    × max-new decile of the serving range, resolved to its representative
    shape by ``ShapeBucketer``.  ``make_evaluator`` prices the portable
    serving workload through the cost model with configurations that
    cannot hold the bucket's sequences charged ``INFEASIBLE_S`` — the
    exact semantics the daemon's serve-kind special case hard-coded
    before this class replaced it.

    Workload-model constants come either from explicit ``stats`` (a
    ``ServeWorkloadStats`` or its dict form — the service wire format) or
    from a model-zoo entry via ``arch=`` (closed-form parameter count, no
    jax).
    """

    kind = "serve"

    def __init__(self, bucket: str, batch_sizes: Sequence[int] = None,
                 max_seqs: Sequence[int] = None, space_name: str = SPACE_NAME,
                 calib_n: int = 16, stats=None, arch: Optional[str] = None,
                 max_prompt: int = 96, max_new: int = 32,
                 shape: Optional[Tuple[int, int]] = None):
        b = _parse_bucket(bucket)
        if stats is not None and arch is not None:
            raise ValueError("pass stats= or arch=, not both")
        if isinstance(stats, dict):
            allowed = {f.name for f in dataclasses.fields(ServeWorkloadStats)}
            bad = set(stats) - allowed
            if bad:
                raise ValueError(f"unknown stats fields {sorted(bad)}")
            stats = ServeWorkloadStats(**stats)
        if arch is not None:
            stats = stats_from_arch(arch)
        self.stats = stats if stats is not None else ServeWorkloadStats()
        self.bucketer = ShapeBucketer(max_prompt=max_prompt, max_new=max_new)
        self._bucket = b
        self.bucket = b.key
        self.name = b.key
        self.calib_n = int(calib_n)
        # explicit (prompt_len, new_tokens) override: the service path
        # measures at the CLIENT's representative shape, whatever its
        # bucketer's deciles resolve to, not this problem's default
        self._shape = (int(shape[0]), int(shape[1])) \
            if shape is not None else None
        self._space = serve_space(
            batch_sizes if batch_sizes is not None else (1, 2, 4, 8, 16),
            max_seqs if max_seqs is not None else (32, 64, 96, 128, 192),
            name=space_name)

    @classmethod
    def from_name(cls, name: str, **params) -> "ServeProblem":
        return cls(name, **params)

    @property
    def rep_shape(self) -> Tuple[int, int]:
        """(prompt_len, new_tokens) at the bucket's upper decile edge
        (or the explicit ``shape=`` override)."""
        if self._shape is not None:
            return self._shape
        return self.bucketer.rep_shape(self._bucket)

    def space(self) -> TuningSpace:
        return self._space

    def workload_fn(self) -> Callable[[Config], Dict[str, float]]:
        plen, new = self.rep_shape
        return serve_workload_fn(self.calib_n, plen, new, self.stats)

    def make_evaluator(self, hw: HardwareSpec) -> Optional[Callable]:
        from repro.core.evaluate import (PROFILE_FIXED, PROFILE_SLOWDOWN,
                                         TEST_OVERHEAD)
        space, wl = self._space, self.workload_fn()
        plen, new = self.rep_shape
        need = plen + new

        def fn(index: int, profile: bool):
            cfg = space[int(index)]
            cs = costmodel.execute(wl(cfg), hw)
            rt = INFEASIBLE_S if int(cfg["MAX_SEQ"]) < need \
                else float(cs.runtime)
            if profile:
                return rt, cs, rt * PROFILE_SLOWDOWN + TEST_OVERHEAD \
                    + PROFILE_FIXED
            return rt, None, rt + TEST_OVERHEAD

        return fn


_BUCKET_RE = re.compile(r"^p(\d)n(\d)$")


def _parse_bucket(key: str) -> Bucket:
    m = _BUCKET_RE.match(str(key))
    if not m:
        raise ValueError(
            f"serve problem name must be a bucket key 'p<0-9>n<0-9>', "
            f"got {key!r}")
    return Bucket(prompt_decile=int(m.group(1)), new_decile=int(m.group(2)))


def stats_from_arch(arch: str, bytes_per_value: int = 2
                    ) -> ServeWorkloadStats:
    """Workload stats from a model-zoo entry WITHOUT building the model
    (closed-form parameter count — usable on jax-free paths)."""
    from repro.configs import ARCHS
    from repro.distributed.tuning import arch_param_count
    if arch not in ARCHS:
        raise KeyError(f"unknown model-zoo entry {arch!r}; available: "
                       f"{sorted(ARCHS)}")
    cfg = ARCHS[arch]
    return ServeWorkloadStats(
        param_bytes=float(arch_param_count(cfg)) * bytes_per_value,
        d_model=int(cfg.d_model), n_layers=int(cfg.n_layers),
        bytes_per_value=bytes_per_value)


# =============================================================================
# Live-measurement backends
# =============================================================================
def _tick_shape(requests: Sequence[Request]) -> Tuple[int, int, int]:
    """(n, max prompt len, max new tokens) of a request batch."""
    n = len(requests)
    plen = max((len(r.prompt) for r in requests), default=1)
    new = max((max(0, r.max_new_tokens) for r in requests), default=1)
    return n, max(1, plen), max(1, new)


class EngineBackend:
    """Real serving backend: warmed ``ServeEngine``s cached per (batch,
    max_seq), all sharing ONE parameter set (``model.init`` runs once, not
    per trial configuration).  Before a timed measurement the engine warms
    every wave size the request count implies (full batch + masked tail), so
    ``measure`` never times first-call JIT compilation; ``serve`` bumps the
    cache length when a request would not fit the tuned configuration."""

    def __init__(self, model, rng=None, warmup: bool = True,
                 seq_round: int = 32):
        import jax

        self.model = model
        self.params = model.init(rng if rng is not None
                                 else jax.random.PRNGKey(0))
        self.do_warmup = warmup
        self.seq_round = int(seq_round)
        self.engines: Dict[Tuple[int, int], ServeEngine] = {}
        self._warmed: Dict[Tuple[int, int], set] = {}
        self.measure_calls = 0

    def _engine(self, batch: int, max_seq: int,
                n_requests: Optional[int] = None) -> ServeEngine:
        key = (int(batch), int(max_seq))
        if key not in self.engines:
            self.engines[key] = ServeEngine(
                self.model, batch_size=key[0], max_seq=key[1],
                params=self.params)
            self._warmed[key] = set()
        eng = self.engines[key]
        if self.do_warmup and n_requests is not None:
            n = max(1, int(n_requests))
            sizes = {min(key[0], n)}
            if n % key[0]:
                sizes.add(n % key[0])
            for size in sorted(sizes - self._warmed[key]):
                eng.warmup(wave_size=size)
                self._warmed[key].add(size)
        return eng

    def _fit_seq(self, cfg: Config, requests: Sequence[Request]) -> int:
        _, plen, new = _tick_shape(requests)
        need = plen + new
        ms = int(cfg["MAX_SEQ"])
        if need > ms:  # oversize stragglers: round up, keep the cache small
            ms = math.ceil(need / self.seq_round) * self.seq_round
        return ms

    def measure(self, cfg: Config, requests: Sequence[Request]) -> float:
        """Timed wave latency of ``requests`` under ``cfg`` (one live
        empirical test, warmed engine, seconds)."""
        _, plen, new = _tick_shape(requests)
        if plen + new > int(cfg["MAX_SEQ"]):
            return INFEASIBLE_S
        self.measure_calls += 1
        engine = self._engine(int(cfg["BATCH"]), int(cfg["MAX_SEQ"]),
                              n_requests=len(requests))
        reqs = [dataclasses.replace(r, generated=None) for r in requests]
        t0 = time.perf_counter()
        engine.generate(reqs)
        return time.perf_counter() - t0

    def serve(self, cfg: Config, requests: Sequence[Request]
              ) -> Dict[int, List[int]]:
        engine = self._engine(int(cfg["BATCH"]), self._fit_seq(cfg, requests))
        return engine.generate(list(requests))


class SyntheticServeBackend:
    """Deterministic fake serving backend (virtual clock, no JAX).

    Wave latency = the analytic cost model on the *true* hardware spec, over
    a skewed copy of the portable workload's counters (the model never sees
    the skew), plus per-wave host overhead and a seeded shape/config-keyed
    jitter — so warm-start rankings are good-but-imperfect, exactly the
    regime the ≤K-live-trials design targets.  Used by the shifting-workload
    benchmark and the golden ask-tell trace tests.
    """

    def __init__(self, hw: HardwareSpec, stats: ServeWorkloadStats,
                 noise: float = 0.05, host_overhead_s: float = 1.5e-3,
                 hbm_skew: float = 1.12, seed: int = 0,
                 seq_round: int = 32):
        self.hw = hw
        self.stats = stats
        self.noise = float(noise)
        self.host_overhead_s = float(host_overhead_s)
        self.hbm_skew = float(hbm_skew)
        self.seed = int(seed)
        self.seq_round = int(seq_round)
        self.measure_calls = 0
        self.serve_calls = 0
        self.virtual_time = 0.0

    def latency(self, cfg: Config, n: int, plen: int, new: int) -> float:
        """Pure deterministic latency — also the oracle's measurement."""
        b, ms = int(cfg["BATCH"]), int(cfg["MAX_SEQ"])
        if plen + new > ms:
            return INFEASIBLE_S
        ops = serve_workload_fn(n, plen, new, self.stats)(cfg)
        ops[C.HBM_RD] = ops[C.HBM_RD] * self.hbm_skew
        base = costmodel.execute(ops, self.hw).runtime
        waves = math.ceil(max(1, n) / b)
        rng = np.random.default_rng([self.seed, b, ms, n, plen, new])
        jitter = (2.0 * rng.random() - 1.0) * self.noise
        return base * (1.0 + jitter) + waves * self.host_overhead_s

    def measure(self, cfg: Config, requests: Sequence[Request]) -> float:
        self.measure_calls += 1
        return self.latency(cfg, *_tick_shape(requests))

    def serve(self, cfg: Config, requests: Sequence[Request]
              ) -> Dict[int, List[int]]:
        self.serve_calls += 1
        n, plen, new = _tick_shape(requests)
        ms = int(cfg["MAX_SEQ"])
        if plen + new > ms:  # mirror EngineBackend._fit_seq: bump, don't fail
            ms = math.ceil((plen + new) / self.seq_round) * self.seq_round
        self.virtual_time += self.latency({**cfg, "MAX_SEQ": ms}, n, plen,
                                          new)
        return {r.uid: [0] * max(0, r.max_new_tokens) for r in requests}


# =============================================================================
# The online tuner
# =============================================================================
@dataclasses.dataclass
class TickReport:
    """What one ``serve`` call did: which bucket dominated, whether the mix
    drifted, and how the active configuration was (re)established."""

    bucket: str
    drift: bool
    reused: bool                 # config came from the store, 0 live trials
    live_trials: int
    config: Config
    history: List[Tuple[int, float]] = dataclasses.field(default_factory=list)
    via_service: bool = False    # tuned remotely by the tuning daemon


class OnlineAutotuner:
    """Drift-triggered, store-backed autotuning around a serving backend.

    Flow per ``serve(requests)`` tick:

    1. bucket every request; extend the sliding shape window; the window's
       dominant bucket is the current mix;
    2. **drift** when the dominant bucket differs from the bucket the active
       configuration was tuned for (or nothing is active yet);
    3. on drift, consult the ``ConfigStore`` under ``(space name, bucket,
       hardware)`` — a hit reuses the stored config with zero live trials; a
       miss runs at most ``max_live_trials`` live wave-latency measurements
       through the ask-tell API (``warm_start`` searcher ordered by the
       portable model's predicted runtimes on the target hardware +
       ``FunctionEvaluator``), then persists the winner and the model
       artifact;
    4. serve the tick through the backend with the active configuration.

    ``hw`` is the (virtual) hardware of interest: it prices the model's
    PC_ops predictions into the warm-start ranking.  ``train_hw`` makes the
    cross-hardware training scenario explicit (default: train on ``hw``).
    """

    def __init__(
        self,
        backend,
        store: Optional[ConfigStore] = None,
        bucketer: Optional[ShapeBucketer] = None,
        space: Optional[TuningSpace] = None,
        hw: HardwareSpec = PRODUCTION,
        train_hw: Optional[HardwareSpec] = None,
        stats: Optional[ServeWorkloadStats] = None,
        hardware_name: Optional[str] = None,
        max_live_trials: int = 8,
        window: int = 32,
        calib_n: int = 16,
        model_kind: str = "tree",
        in_flight: int = 1,
        seed: int = 0,
        service: Optional[Any] = None,
        service_tenant: str = "serve",
        service_timeout: float = 120.0,
    ):
        self.backend = backend
        self.store = store if store is not None else ConfigStore()
        self.bucketer = bucketer if bucketer is not None else ShapeBucketer()
        self.space = space if space is not None else serve_space()
        self.hw = hw
        self.train_hw = train_hw if train_hw is not None else hw
        self.stats = stats if stats is not None else ServeWorkloadStats()
        # normalized so store hits survive naming drift ("TPUv4" == "tpu_v4")
        self.hardware_name = hardware_key(
            hardware_name if hardware_name is not None else hw)
        self.max_live_trials = int(max_live_trials)
        # outstanding live trials kept in flight by the async search driver
        # (1 = sequential; >1 pays off once the backend measures async)
        self.in_flight = int(in_flight)
        self.calib_n = int(calib_n)
        self.model_kind = model_kind
        self.seed = int(seed)
        self._window: deque = deque(maxlen=int(window))
        self._seen: Dict[str, Bucket] = {}
        self._models: Dict[str, Any] = {}
        self._active: Optional[StoreEntry] = None
        self.reports: List[TickReport] = []
        # optional tuning-as-a-service routing: a daemon address
        # ("host:port" / (host, port)) or a ready ServiceClient.  Drift
        # retunes are tried through the daemon first (sharing its fleet,
        # corpus and budgets) and fall back to in-process live trials
        # whenever it is unreachable or refuses the request.
        self.service = service
        self.service_tenant = service_tenant
        self.service_timeout = float(service_timeout)
        self._service_client: Optional[Any] = None
        self._via_service = False

    # -- portable model / ranking ---------------------------------------------
    def _session_for(self, bucket: Bucket) -> TuningSession:
        plen, new = self.bucketer.rep_shape(bucket)
        wl = serve_workload_fn(self.calib_n, plen, new, self.stats)
        return TuningSession(self.space, wl, hw=self.hw, seed=self.seed)

    def _model_for(self, bucket: Bucket):
        model = self._models.get(bucket.key)
        if model is not None:
            return model
        session = self._session_for(bucket)
        model = session.load_model_from_store(self.store, bucket.key,
                                              self.hardware_name,
                                              kind="serve")
        if model is None:
            # train the portable TP→PC_ops model (on train_hw — possibly a
            # different machine than the one being tuned) and persist it
            session.train(train_hw=self.train_hw, kind=self.model_kind,
                          sample="full")
            session.save_model_to_store(self.store, bucket.key,
                                        self.hardware_name, kind="serve")
            model = session.model
        self._models[bucket.key] = model
        return model

    def ranking(self, bucket: Bucket, min_seq: Optional[int] = None
                ) -> List[int]:
        """Feasible config indices, best-predicted first: the model's PC_ops
        predictions priced through the cost model on the target hardware.

        ``min_seq`` raises the feasibility bar beyond the bucket's
        representative edge — requests clamped into the top decile can be
        longer than the edge, and tuning must only consider configurations
        the live calibration wave actually fits in.
        """
        model = self._model_for(bucket)
        pred_rt = predicted_runtimes(model, self.space, self.hw)
        plen, new = self.bucketer.rep_shape(bucket)
        need = max(plen + new, min_seq if min_seq is not None else 0)
        order = [int(i) for i in np.argsort(pred_rt, kind="stable")
                 if int(self.space[int(i)]["MAX_SEQ"]) >= need]
        if not order:
            raise ValueError(
                f"no feasible config in {self.space.name!r} for bucket "
                f"{bucket.key} (needs MAX_SEQ >= {need})")
        return order

    # -- tuning ----------------------------------------------------------------
    def _tune_via_service(self, bucket: Bucket) -> Optional[StoreEntry]:
        """Ask the tuning daemon to tune this bucket; ``None`` = fall back.

        The submit describes the client's exact tuning problem (same
        space name and parameter grid, the bucket's representative
        shape, the workload-model constants), so the daemon's fleet
        answers with a config valid here and publishes artifacts future
        clients warm-start from.  Any transport or service refusal —
        daemon down, admission denied, tenant budget exhausted, request
        cancelled by a daemon drain — degrades to in-process tuning.
        """
        if self.service is None:
            return None
        from repro.service.client import ServiceClient, ServiceError
        try:
            if self._service_client is None:
                self._service_client = self.service \
                    if hasattr(self.service, "submit_serve") \
                    else ServiceClient(self.service,
                                       timeout=self.service_timeout)
            client = self._service_client
            plen, new = self.bucketer.rep_shape(bucket)
            by_name = {p.name: list(p.values) for p in self.space.parameters}
            # a hardware label outside the spec registry (e.g. a replica
            # running on "cpu") ships its pricing spec's numbers so the
            # daemon can still cost the space — the fleet's lane idiom
            from repro.core import hwspec
            try:
                hwspec.get(self.hardware_name)
                spec_payload = None
            except KeyError:
                spec_payload = dataclasses.asdict(self.hw)
            resp = client.submit_serve(
                tenant=self.service_tenant,
                hardware=self.hardware_name,
                bucket=bucket.key, bucket_shape=[plen, new],
                batch_sizes=by_name["BATCH"], max_seqs=by_name["MAX_SEQ"],
                space=self.space.name, calib_n=self.calib_n,
                stats=dataclasses.asdict(self.stats),
                budget=self.max_live_trials, seed=self.seed,
                hardware_spec=spec_payload)
            if resp["state"] == "done":     # store hit on the daemon side
                res = resp
            else:
                res = client.result(resp["request_id"],
                                    timeout=self.service_timeout)
        except (ServiceError, TimeoutError, OSError):
            self._service_client = None     # reconnect lazily next drift
            return None
        # adopt locally so subsequent drifts back to this bucket are pure
        # local store hits (and survive daemon restarts)
        return self.store.put(
            self.space.name, bucket.key, self.hardware_name,
            config=dict(res["config"]), runtime=float(res["runtime"]),
            trials=int(res.get("trials", 0)),
            meta={"source": res.get("source", "service"),
                  "service": True, "bucket_shape": list(
                      self.bucketer.rep_shape(bucket))},
            kind="serve")

    def ensure(self, bucket: Bucket, calib: Sequence[Request]
               ) -> Tuple[StoreEntry, int, bool]:
        """Return (entry, live_trials, reused) for ``bucket`` — store hit is
        pure reuse (0 live trials); a miss asks the tuning service (when
        configured), and failing that tunes live and persists."""
        self._via_service = False
        entry = self.store.get(self.space.name, bucket.key,
                               self.hardware_name, kind="serve")
        if entry is not None:
            return entry, 0, True
        entry = self._tune_via_service(bucket)
        if entry is not None:
            self._via_service = True
            return entry, 0, False
        _, calib_plen, calib_new = _tick_shape(calib)
        order = self.ranking(bucket, min_seq=calib_plen + calib_new)
        ev = FunctionEvaluator(
            self.space, lambda cfg: self.backend.measure(cfg, calib))
        searcher = WarmStartSearcher(self.space, order=order, seed=self.seed)
        run_search(searcher, ev, min(self.max_live_trials, len(order)),
                   in_flight=self.in_flight)
        plen, new = self.bucketer.rep_shape(bucket)
        entry = self.store.put(
            self.space.name, bucket.key, self.hardware_name,
            config=self.space[ev.best_index],
            runtime=ev.best_runtime, trials=ev.steps,
            meta={"history": [[int(i), float(rt)] for i, rt in ev.history()],
                  "bucket_shape": [plen, new]},
            kind="serve")
        return entry, ev.steps, False

    # -- the serving loop ------------------------------------------------------
    def serve(self, requests: Sequence[Request]
              ) -> Tuple[Dict[int, List[int]], Optional[TickReport]]:
        """Serve one tick: detect drift, (re)tune or reuse, then generate."""
        if not requests:
            return {}, None
        buckets = [self.bucketer.request_bucket(r) for r in requests]
        self._seen.update({b.key: b for b in buckets})
        self._window.extend(b.key for b in buckets)
        counts = Counter(self._window)
        dom_key = max(sorted(counts), key=lambda k: counts[k])
        dom = self._seen[dom_key]
        drift = self._active is None or self._active.bucket != dom_key
        live, reused, history = 0, False, []
        if drift:
            calib = [r for r, b in zip(requests, buckets)
                     if b.key == dom_key][: self.calib_n]
            if not calib:
                calib = list(requests)[: self.calib_n]
            entry, live, reused = self.ensure(dom, calib)
            history = [tuple(h) for h in entry.meta.get("history", [])] \
                if not reused else []
            self._active = entry
        outputs = self.backend.serve(self._active.config, requests)
        report = TickReport(bucket=dom_key, drift=drift, reused=reused,
                            live_trials=live, config=dict(self._active.config),
                            history=history,
                            via_service=drift and self._via_service)
        self.reports.append(report)
        return outputs, report

    @property
    def drift_events(self) -> List[TickReport]:
        return [r for r in self.reports if r.drift]
