"""Batched serving engine: prefill + decode with continuous slot reuse.

The engine owns a fixed-size batch of decode slots.  Requests are admitted
into free slots (their prompt prefilled into the slot's cache region),
decoded greedily until EOS/max-len, then the slot is recycled — a
continuous-batching loop in the vLLM style, expressed over the functional
prefill/decode of the model zoo.

For simplicity slots share one right-aligned cache (prefill fills positions
[0, prompt_len); decode appends) and admission happens between decode
steps.  This is the serving analog of the train driver and the substrate
for the decode dry-run cells.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (L,) int32
    max_new_tokens: int = 16
    eos_id: int = -1            # -1: never stops early
    generated: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, model: Model, batch_size: int, max_seq: int,
                 params=None, rng=None):
        self.model = model
        self.batch = batch_size
        self.max_seq = max_seq
        self.params = params if params is not None else model.init(
            rng if rng is not None else jax.random.PRNGKey(0))
        self._decode = jax.jit(model.decode, donate_argnums=(1,))

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion, batch_size at a time."""
        out: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave = queue[:self.batch]
            queue = queue[self.batch:]
            out.update(self._run_wave(wave))
        return out

    def _run_wave(self, wave: List[Request]) -> Dict[int, List[int]]:
        b = self.batch
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self.model.prefill(self.params, batch,
                                           max_seq=self.max_seq)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        steps = max(r.max_new_tokens for r in wave)
        done = np.zeros(b, bool)
        gen: List[List[int]] = [[] for _ in range(b)]
        for _ in range(steps):
            for i, r in enumerate(wave):
                if not done[i]:
                    gen[i].append(int(next_tok[i]))
                    if (int(next_tok[i]) == r.eos_id
                            or len(gen[i]) >= r.max_new_tokens):
                        done[i] = True
            if done[:len(wave)].all():
                break
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": next_tok[:, None]})
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return {r.uid: gen[i] for i, r in enumerate(wave)}


def tune_engine_batch(
    engine_factory,
    requests: List[Request],
    batch_sizes: Tuple[int, ...] = (1, 2, 4, 8),
    budget: Optional[int] = None,
    seed: int = 0,
):
    """Pick the engine batch size by timed end-to-end trials, driven through
    the shared ask-tell tuning API (``FunctionEvaluator`` + registry
    searcher — no counters exist for a serving loop, so the search is
    runtime-only).

    ``engine_factory(batch_size) -> ServeEngine``.  Returns
    (best_batch_size, best_seconds, history) where history is the public
    per-trial (config index, seconds) trace.
    """
    import time as _time

    from repro.core.evaluate import FunctionEvaluator
    from repro.core.searcher import make_searcher, run_search
    from repro.core.tuning_space import TuningParameter, TuningSpace

    space = TuningSpace([TuningParameter("BATCH", tuple(batch_sizes))],
                        name="serve_batch")

    def timed_run(cfg) -> float:
        engine = engine_factory(int(cfg["BATCH"]))
        t0 = _time.time()
        engine.generate([dataclasses.replace(r, generated=None)
                         for r in requests])
        return _time.time() - t0

    ev = FunctionEvaluator(space, timed_run)
    run_search(make_searcher("random", space, seed=seed), ev,
               budget if budget is not None else len(space))
    best = space[ev.best_index]
    return int(best["BATCH"]), ev.best_runtime, ev.history()
