"""Batched serving engine: prefill + decode with continuous slot reuse.

The engine owns a fixed-size batch of decode slots.  Requests are admitted
into free slots (their prompt prefilled into the slot's cache region),
decoded greedily until EOS/max-len, then the slot is recycled — a
continuous-batching loop in the vLLM style, expressed over the functional
prefill/decode of the model zoo.

For simplicity slots share one right-aligned cache (prefill fills positions
[0, prompt_len); decode appends) and admission happens between decode
steps.  This is the serving analog of the train driver and the substrate
for the decode dry-run cells.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (L,) int32
    max_new_tokens: int = 16
    eos_id: int = -1            # -1: never stops early
    generated: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, model: Model, batch_size: int, max_seq: int,
                 params=None, rng=None):
        self.model = model
        self.batch = batch_size
        self.max_seq = max_seq
        self.params = params if params is not None else model.init(
            rng if rng is not None else jax.random.PRNGKey(0))
        self._decode = jax.jit(model.decode, donate_argnums=(1,))

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion, batch_size at a time."""
        out: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave = queue[:self.batch]
            queue = queue[self.batch:]
            out.update(self._run_wave(wave))
        return out

    def _run_wave(self, wave: List[Request]) -> Dict[int, List[int]]:
        # A partial wave (the queue tail) is masked to its true size: padding
        # it to self.batch would prefill+decode ghost slots for the full step
        # count — pure wasted compute that also skews wave timings.
        n = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((n, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self.model.prefill(self.params, batch,
                                           max_seq=self.max_seq)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        steps = max(r.max_new_tokens for r in wave)
        done = np.zeros(n, bool)
        gen: List[List[int]] = [[] for _ in range(n)]
        # an exhausted budget means no generated tokens at all — enforce the
        # limit before the first append, not after it
        for i, r in enumerate(wave):
            if r.max_new_tokens <= 0:
                done[i] = True
        for _ in range(steps):
            for i, r in enumerate(wave):
                if not done[i]:
                    gen[i].append(int(next_tok[i]))
                    if (int(next_tok[i]) == r.eos_id
                            or len(gen[i]) >= r.max_new_tokens):
                        done[i] = True
            if done.all():
                break
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": next_tok[:, None]})
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return {r.uid: gen[i] for i, r in enumerate(wave)}

    def warmup(self, prompt_len: int = 4, wave_size: Optional[int] = None
               ) -> None:
        """Run one untimed dummy wave so prefill and at least one decode step
        are compiled before any timed serving/tuning measurement."""
        n = min(wave_size if wave_size is not None else self.batch,
                self.batch)
        plen = max(1, min(prompt_len, self.max_seq - 2))
        reqs = [Request(uid=-1 - i, prompt=np.ones(plen, np.int32),
                        max_new_tokens=2) for i in range(n)]
        self.generate(reqs)

    def warmup_for(self, n_requests: int, prompt_len: int = 4) -> None:
        """Warm every wave size ``generate(n_requests requests)`` will run:
        the full-batch wave and the masked partial tail (distinct jitted
        decode shapes) — so a timed run over ``n_requests`` compiles
        nothing."""
        n = max(1, int(n_requests))
        sizes = {min(self.batch, n)}
        if n % self.batch:
            sizes.add(n % self.batch)
        for size in sorted(sizes):
            self.warmup(prompt_len=prompt_len, wave_size=size)


def tune_engine_batch(
    engine_factory,
    requests: List[Request],
    batch_sizes: Tuple[int, ...] = (1, 2, 4, 8),
    budget: Optional[int] = None,
    seed: int = 0,
    warmup: bool = True,
):
    """Pick the engine batch size by timed end-to-end trials, driven through
    the shared ask-tell tuning API (``FunctionEvaluator`` + registry
    searcher — no counters exist for a serving loop, so the search is
    runtime-only).

    Engines are built once per batch size and reused across repeated trials,
    and each engine serves one untimed warmup wave before its first timed
    trial — otherwise the timed region includes first-call JIT compilation
    of prefill/decode, which scales with batch size and biases selection.

    ``engine_factory(batch_size) -> ServeEngine``.  Returns
    (best_batch_size, best_seconds, history) where history is the public
    per-trial (config index, seconds) trace.
    """
    import time as _time

    from repro.core.evaluate import FunctionEvaluator
    from repro.core.searcher import make_searcher, run_search
    from repro.core.tuning_space import TuningParameter, TuningSpace

    space = TuningSpace([TuningParameter("BATCH", tuple(batch_sizes))],
                        name="serve_batch")
    engines: Dict[int, ServeEngine] = {}

    def _engine(b: int) -> ServeEngine:
        if b not in engines:
            eng = engines[b] = engine_factory(b)
            # warm every wave shape the timed run will hit (full + tail)
            if warmup and hasattr(eng, "warmup_for"):
                eng.warmup_for(len(requests))
            elif warmup and hasattr(eng, "warmup"):
                eng.warmup()
        return engines[b]

    def timed_run(cfg) -> float:
        engine = _engine(int(cfg["BATCH"]))
        t0 = _time.perf_counter()
        engine.generate([dataclasses.replace(r, generated=None)
                         for r in requests])
        return _time.perf_counter() - t0

    ev = FunctionEvaluator(space, timed_run)
    run_search(make_searcher("random", space, seed=seed), ev,
               budget if budget is not None else len(space))
    best = space[ev.best_index]
    return int(best["BATCH"]), ev.best_runtime, ev.history()
